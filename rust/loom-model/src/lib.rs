//! loom model checks for the two concurrency protocols every serving PR
//! stacks on: `util::pool`'s claim-counter + raw-pointer partitioning and
//! the scheduler's round-boundary cancellation registry.
//!
//! These are *models*, not imports of the production code: `util::pool`
//! builds on `std::thread::scope` and std atomics, which loom cannot
//! instrument (loom requires its own `loom::sync`/`loom::thread` types,
//! and has no scoped threads). Each test re-expresses the production
//! protocol 1:1 in loom vocabulary — same orderings, same claim and
//! partition arithmetic, same registry call sequence — so a bug in the
//! protocol itself (e.g. the `Relaxed` claim counter permitting a
//! double-claim, or a cancellation lost across a round boundary) is caught
//! even though the concrete functions are not linked.
//!
//! Run with the nightly verify workflow, or locally:
//!
//! ```text
//! cd rust/loom-model
//! RUSTFLAGS="--cfg loom" cargo test --release
//! ```
//!
//! The tests live in `tests/` behind `#![cfg(loom)]`; without the cfg this
//! crate is intentionally empty so accidental plain builds are free.

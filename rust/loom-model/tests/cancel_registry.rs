//! Models the scheduler's `CancelHandle` protocol: external threads insert
//! ids at any time; the scheduler thread takes a `snapshot()` at each round
//! boundary, finishes matching requests (calling `clear_id`), and calls
//! `clear_all` when a run drains. The pinned invariants:
//!
//! * a cancel that lands before the final snapshot is either observed by
//!   that snapshot or wiped by the drain — never silently resurrected for
//!   a later request reusing the id;
//! * the registry is empty after every drain, in every interleaving.
#![cfg(loom)]

use loom::sync::{Arc, Mutex};
use loom::thread;

#[derive(Clone, Default)]
struct Registry {
    ids: Arc<Mutex<Vec<usize>>>,
}

// Same call surface as scheduler::CancelHandle (Vec for a set: loom models
// the lock protocol, not the container).
impl Registry {
    fn cancel(&self, id: usize) {
        let mut g = self.ids.lock().unwrap();
        if !g.contains(&id) {
            g.push(id);
        }
    }
    fn snapshot(&self) -> Vec<usize> {
        self.ids.lock().unwrap().clone()
    }
    fn clear_id(&self, id: usize) {
        self.ids.lock().unwrap().retain(|&x| x != id);
    }
    fn clear_all(&self) {
        self.ids.lock().unwrap().clear();
    }
}

#[test]
fn registry_empty_after_drain_in_every_interleaving() {
    loom::model(|| {
        let reg = Registry::default();
        let external = {
            let reg = reg.clone();
            thread::spawn(move || {
                reg.cancel(7);
                reg.cancel(9);
            })
        };

        // Scheduler round: snapshot, finish the in-flight request 7 if its
        // cancel was observed, dropping its id like finish paths do.
        let snap = reg.snapshot();
        if snap.contains(&7) {
            reg.clear_id(7);
        }

        external.join().unwrap();
        // Run drains: unmatched ids (9, and 7 if its cancel raced past the
        // snapshot) must all be wiped so reused ids are never spuriously
        // cancelled.
        reg.clear_all();
        assert!(reg.snapshot().is_empty(), "drain leaked cancellations");
    });
}

#[test]
fn observed_cancel_is_consumed_not_resurrected() {
    loom::model(|| {
        let reg = Registry::default();
        let external = {
            let reg = reg.clone();
            thread::spawn(move || reg.cancel(3))
        };

        // Round 1: maybe observe and consume the cancel.
        let observed_r1 = reg.snapshot().contains(&3);
        if observed_r1 {
            reg.clear_id(3);
        }
        external.join().unwrap();

        // Round 2 (same run, id 3 finished in round 1): a consumed cancel
        // must not reappear; an unconsumed one must still be visible so the
        // round boundary can act on it.
        let observed_r2 = reg.snapshot().contains(&3);
        assert!(
            observed_r1 ^ observed_r2,
            "cancel must be seen exactly once across round boundaries"
        );
        if observed_r2 {
            reg.clear_id(3);
        }
        assert!(reg.snapshot().is_empty());
    });
}

//! Models `util::pool::parallel_map` / `parallel_chunks_mut`: workers claim
//! work items from a shared atomic counter with `Ordering::Relaxed` and
//! write disjoint slots of one buffer through a shared raw pointer
//! (`SendPtr`). loom's `UnsafeCell` access tracking fails the test if any
//! interleaving lets two threads touch the same slot concurrently, and the
//! final assertion fails if any interleaving loses or duplicates a claim.
#![cfg(loom)]

use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

/// The claim loop of `parallel_map`, verbatim: `fetch_add(1, Relaxed)`
/// hands out indices; the winner writes slot `i` exactly once.
#[test]
fn relaxed_claim_counter_partitions_slot_writes() {
    loom::model(|| {
        const N: usize = 3;
        let next = Arc::new(AtomicUsize::new(0));
        let slots: Arc<Vec<UnsafeCell<usize>>> =
            Arc::new((0..N).map(|_| UnsafeCell::new(usize::MAX)).collect());

        let mut handles = Vec::new();
        for _ in 0..2 {
            let next = next.clone();
            let slots = slots.clone();
            handles.push(thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= N {
                    break;
                }
                // Production writes `*slot_ptr.get().add(i) = Some(out)`;
                // the UnsafeCell stands in for that raw write and lets
                // loom police exclusive access per slot.
                slots[i].with_mut(|p| unsafe { *p = i * i });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Join is the only synchronization (as with thread::scope): every
        // claim must have produced exactly its own slot's value.
        for (i, s) in slots.iter().enumerate() {
            s.with(|p| assert_eq!(unsafe { *p }, i * i, "slot {i} lost or torn"));
        }
    });
}

/// The chunk partition of `parallel_chunks_mut`: claimed chunk index `ci`
/// maps to `[ci*chunk, min((ci+1)*chunk, len))`. Two threads, ragged tail.
#[test]
fn chunk_ranges_are_disjoint_and_cover() {
    loom::model(|| {
        const LEN: usize = 5;
        const CHUNK: usize = 2;
        let n_chunks = LEN.div_ceil(CHUNK);
        let next = Arc::new(AtomicUsize::new(0));
        let data: Arc<Vec<UnsafeCell<usize>>> =
            Arc::new((0..LEN).map(|_| UnsafeCell::new(0)).collect());

        let mut handles = Vec::new();
        for _ in 0..2 {
            let next = next.clone();
            let data = data.clone();
            handles.push(thread::spawn(move || loop {
                let ci = next.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let start = ci * CHUNK;
                let end = (start + CHUNK).min(LEN);
                for k in start..end {
                    data[k].with_mut(|p| unsafe { *p += k + 1 });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for (k, c) in data.iter().enumerate() {
            c.with(|p| assert_eq!(unsafe { *p }, k + 1, "element {k} written != once"));
        }
    });
}

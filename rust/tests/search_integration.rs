//! Integration tests of the full search stack on real artifacts:
//! Algorithm 1 must monotonically improve the calibration objective, be
//! deterministic, compose with every baseline, and respect transform-kind
//! ablation masks.

use invarexplore::baselines::{self, Method};
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::{PipelineOpts, SearchRun, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::search;
use invarexplore::transform::TransformKinds;

fn session() -> Option<Session> {
    match Session::load_default() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn base_opts(model: &str, method: Method) -> PipelineOpts {
    let mut o = PipelineOpts::new(model, method, QuantScheme::new(2, 64));
    o.calib_seqs = 8;
    o.eval_seqs = 16;
    o
}

#[test]
fn search_improves_calibration_loss_monotonically() {
    let Some(session) = session() else { return };
    let opts = base_opts("opt-tiny", Method::Rtn);
    let mut run = SearchRun::build(&session, &opts).unwrap();
    run.init().unwrap();
    let init_loss = run.state.best.total(run.state.alpha);
    run.steps(60).unwrap();
    let final_loss = run.state.best.total(run.state.alpha);
    assert!(final_loss < init_loss, "no improvement: {init_loss} -> {final_loss}");
    // monotone best-loss telemetry
    let mut prev = f64::INFINITY;
    for r in &run.state.telemetry {
        assert!(r.loss_total <= prev + 1e-12);
        prev = r.loss_total;
    }
    assert!(run.state.accepts > 0, "nothing accepted in 60 steps");
}

#[test]
fn search_deterministic_under_seed() {
    let Some(session) = session() else { return };
    let result = |seed: u64| {
        let mut o = base_opts("opt-tiny", Method::Rtn);
        o.seed = seed;
        let mut run = SearchRun::build(&session, &o).unwrap();
        run.init().unwrap();
        run.steps(25).unwrap();
        (run.state.best.ce, run.state.accepts)
    };
    let a = result(3);
    let b = result(3);
    assert_eq!(a, b, "same seed must reproduce exactly");
}

#[test]
fn search_composes_with_all_baselines() {
    let Some(session) = session() else { return };
    for method in [Method::Rtn, Method::Awq, Method::Gptq, Method::OmniQuant] {
        let opts = base_opts("opt-tiny", method);
        let mut run = SearchRun::build(&session, &opts).unwrap();
        run.init().unwrap();
        let init = run.state.best.total(run.state.alpha);
        run.steps(25).unwrap();
        let fin = run.state.best.total(run.state.alpha);
        assert!(
            fin <= init,
            "{}: loss went up {init} -> {fin}",
            method.name()
        );
        eprintln!(
            "{}: loss {:.4} -> {:.4} (accept {:.2})",
            method.name(),
            init,
            fin,
            run.state.accept_rate()
        );
    }
}

#[test]
fn ablation_masks_respected() {
    let Some(session) = session() else { return };
    for kinds in ["p", "s", "r"] {
        let mut opts = base_opts("opt-tiny", Method::Rtn);
        opts.kinds = TransformKinds::parse(kinds).unwrap();
        let mut run = SearchRun::build(&session, &opts).unwrap();
        run.init().unwrap();
        run.steps(20).unwrap();
        for t in &run.state.transforms {
            if kinds != "p" {
                assert!(
                    t.perm.iter().enumerate().all(|(i, &p)| i == p),
                    "{kinds}: permutation leaked"
                );
            }
            if kinds != "s" {
                assert!(t.scale.iter().all(|&s| s == 1.0), "{kinds}: scaling leaked");
            }
            if kinds != "r" {
                assert!(t.phis.iter().all(|&p| p == 0.0), "{kinds}: rotation leaked");
            }
        }
    }
}

#[test]
fn accepted_transforms_preserve_fp_invariance() {
    // After a search, applying the accepted transforms to the FP model must
    // not change its function (up to rotation's approximation).
    let Some(session) = session() else { return };
    let opts = base_opts("opt-tiny", Method::Rtn);
    let mut run = SearchRun::build(&session, &opts).unwrap();
    run.init().unwrap();
    run.steps(40).unwrap();

    let w = session.weights("opt-tiny").unwrap();
    let pile = session.corpus("pile").unwrap();
    let cs = CalibSet::from_corpus(&pile, 8, session.manifest.seq);
    let ce0 = invarexplore::model::native::forward(
        &w,
        &cs.tokens,
        &cs.targets,
        &cs.masks,
        Default::default(),
    )
    .ce;
    let mut w2 = w.clone();
    for (l, t) in run.state.transforms.iter().enumerate() {
        invarexplore::transform::apply_to_layer(&w, &mut w2, l, t);
    }
    let ce1 = invarexplore::model::native::forward(
        &w2,
        &cs.tokens,
        &cs.targets,
        &cs.masks,
        Default::default(),
    )
    .ce;
    let drift = (ce1 - ce0).abs() / ce0;
    assert!(drift < 1e-3, "FP invariance broken: {ce0} -> {ce1}");
}

#[test]
fn probed_proposals_restore_state_exactly() {
    // draft + evaluate a proposal without committing, and verify a full
    // re-eval equals the accepted loss (buffer restore is exact).
    let Some(session) = session() else { return };
    let opts = base_opts("opt-tiny", Method::Awq);
    let mut run = SearchRun::build(&session, &opts).unwrap();
    run.init().unwrap();
    let before = run.state.best;

    let proposal = run.state.transforms[0].propose(
        &mut run.state.rng,
        TransformKinds::all(),
        0.2,
        0.05,
        1e-4,
    );
    let _ = search::probe(&mut run.obj, 0, &proposal).unwrap();
    let after = run.obj.eval.full_eval().unwrap();
    assert!(
        (after.ce - before.ce).abs() < 1e-9 + before.ce * 1e-6,
        "probe did not restore: {} vs {}",
        before.ce,
        after.ce
    );
}

#[test]
fn batched_rounds_match_sequential_at_k1_on_real_stack() {
    // --batch 1 must reproduce the sequential search bit-for-bit on the
    // full XLA objective: identical telemetry streams for a fixed seed.
    let Some(session) = session() else { return };
    let telem = |batch: usize| {
        let mut o = base_opts("opt-tiny", Method::Rtn);
        o.seed = 9;
        o.batch = batch;
        let mut run = SearchRun::build(&session, &o).unwrap();
        run.init().unwrap();
        run.steps(20).unwrap();
        run.state
    };
    let seq = telem(1); // dispatches to the sequential driver
    let k1 = {
        // force the round engine at K = 1
        let mut o = base_opts("opt-tiny", Method::Rtn);
        o.seed = 9;
        let mut run = SearchRun::build(&session, &o).unwrap();
        run.init().unwrap();
        search::run_rounds(&mut run.obj, &mut run.state, &run.cfg.clone(), 20, 1).unwrap();
        run.state
    };
    assert_eq!(seq.telemetry.len(), k1.telemetry.len());
    for (a, b) in seq.telemetry.iter().zip(&k1.telemetry) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.layer, b.layer);
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.loss_total.to_bits(), b.loss_total.to_bits(), "step {}", a.step);
        assert_eq!(a.ce.to_bits(), b.ce.to_bits());
        assert_eq!(a.act_mse.to_bits(), b.act_mse.to_bits());
    }
    assert_eq!(seq.accepts, k1.accepts);
}

#[test]
fn batched_rounds_improve_monotonically_on_real_stack() {
    let Some(session) = session() else { return };
    let mut o = base_opts("opt-tiny", Method::Rtn);
    o.batch = 3;
    let mut run = SearchRun::build(&session, &o).unwrap();
    run.init().unwrap();
    let init_loss = run.state.best.total(run.state.alpha);
    run.steps(45).unwrap();
    assert_eq!(run.state.telemetry.len(), 45);
    let mut prev = f64::INFINITY;
    for r in &run.state.telemetry {
        assert!(r.loss_total <= prev + 1e-12, "loss increased under batching");
        prev = r.loss_total;
    }
    assert!(run.state.best.total(run.state.alpha) <= init_loss);
    // committed losses must be exact: a full re-eval reproduces best
    let full = run.obj.eval.full_eval().unwrap();
    assert!(
        (full.ce - run.state.best.ce).abs() < 1e-9 + run.state.best.ce * 1e-6,
        "accepted loss drifted from device state: {} vs {}",
        run.state.best.ce,
        full.ce
    );
}

#[test]
fn search_state_checkpoint_roundtrip_on_real_run() {
    let Some(session) = session() else { return };
    let opts = base_opts("opt-tiny", Method::Rtn);
    let mut run = SearchRun::build(&session, &opts).unwrap();
    run.init().unwrap();
    run.steps(15).unwrap();
    let dir = std::env::temp_dir().join("invarexplore_search_it");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("state.json");
    run.state.save(&p).unwrap();
    let restored = invarexplore::search::SearchState::load(&p, 0).unwrap();
    assert_eq!(restored.step, run.state.step);
    for (a, b) in restored.transforms.iter().zip(&run.state.transforms) {
        assert_eq!(a.perm, b.perm);
    }
    // the saved transforms must apply cleanly to a fresh Prepared
    let w = session.weights("opt-tiny").unwrap();
    let pile = session.corpus("pile").unwrap();
    let cs = CalibSet::from_corpus(&pile, 8, session.manifest.seq);
    let prepared = baselines::prepare(Method::Rtn, opts.scheme, &w, &cs, None).unwrap();
    let mut w2 = prepared.fp.clone();
    for (l, t) in restored.transforms.iter().enumerate() {
        invarexplore::transform::apply_to_layer(&prepared.fp, &mut w2, l, t);
    }
}

#[test]
fn resume_continues_from_checkpoint() {
    let Some(session) = session() else { return };
    let opts = base_opts("opt-tiny", Method::Rtn);
    // run 20 steps, checkpoint
    let mut run1 = SearchRun::build(&session, &opts).unwrap();
    run1.init().unwrap();
    run1.steps(20).unwrap();
    let loss_at_ckpt = run1.state.best.total(run1.state.alpha);
    let dir = std::env::temp_dir().join("invarexplore_resume_it");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("ckpt.json");
    run1.state.save(&p).unwrap();

    // restore in a fresh stack: loss must match the checkpointed loss
    let saved = invarexplore::search::SearchState::load(&p, 0).unwrap();
    let mut run2 = SearchRun::build(&session, &opts).unwrap();
    run2.restore(saved).unwrap();
    assert_eq!(run2.state.step, 20);
    let restored_loss = run2.state.best.total(run2.state.alpha);
    assert!(
        (restored_loss - loss_at_ckpt).abs() < 1e-6 + loss_at_ckpt * 1e-4,
        "restored {restored_loss} vs checkpoint {loss_at_ckpt}"
    );
    // and further steps keep improving monotonically
    run2.steps(10).unwrap();
    assert!(run2.state.best.total(run2.state.alpha) <= restored_loss + 1e-12);
    assert_eq!(run2.state.step, 30);
}

//! Evaluation-harness integration: perplexity and few-shot reasoning on the
//! real trained models through the XLA engine, with the sanity properties
//! the paper's Table 1 depends on (FP best, bigger models better, trained
//! models above chance).

use invarexplore::coordinator::Session;
use invarexplore::eval;
use invarexplore::io::tasks;
use invarexplore::runtime::Engine;

fn session() -> Option<Session> {
    match Session::load_default() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn fp_perplexity_beats_unigram_and_scales_with_size() {
    let Some(session) = session() else { return };
    let wiki = session.corpus("wiki").unwrap();
    let mut ppls = Vec::new();
    for model in ["opt-tiny", "opt-base"] {
        let w = session.weights(model).unwrap();
        let mut engine = Engine::load(&session.manifest, model).unwrap();
        engine.upload_weights(&w).unwrap();
        let ppl = eval::perplexity(&engine, &wiki, 32).unwrap();
        eprintln!("{model}: wiki ppl {ppl:.2}");
        assert!(ppl < session.manifest.data.vocab as f64, "{model} worse than uniform");
        assert!(ppl > 1.0);
        ppls.push(ppl);
    }
    assert!(
        ppls[1] < ppls[0],
        "bigger model must have lower ppl: {ppls:?}"
    );
}

#[test]
fn reasoning_above_chance_on_trained_model() {
    let Some(session) = session() else { return };
    let model = "opt-base";
    let w = session.weights(model).unwrap();
    let mut engine = Engine::load(&session.manifest, model).unwrap();
    engine.upload_weights(&w).unwrap();

    let (results, avg) = eval::eval_all_tasks(&engine, &session.manifest.data, 5, 40, 0).unwrap();
    for r in &results {
        eprintln!("{:8} acc {:6.2} (n={})", r.task, r.accuracy, r.n);
    }
    eprintln!("avg {avg:.2}");
    // chance: 2-option tasks 50, 4-option 25 — average chance ≈ 41.7;
    // a trained model must clear it by a margin
    assert!(avg > 47.0, "avg accuracy {avg} not above chance margin");
}

#[test]
fn reasoning_deterministic() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let mut engine = Engine::load(&session.manifest, model).unwrap();
    engine.upload_weights(&w).unwrap();
    let examples = tasks::read(session.manifest.data.task("bool").unwrap()).unwrap();
    let a = eval::eval_task(&engine, "bool", &examples, 5, 20, 7).unwrap();
    let b = eval::eval_task(&engine, "bool", &examples, 5, 20, 7).unwrap();
    assert_eq!(a.accuracy, b.accuracy);
    let c = eval::eval_task(&engine, "bool", &examples, 5, 20, 8).unwrap();
    // different seed shuffles demonstrations; accuracy may differ but both
    // must be valid percentages
    assert!((0.0..=100.0).contains(&c.accuracy));
}

#[test]
fn quantization_degrades_reasoning_and_ppl() {
    let Some(session) = session() else { return };
    let model = "opt-base";
    let w = session.weights(model).unwrap();
    let wiki = session.corpus("wiki").unwrap();
    let mut engine = Engine::load(&session.manifest, model).unwrap();

    engine.upload_weights(&w).unwrap();
    let ppl_fp = eval::perplexity(&engine, &wiki, 32).unwrap();

    // 1-bit RTN — the paper's most damaged setting
    let mut wq = w.clone();
    for name in w.quant_names() {
        wq.set(
            &name,
            invarexplore::quant::fake_quant(w.get(&name), invarexplore::quant::QuantScheme::new(1, 32)),
        );
    }
    engine.upload_weights(&wq).unwrap();
    let ppl_1bit = eval::perplexity(&engine, &wiki, 32).unwrap();
    eprintln!("wiki ppl: fp {ppl_fp:.2} -> 1-bit {ppl_1bit:.2}");
    assert!(
        ppl_1bit > ppl_fp * 1.5,
        "1-bit quantization should clearly hurt ({ppl_fp} -> {ppl_1bit})"
    );
}

//! Cross-module property tests (propcheck-driven): algebraic invariants
//! that must hold for *any* seeded input, independent of artifacts.

use invarexplore::quant::{self, PackedTensor, QuantScheme};
use invarexplore::tensor::{ops, Tensor};
use invarexplore::transform::{apply_to_tensors, LayerTransform, TransformKinds};
use invarexplore::util::json::{self, Json};
use invarexplore::util::propcheck::{check, ensure, ensure_all_close};
use invarexplore::util::rng::Pcg64;

fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize, scale: f32) -> Tensor {
    Tensor::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect())
}

// ---------------------------------------------------------------------------
// Transform algebra
// ---------------------------------------------------------------------------

#[test]
fn prop_permutation_composition_is_permutation() {
    check("perm ∘ perm is a valid transform", 64, |rng| {
        let d = 2 * (rng.below(31) + 2);
        let mut t = LayerTransform::identity(d);
        for _ in 0..5 {
            t = t.propose(rng, TransformKinds::parse("p").unwrap(), 0.3, 0.0, 0.0);
            t.validate().map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

#[test]
fn prop_transform_preserves_ffn_rank_structure() {
    // transformed tensors have the same shapes and finite values
    check("transform output well-formed", 48, |rng| {
        let f = 2 * (rng.below(15) + 2);
        let d = rng.below(12) + 2;
        let wu = rand_tensor(rng, f, d, 1.0);
        let bu = rand_tensor(rng, 1, f, 1.0);
        let wd = rand_tensor(rng, d, f, 1.0);
        let t = LayerTransform::identity(f).propose(rng, TransformKinds::all(), 0.5, 0.3, 0.01);
        let (wu2, bu2, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
        ensure(wu2.shape() == (f, d), "wu shape")?;
        ensure(bu2.numel() == f, "bu shape")?;
        ensure(wd2.shape() == (d, f), "wd shape")?;
        ensure(
            wu2.data.iter().chain(&bu2.data).chain(&wd2.data).all(|v| v.is_finite()),
            "non-finite output",
        )
    });
}

#[test]
fn prop_permutation_scaling_preserve_frobenius_structure() {
    // P alone preserves all row norms of W_up as a multiset; S scales them.
    check("P preserves W_up row-norm multiset", 48, |rng| {
        let f = 2 * (rng.below(15) + 2);
        let d = rng.below(12) + 2;
        let wu = rand_tensor(rng, f, d, 1.0);
        let bu = rand_tensor(rng, 1, f, 1.0);
        let wd = rand_tensor(rng, d, f, 1.0);
        let t = LayerTransform::identity(f).propose(rng, TransformKinds::parse("p").unwrap(), 0.5, 0.0, 0.0);
        let (wu2, _, _) = apply_to_tensors(&t, &wu, &bu, &wd);
        let norms = |w: &Tensor| {
            let mut v: Vec<i64> = (0..w.rows)
                .map(|r| (w.row(r).iter().map(|x| (x * x) as f64).sum::<f64>() * 1e6) as i64)
                .collect();
            v.sort_unstable();
            v
        };
        ensure(norms(&wu) == norms(&wu2), "row-norm multiset changed")
    });
}

#[test]
fn prop_quantized_output_is_fixed_point() {
    check("fake_quant idempotent under every scheme", 48, |rng| {
        let bits = rng.below(4) + 1;
        let group = *rng.choice(&[16usize, 32, 64]);
        let scheme = QuantScheme::new(bits, group);
        let rows = rng.below(6) + 1;
        let cols = group * (rng.below(4) + 1);
        let w = rand_tensor(rng, rows, cols, 2.0);
        let q1 = quant::fake_quant(&w, scheme);
        let q2 = quant::fake_quant(&q1, scheme);
        ensure_all_close(&q1.data, &q2.data, 1e-5, "fixed point")
    });
}

#[test]
fn prop_pack_unpack_bounded_by_f16_scale_error() {
    check("packed dequant ≈ exact dequant", 32, |rng| {
        let scheme = QuantScheme::new(rng.below(3) + 1, 32);
        let rows = rng.below(5) + 1;
        let w = rand_tensor(rng, rows, 64, 1.0);
        let q = quant::quantize(&w, scheme);
        let exact = quant::dequantize(&q);
        let packed = PackedTensor::pack(&q).unpack();
        for (a, b) in exact.data.iter().zip(&packed.data) {
            let tol = (a.abs() * 2e-3).max(2e-4);
            if (a - b).abs() > tol {
                return Err(format!("{a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_error_never_worse_after_clip_search() {
    check("clip search dominates plain RTN", 32, |rng| {
        let scheme = QuantScheme::new(rng.below(3) + 1, 32);
        let rows = rng.below(5) + 1;
        let scale = *rng.choice(&[0.05f32, 1.0, 20.0]);
        let w = rand_tensor(rng, rows, 64, scale);
        let plain = w.mse(&quant::fake_quant(&w, scheme));
        let clipped = w.mse(&quant::clip::fake_quant_clip_search(
            &w,
            scheme,
            &quant::clip::OMNI_CLIP_GRID,
        ));
        ensure(clipped <= plain + 1e-12, format!("{clipped} > {plain}"))
    });
}

// ---------------------------------------------------------------------------
// Tensor / linalg
// ---------------------------------------------------------------------------

#[test]
fn prop_matmul_distributes_over_addition() {
    check("X(A+B) == XA + XB", 32, |rng| {
        let m = rng.below(6) + 1;
        let k = rng.below(10) + 1;
        let n = rng.below(8) + 1;
        let x = rand_tensor(rng, m, k, 1.0);
        let a = rand_tensor(rng, n, k, 1.0);
        let b = rand_tensor(rng, n, k, 1.0);
        let ab = Tensor::from_vec(n, k, a.data.iter().zip(&b.data).map(|(p, q)| p + q).collect());
        let mut y_ab = vec![0.0; m * n];
        let mut y_a = vec![0.0; m * n];
        let mut y_b = vec![0.0; m * n];
        ops::matmul_nt(&x.data, &ab.data, m, k, n, &mut y_ab);
        ops::matmul_nt(&x.data, &a.data, m, k, n, &mut y_a);
        ops::matmul_nt(&x.data, &b.data, m, k, n, &mut y_b);
        let sum: Vec<f32> = y_a.iter().zip(&y_b).map(|(p, q)| p + q).collect();
        ensure_all_close(&y_ab, &sum, 1e-3, "distributivity")
    });
}

#[test]
fn prop_softmax_rows_invariant_to_shift() {
    check("softmax(x) == softmax(x + c)", 32, |rng| {
        let t = rng.below(6) + 1;
        let mut a = rand_tensor(rng, t, 8, 2.0);
        let mut b = a.clone();
        let c = rng.normal() as f32 * 10.0;
        for v in &mut b.data {
            *v += c;
        }
        ops::softmax_rows(&mut a);
        ops::softmax_rows(&mut b);
        ensure_all_close(&a.data, &b.data, 1e-5, "shift invariance")
    });
}

#[test]
fn prop_layer_norm_output_standardized() {
    check("LN output has mean≈0, var≈1 with unit affine", 32, |rng| {
        let rows = rng.below(4) + 1;
        let scale = *rng.choice(&[0.1f32, 1.0, 50.0]);
        let x = rand_tensor(rng, rows, 32, scale);
        let out = ops::layer_norm(&x, &[1.0; 32], &[0.0; 32]);
        for r in 0..out.rows {
            let mean: f32 = out.row(r).iter().sum::<f32>() / 32.0;
            let var: f32 = out.row(r).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
            ensure((mean.abs()) < 1e-4, format!("mean {mean}"))?;
            ensure((var - 1.0).abs() < 1e-2, format!("var {var}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_log_prob_normalized() {
    check("Σ exp(logprob) == 1", 32, |rng| {
        let logits: Vec<f32> = (0..64).map(|_| rng.normal() as f32 * 3.0).collect();
        let total: f32 = (0..64).map(|i| ops::log_prob_at(&logits, i).exp()).sum();
        ensure((total - 1.0).abs() < 1e-3, format!("Σp = {total}"))
    });
}

// ---------------------------------------------------------------------------
// JSON fuzz
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.normal() * 100.0 * 1e6).round() / 1e6),
        3 => {
            let len = rng.below(8);
            Json::Str((0..len).map(|_| *rng.choice(&['a', 'b', '"', '\\', 'π', '\n', '\t'])).collect())
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    check("parse(to_string(v)) == v", 200, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        match json::parse(&text) {
            Ok(back) => ensure(back == v, format!("roundtrip mismatch for {text}")),
            Err(e) => Err(format!("parse failed on {text}: {e}")),
        }
    });
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    check("parser total on random bytes", 200, |rng| {
        let len = rng.below(40);
        let garbage: String = (0..len)
            .map(|_| *rng.choice(&['{', '}', '[', ']', '"', ':', ',', '1', 'e', '-', '.', ' ', 'n', 't']))
            .collect();
        let _ = json::parse(&garbage); // must return, not panic
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Search-state serialization
// ---------------------------------------------------------------------------

#[test]
fn prop_transform_json_roundtrip() {
    check("LayerTransform JSON roundtrip", 64, |rng| {
        let d = 2 * (rng.below(20) + 2);
        let t = LayerTransform::identity(d).propose(rng, TransformKinds::all(), 0.4, 0.2, 0.02);
        let back = LayerTransform::from_json(&t.to_json()).map_err(|e| e.to_string())?;
        ensure(back.perm == t.perm, "perm")?;
        for (a, b) in back.scale.iter().zip(&t.scale) {
            if (a - b).abs() > 1e-5 {
                return Err(format!("scale {a} vs {b}"));
            }
        }
        for (a, b) in back.phis.iter().zip(&t.phis) {
            if (a - b).abs() > 1e-6 {
                return Err(format!("phi {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// GPTQ invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_gptq_output_respects_codebook_cardinality() {
    // GPTQ's scale/zero are frozen from the *compensated* weights at group
    // start, so the output need not be an RTN fixed point — but each
    // (row, group) segment can still hold at most 2^bits distinct values.
    check("GPTQ row-group holds ≤ 2^bits distinct values", 24, |rng| {
        let bits = rng.below(2) + 2;
        let scheme = QuantScheme::new(bits, 16);
        let out = rng.below(6) + 2;
        let inp = 48;
        let x = rand_tensor(rng, 64, inp, 1.0);
        let h = invarexplore::calib::hessian(&x, 0.01);
        let w = rand_tensor(rng, out, inp, 1.0);
        let gq = invarexplore::baselines::gptq::gptq_quantize(&w, &h, scheme, false, None);
        for r in 0..out {
            for g in 0..inp / scheme.group {
                let seg = &gq.row(r)[g * scheme.group..(g + 1) * scheme.group];
                let mut vals: Vec<i64> = seg.iter().map(|&v| (v as f64 * 1e6).round() as i64).collect();
                vals.sort_unstable();
                vals.dedup();
                ensure(
                    vals.len() <= 1 << bits,
                    format!("row {r} group {g}: {} distinct values > {}", vals.len(), 1 << bits),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hessian_transform_preserves_spd() {
    check("T·H·Tᵀ stays SPD", 24, |rng| {
        let n = 16;
        let x = rand_tensor(rng, 48, n, 1.0);
        let h = invarexplore::calib::hessian(&x, 0.01);
        let t = LayerTransform::identity(n).propose(rng, TransformKinds::all(), 0.5, 0.3, 0.1);
        let ht = invarexplore::baselines::gptq::transform_hessian(&h, n, &t);
        invarexplore::tensor::linalg::cholesky(&ht, n)
            .map(|_| ())
            .map_err(|e| format!("not SPD: {e}"))
    });
}

//! Baseline-method integration on real trained models: the method ordering
//! the paper reports (RTN worst; GPTQ/AWQ/OmniQuant progressively better or
//! comparable) must hold in calibration CE, and every method's prepared
//! model must be FP-invariant.

use invarexplore::baselines::{self, Method};
use invarexplore::calib::{self, CalibSet};
use invarexplore::coordinator::Session;
use invarexplore::model::native::{forward, Capture};
use invarexplore::quant::QuantScheme;

fn session() -> Option<Session> {
    match Session::load_default() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn baseline_ordering_on_trained_model() {
    let Some(session) = session() else { return };
    let model = "opt-small";
    let w = session.weights(model).unwrap();
    let pile = session.corpus("pile").unwrap();
    let cs = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let stats = calib::capture(&w, &cs);
    let scheme = QuantScheme::new(2, 32);

    let ce_fp = stats.ce_fp;
    let mut ce = std::collections::HashMap::new();
    for m in Method::all() {
        let p = baselines::prepare(m, scheme, &w, &cs, Some(&stats)).unwrap();
        let q = p.quantize_model(&p.fp, None);
        let out = forward(&q, &cs.tokens, &cs.targets, &cs.masks, Capture::default());
        ce.insert(m.name(), out.ce);
        eprintln!("{:10} calib CE {:.4} (fp {:.4})", m.name(), out.ce, ce_fp);
    }
    // every method degrades vs FP...
    for (name, &v) in &ce {
        assert!(v > ce_fp, "{name} CE {v} not above FP {ce_fp}");
    }
    // ...and the calibrated methods beat plain RTN (the paper's core
    // ordering; ties within 2% tolerated at this scale)
    let rtn = ce["RTN"];
    for name in ["GPTQ", "AWQ", "OmniQuant"] {
        assert!(
            ce[name] <= rtn * 1.02,
            "{name} ({}) worse than RTN ({rtn})",
            ce[name]
        );
    }
}

#[test]
fn prepared_models_are_fp_invariant() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let pile = session.corpus("pile").unwrap();
    let cs = CalibSet::from_corpus(&pile, 8, session.manifest.seq);
    let stats = calib::capture(&w, &cs);
    let ce0 = stats.ce_fp;
    for m in Method::all() {
        let p = baselines::prepare(m, QuantScheme::new(2, 64), &w, &cs, Some(&stats)).unwrap();
        let out = forward(&p.fp, &cs.tokens, &cs.targets, &cs.masks, Capture::default());
        let drift = (out.ce - ce0).abs() / ce0;
        assert!(
            drift < 1e-4,
            "{}: preprocessing changed the FP model ({ce0} -> {})",
            m.name(),
            out.ce
        );
    }
}

#[test]
fn gptq_beats_rtn_at_equal_scheme_on_real_layer() {
    let Some(session) = session() else { return };
    let w = session.weights("opt-small").unwrap();
    let pile = session.corpus("pile").unwrap();
    let cs = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let stats = calib::capture(&w, &cs);
    let scheme = QuantScheme::new(2, 32);

    // proxy output error on the real down-projection of layer 0
    let x = &stats.inputs[0].down_in;
    let wt = w.layer(0, "down.w");
    let h = calib::hessian(x, baselines::gptq::DAMP);
    let rtn = invarexplore::quant::fake_quant(wt, scheme);
    let gptq = baselines::gptq::gptq_quantize(wt, &h, scheme, false, None);

    let err = |wq: &invarexplore::tensor::Tensor| {
        let (m, k, n) = (x.rows, x.cols, wt.rows);
        let mut y0 = vec![0.0f32; m * n];
        let mut y1 = vec![0.0f32; m * n];
        invarexplore::tensor::ops::matmul_nt(&x.data, &wt.data, m, k, n, &mut y0);
        invarexplore::tensor::ops::matmul_nt(&x.data, &wq.data, m, k, n, &mut y1);
        y0.iter().zip(&y1).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
    };
    let (e_rtn, e_gptq) = (err(&rtn), err(&gptq));
    eprintln!("layer-0 down.w output err: RTN {e_rtn:.4e}  GPTQ {e_gptq:.4e}");
    assert!(e_gptq < e_rtn, "GPTQ {e_gptq} !< RTN {e_rtn}");
}

#[test]
fn memory_accounting_matches_scheme() {
    let Some(session) = session() else { return };
    let w = session.weights("opt-base").unwrap();
    for (bits, group) in [(1usize, 32usize), (2, 64), (3, 64)] {
        let scheme = QuantScheme::new(bits, group);
        let p = baselines::rtn::prepare(scheme, &w);
        let (packed, bytes) = p.pack_model(&p.fp);
        let total: usize = packed.iter().map(|(_, t)| t.rows * t.cols).sum();
        let measured = bytes as f64 * 8.0 / total as f64;
        let nominal = scheme.bits_per_param();
        assert!(
            (measured - nominal).abs() / nominal < 0.15,
            "{scheme}: measured {measured:.3} vs nominal {nominal:.3} bits/param"
        );
        // the paper's headline: 2-bit ⇒ ≥85% memory saving vs FP16
        if bits == 2 {
            let saving = 1.0 - bytes as f64 / (total * 2) as f64;
            assert!(saving > 0.8, "saving {saving}");
        }
    }
}

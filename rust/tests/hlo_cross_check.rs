//! Integration tests over the real artifacts: pin the three quantization
//! implementations (Rust codec / Pallas kernel / jnp oracle-trained HLO)
//! and the two forward implementations (native Rust / XLA programs)
//! against each other.
//!
//! Requires `make artifacts`.  All cases share one process and run inside a
//! single #[test] each to serialize PJRT client usage.

use invarexplore::calib::CalibSet;
use invarexplore::coordinator::Session;
use invarexplore::io::tokens::TokenCorpus;
use invarexplore::model::native::{self, Capture};
use invarexplore::quant::{self, QuantScheme};
use invarexplore::runtime::{Engine, Evaluator};
use invarexplore::tensor::Tensor;
use invarexplore::util::rng::Pcg64;

fn session() -> Option<Session> {
    match Session::load_default() {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn calib(session: &Session, n: usize) -> CalibSet {
    let pile = session.corpus("pile").unwrap();
    CalibSet::from_corpus(&pile, n, session.manifest.seq)
}

/// Native Rust forward == monolithic HLO forward (CE, logprob, acts).
#[test]
fn native_forward_matches_hlo_monolith() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let cs = calib(&session, session.manifest.batch);

    // native
    let nat = native::forward(
        &w,
        &cs.tokens,
        &cs.targets,
        &cs.masks,
        Capture { hidden: true, linear_inputs: false, last_logits: false },
    );

    // HLO monolith
    let engine = Engine::load(&session.manifest, model).unwrap();
    let batch = engine.upload_batch(&cs.tokens, &cs.targets, &cs.masks).unwrap();
    let (ce, lp, acts) = engine.run_forward_fp(&w, &batch).unwrap();

    let rel = (nat.ce - ce).abs() / nat.ce;
    assert!(rel < 1e-4, "CE mismatch: native {} vs hlo {}", nat.ce, ce);
    for (a, b) in nat.seq_logprob.iter().zip(&lp) {
        assert!((a - b).abs() < 0.3 + a.abs() * 1e-3, "logprob {a} vs {b}");
    }
    // hidden stack: acts is [L*B*T, D]; native hidden[l] is [B*T, D]
    let cfg = &w.config;
    let bt = cs.n_seqs() * cs.seqlen();
    for l in 0..cfg.n_layers {
        let hl = &nat.hidden[l];
        let mut max_diff = 0f32;
        for r in 0..bt {
            for c in 0..cfg.d_model {
                let diff = (hl.at(r, c) - acts.at(l * bt + r, c)).abs();
                max_diff = max_diff.max(diff);
            }
        }
        assert!(max_diff < 5e-3, "layer {l} hidden max diff {max_diff}");
    }
}

/// Layer-pipelined engine == monolithic program (same weights, same batch).
#[test]
fn pipelined_engine_matches_monolith() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let cs = calib(&session, session.manifest.batch);

    let mut engine = Engine::load(&session.manifest, model).unwrap();
    engine.upload_weights(&w).unwrap();
    let batch = engine.upload_batch(&cs.tokens, &cs.targets, &cs.masks).unwrap();

    let (ce_pipe, lp_pipe, _) = engine.forward_full(&batch).unwrap();
    let (ce_mono, lp_mono, _) = engine.run_forward_fp(&w, &batch).unwrap();

    assert!(
        (ce_pipe - ce_mono).abs() < 1e-5 * ce_mono.abs().max(1.0),
        "pipelined {ce_pipe} vs monolith {ce_mono}"
    );
    for (a, b) in lp_pipe.iter().zip(&lp_mono) {
        assert!((a - b).abs() < 1e-2 + a.abs() * 1e-4);
    }
}

/// Rust codec == on-device Pallas fake-quant program, for every scheme.
#[test]
fn rust_codec_matches_pallas_kernel_on_device() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let engine = Engine::load(&session.manifest, model).unwrap();
    let cfg = &session.manifest.model(model).unwrap().config;
    let mut rng = Pcg64::new(42);

    for &bits in &session.manifest.quant_bits {
        for &group in &session.manifest.quant_groups {
            let scheme = QuantScheme::new(bits, group);
            for (r, c) in [
                (cfg.d_model, cfg.d_model),
                (cfg.d_ffn, cfg.d_model),
                (cfg.d_model, cfg.d_ffn),
            ] {
                let w = Tensor::from_vec(
                    r,
                    c,
                    (0..r * c).map(|_| rng.normal() as f32 * 0.1).collect(),
                );
                let host = quant::fake_quant(&w, scheme);
                let device = engine.device_fake_quant(&w, scheme).unwrap();
                let mut max_diff = 0f32;
                for (a, b) in host.data.iter().zip(&device.data) {
                    max_diff = max_diff.max((a - b).abs());
                }
                assert!(
                    max_diff < 2e-6,
                    "codec mismatch {scheme} shape ({r},{c}): {max_diff}"
                );
            }
        }
    }
}

/// In-graph Pallas quantized forward == rust-quantized weights + FP forward.
#[test]
fn forward_quant_monolith_matches_host_quantization() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let cs = calib(&session, session.manifest.batch);
    let scheme = QuantScheme::new(2, 64);

    let engine = Engine::load(&session.manifest, model).unwrap();
    let batch = engine.upload_batch(&cs.tokens, &cs.targets, &cs.masks).unwrap();

    // H0 from the FP monolith
    let (_, _, acts) = engine.run_forward_fp(&w, &batch).unwrap();

    // path A: in-graph Pallas fake-quant
    let (ce_a, _, mse_a) = engine.run_forward_quant(scheme, &w, &acts, &batch).unwrap();

    // path B: host-quantized weights through the FP monolith
    let mut wq = w.clone();
    for name in w.quant_names() {
        wq.set(&name, quant::fake_quant(w.get(&name), scheme));
    }
    let (ce_b, _, acts_b) = engine.run_forward_fp(&wq, &batch).unwrap();

    assert!(
        (ce_a - ce_b).abs() < 1e-4 * ce_b.max(1.0),
        "in-graph {ce_a} vs host-quant {ce_b}"
    );
    // and the in-graph act MSE equals the host-computed one
    let host_mse = {
        let cfg = &w.config;
        let bt = cs.n_seqs() * cs.seqlen();
        let mut total = 0.0;
        for l in 0..cfg.n_layers {
            let mut s = 0.0;
            for r in 0..bt {
                for c in 0..cfg.d_model {
                    let d = (acts_b.at(l * bt + r, c) - acts.at(l * bt + r, c)) as f64;
                    s += d * d;
                }
            }
            total += s / (bt * cfg.d_model) as f64;
        }
        total / cfg.n_layers as f64
    };
    assert!(
        (mse_a - host_mse).abs() < 1e-6 + host_mse * 1e-2,
        "act mse: in-graph {mse_a} vs host {host_mse}"
    );
}

/// Incremental (prefix-cache) evaluation == full evaluation after an update.
#[test]
fn incremental_eval_matches_full_eval() {
    let Some(session) = session() else { return };
    let model = "opt-tiny";
    let w = session.weights(model).unwrap();
    let cs = calib(&session, 8);

    let mut engine = Engine::load(&session.manifest, model).unwrap();
    engine.upload_weights(&w).unwrap();
    let match_layers = vec![0, 1];
    let mut ev = Evaluator::new(engine, &cs, match_layers).unwrap();
    ev.capture_h0().unwrap();

    // quantize layer-1 FFN only, evaluate incrementally vs fully
    let scheme = QuantScheme::new(2, 64);
    let l = 1usize;
    let base = ev.full_eval().unwrap();

    let upq = quant::fake_quant(w.layer(l, "up.w"), scheme);
    let downq = quant::fake_quant(w.layer(l, "down.w"), scheme);
    ev.engine.update_tensor(&format!("l{l}.up.w"), &upq).unwrap();
    ev.engine.update_tensor(&format!("l{l}.down.w"), &downq).unwrap();

    let pending = ev.eval_from_layer(l).unwrap();
    let inc = pending.loss;
    ev.accept(pending);

    // now recompute from scratch — must agree
    let full = ev.full_eval().unwrap();
    assert!(
        (inc.ce - full.ce).abs() < 1e-6 * full.ce.max(1.0),
        "incremental ce {} vs full {}",
        inc.ce,
        full.ce
    );
    assert!(
        (inc.act_mse - full.act_mse).abs() < 1e-9 + full.act_mse * 1e-3,
        "incremental mse {} vs full {}",
        inc.act_mse,
        full.act_mse
    );
    assert!(inc.ce > base.ce, "quantizing a layer must raise CE");
}

/// §3.2 pilot study: small random rotations leave the FP model's CE nearly
/// unchanged (paper: 0.001% drift), measured on the real trained model
/// through the XLA path.
#[test]
fn rotation_near_invariance_pilot() {
    let Some(session) = session() else { return };
    let model = "opt-small";
    let w = session.weights(model).unwrap();
    let cs = calib(&session, 8);

    let mut engine = Engine::load(&session.manifest, model).unwrap();
    engine.upload_weights(&w).unwrap();
    let (ce0, _, _) = engine.eval_batch(&cs.tokens, &cs.targets, &cs.masks).unwrap();

    // rotate every layer with sigma_r-scale angles
    let mut rng = Pcg64::new(5);
    let mut w2 = w.clone();
    for l in 0..w.config.n_layers {
        let mut t = invarexplore::transform::LayerTransform::identity(w.config.d_ffn);
        for p in t.phis.iter_mut() {
            *p = (rng.normal() * 1e-4) as f32;
        }
        invarexplore::transform::apply_to_layer(&w, &mut w2, l, &t);
    }
    engine.upload_weights(&w2).unwrap();
    let (ce1, _, _) = engine.eval_batch(&cs.tokens, &cs.targets, &cs.masks).unwrap();
    let drift = (ce1 - ce0).abs() / ce0;
    assert!(drift < 1e-4, "rotation drift {drift:.2e} (ce {ce0} -> {ce1})");
    eprintln!("rotation pilot: ce {ce0:.6} -> {ce1:.6} (drift {:.4}%)", drift * 100.0);
}

/// TokenCorpus sanity on real artifacts.
#[test]
fn corpora_load_and_chunk() {
    let Some(session) = session() else { return };
    for name in ["train", "pile", "wiki", "c4"] {
        let c: TokenCorpus = session.corpus(name).unwrap();
        assert_eq!(c.vocab, session.manifest.data.vocab);
        assert!(c.tokens.len() > 1000, "{name} too small");
        let seqs = c.sequences(4, session.manifest.seq);
        assert_eq!(seqs.len(), 4);
    }
}

//! Serving-path benchmarks: the fused packed-weight kernels and the
//! KV-cache decode loop, against the paths they replace.
//!
//! 1. fused unpack→dequant→GEMV directly on packed codes
//!    vs unpack-to-dense + dense GEMV (the old serve example's load path);
//! 2. per-token KV-cache decode ([`native::decode_step`])
//!    vs full-context re-forward per token (the old serve example's loop);
//! 3. end-to-end `serve::Server` throughput on a [`PackedModel`].
//!
//! Runs entirely on a synthetic random model — no artifacts needed, so CI
//! can exercise the whole serving path.  `--smoke` (or env
//! `SERVE_DECODE_SMOKE=1`) runs one decode step per path plus the parity
//! assertions and exits; `INVAREXPLORE_BENCH_MS` bounds full measurements.

use std::time::Instant;

use invarexplore::model::native::{self, Capture, KvCache};
use invarexplore::model::{OptConfig, Weights};
use invarexplore::quant::{self, PackedTensor, QuantScheme};
use invarexplore::serve::{PackedModel, Request, ServeOpts, Server};
use invarexplore::tensor::{ops, Tensor};
use invarexplore::util::bench::{self, BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn bench_config(smoke: bool) -> OptConfig {
    if smoke {
        OptConfig::test_config()
    } else {
        OptConfig {
            name: "serve-bench".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ffn: 512,
            max_seq: 128,
        }
    }
}

fn build_packed(w: &Weights, scheme: QuantScheme) -> PackedModel {
    let packed: Vec<(String, PackedTensor)> = w
        .quant_names()
        .iter()
        .map(|n| (n.clone(), PackedTensor::pack(&quant::quantize(w.get(n), scheme))))
        .collect();
    PackedModel::new(w.clone(), packed)
}

/// Old serve path: re-forward the whole context for every generated token.
fn full_reforward_decode(w: &Weights, prompt: &[i32], gen: usize) -> (Vec<i32>, f64) {
    let mut seq = prompt.to_vec();
    let t0 = Instant::now();
    for _ in 0..gen {
        let toks = vec![seq.clone()];
        let tgts = vec![vec![0i32; seq.len()]];
        let mask = vec![vec![0f32; seq.len()]];
        let out = native::forward(
            w,
            &toks,
            &tgts,
            &mask,
            Capture { last_logits: true, ..Default::default() },
        );
        let next = invarexplore::util::sampling::argmax(&out.last_logits[0]) as i32;
        seq.push(next);
    }
    let secs = t0.elapsed().as_secs_f64();
    (seq[prompt.len()..].to_vec(), gen as f64 / secs)
}

/// New serve path: prefill once, then one KV-cache step per token.
fn kv_cache_decode<P: native::DecoderParams>(
    p: &P,
    prompt: &[i32],
    gen: usize,
) -> (Vec<i32>, f64) {
    let mut cache = KvCache::new(p.config());
    let t0 = Instant::now();
    let mut logits = native::prefill(p, &mut cache, prompt);
    let mut out = Vec::with_capacity(gen);
    for _ in 0..gen {
        let next = invarexplore::util::sampling::argmax(&logits) as i32;
        out.push(next);
        if out.len() == gen {
            break;
        }
        logits = native::decode_step(p, &mut cache, next);
    }
    let secs = t0.elapsed().as_secs_f64();
    (out, gen as f64 / secs)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SERVE_DECODE_SMOKE").as_deref() == Ok("1");
    let cfg = bench_config(smoke);
    let w = Weights::random(cfg.clone(), 1);
    let scheme = QuantScheme::new(2, 32);
    let pm = build_packed(&w, scheme);
    let dense = pm.unpacked_weights();
    println!(
        "== serve_decode: {} (d={}, L={}, packed {:.3} bits/param{}) ==",
        cfg.name,
        cfg.d_model,
        cfg.n_layers,
        pm.bits_per_param(),
        if smoke { ", SMOKE" } else { "" }
    );

    // ---- parity pins (always, cheap) --------------------------------------
    let mut rng = Pcg64::new(7);
    let prompt: Vec<i32> = (0..8).map(|_| rng.below(cfg.vocab) as i32).collect();
    {
        // fused packed GEMV == dense GEMV over unpack()
        let p_down = PackedTensor::pack(&quant::quantize(w.get("l0.down.w"), scheme));
        let x = Tensor::from_vec(
            1,
            cfg.d_ffn,
            (0..cfg.d_ffn).map(|_| rng.normal() as f32).collect(),
        );
        let bias = vec![0.0f32; cfg.d_model];
        let fused = p_down.linear(&x, &bias);
        let ref_out = ops::linear(&x, &p_down.unpack(), &bias);
        assert_eq!(fused.data, ref_out.data, "fused GEMV must be bit-identical");
        // KV-cache first-step logits == full re-forward logits
        let mut cache = KvCache::new(&cfg);
        let kv_logits = native::prefill(&dense, &mut cache, &prompt);
        let toks = vec![prompt.clone()];
        let tgts = vec![vec![0i32; prompt.len()]];
        let mask = vec![vec![0f32; prompt.len()]];
        let full = native::forward(
            &dense,
            &toks,
            &tgts,
            &mask,
            Capture { last_logits: true, ..Default::default() },
        );
        for (a, b) in kv_logits.iter().zip(&full.last_logits[0]) {
            assert!((a - b).abs() < 5e-3, "KV prefill diverged from full forward: {a} vs {b}");
        }
        println!("parity: fused GEMV bit-identical; KV prefill matches full forward");
    }

    // smoke = 2 tokens: the first samples at prefill time, the second goes
    // through exactly one decode_step, so the KV path is really exercised
    let gen = if smoke { 2 } else { 32 };

    // ---- GEMV: fused packed vs unpack-to-dense ----------------------------
    // smoke still measures real rows (tiny per-case budget) so the
    // BENCH_serve_decode.json trajectory CI uploads is never empty
    if smoke {
        bench::smoke_budget_ms(60);
    }
    let mut suite = BenchSuite::new("serve_decode");
    let p_down = PackedTensor::pack(&quant::quantize(w.get("l0.down.w"), scheme));
    let x = Tensor::from_vec(1, cfg.d_ffn, (0..cfg.d_ffn).map(|_| rng.normal() as f32).collect());
    let bias = vec![0.0f32; cfg.d_model];
    suite.bench("fused packed GEMV (down.w)", || {
        std::hint::black_box(p_down.linear(&x, &bias));
    });
    suite.bench("unpack-to-dense GEMV (down.w)", || {
        let d = p_down.unpack();
        std::hint::black_box(ops::linear(&x, &d, &bias));
    });
    // multi-row GEMM (the chunked-verify shape): one cache-blocked call that
    // dequantizes each weight tile once for all k rows, vs k fused GEMVs
    let k_rows = 4;
    let xk = Tensor::from_vec(
        k_rows,
        cfg.d_ffn,
        (0..k_rows * cfg.d_ffn).map(|_| rng.normal() as f32).collect(),
    );
    let row_views: Vec<Tensor> = (0..k_rows)
        .map(|r| {
            Tensor::from_vec(1, cfg.d_ffn, xk.data[r * cfg.d_ffn..(r + 1) * cfg.d_ffn].to_vec())
        })
        .collect();
    suite.bench("blocked packed GEMM k=4 (down.w)", || {
        std::hint::black_box(p_down.linear_batch(&xk, &bias));
    });
    suite.bench("4x fused packed GEMV (down.w)", || {
        for row in &row_views {
            std::hint::black_box(p_down.linear(row, &bias));
        }
    });
    // pin: the blocked path is bit-identical to the row-at-a-time path
    let batched = p_down.linear_batch(&xk, &bias);
    for (r, row) in row_views.iter().enumerate() {
        let single = p_down.linear(row, &bias);
        assert_eq!(
            batched.data[r * cfg.d_model..(r + 1) * cfg.d_model],
            single.data[..],
            "blocked GEMM row {r} diverged from the fused GEMV"
        );
    }

    // ---- decode: KV cache vs full-context re-forward ----------------------
    let (kv_toks, kv_rate) = kv_cache_decode(&dense, &prompt, gen);
    let (full_toks, full_rate) = full_reforward_decode(&dense, &prompt, gen);
    println!(
        "decode (dense weights, greedy, {gen} tokens): KV cache {kv_rate:.1} tok/s \
         vs full re-forward {full_rate:.1} tok/s ({:.2}x)",
        kv_rate / full_rate
    );
    if kv_toks != full_toks {
        // near-tie argmax flips are possible in f32; report, don't fail
        println!("note: token streams diverged (f32 near-ties): {kv_toks:?} vs {full_toks:?}");
    }
    let (_, packed_rate) = kv_cache_decode(&pm, &prompt, gen);
    println!("decode (packed-direct, greedy, {gen} tokens): {packed_rate:.1} tok/s");
    let per_tok = |rate: f64| {
        Stats::one_shot(std::time::Duration::from_secs_f64(1.0 / rate.max(1e-9)))
    };
    suite.record("KV-cache decode (per token, dense)", per_tok(kv_rate));
    suite.record("full re-forward decode (per token, dense)", per_tok(full_rate));
    suite.record("KV-cache decode (per token, packed-direct)", per_tok(packed_rate));

    // ---- end-to-end batched serving on the packed model -------------------
    let mut server = Server::new(&pm, ServeOpts { max_batch: 4, seed: 0, ..Default::default() });
    for i in 0..4 {
        let start = rng.below(64);
        let prompt: Vec<i32> =
            (start..start + 8).map(|t| (t % cfg.vocab) as i32).collect();
        server.submit(Request::new(i, prompt, gen, Sampler::Greedy));
    }
    let (done, stats) = server.run();
    assert_eq!(done.len(), 4);
    println!("server (packed, batch 4): {}", stats.summary());

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("perf trajectory written to {}", out.display());
}

//! Reproduces **Table 2** (transform ablation): AWQ + the largest model,
//! with permutation / scaling / rotation alone and combined.
//!
//! Shape claims: every family alone beats the AWQ baseline; combining all
//! three is best; scaling adds least on top of AWQ (which already scales).

use invarexplore::coordinator::{tables, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let out = tables::table2(
        &session,
        "opt-base",
        QuantScheme::new(1, 64),
        step_budget(250),
        50,
        0,
    )?;
    println!("{out}");
    println!("(CSV in results/table2_ablation.csv)");
    Ok(())
}

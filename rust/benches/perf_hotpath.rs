//! Performance benchmark of the whole stack's hot paths (EXPERIMENTS.md
//! §Perf): the batched proposal engine (proposals/sec at K ∈ {1, 4, 8}),
//! quant codecs, transforms, GPTQ re-quantization, XLA pipeline stages,
//! incremental vs full evaluation, and end-to-end search-step throughput
//! per model size and per base method.
//!
//! The batched-engine section runs on the synthetic objective and needs no
//! artifacts; the XLA sections are skipped when `artifacts/` is absent.
//!
//! `INVAREXPLORE_BENCH_MS` bounds the per-case measurement budget;
//! `INVAREXPLORE_STEPS` bounds the proposal counts.

use invarexplore::baselines::Method;
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::{PipelineOpts, SearchRun, Session};
use invarexplore::quant::{self, QuantScheme};
use invarexplore::runtime::Engine;
use invarexplore::search::hillclimb::SearchConfig;
use invarexplore::search::{self, Objective, SearchState, SynthObjective};
use invarexplore::tensor::Tensor;
use invarexplore::transform::{LayerTransform, TransformKinds};
use invarexplore::util::bench::{step_budget, BenchSuite};
use invarexplore::util::pool;
use invarexplore::util::rng::Pcg64;

/// Proposals/sec of the round engine on the synthetic objective for one K.
fn synth_proposals_per_sec(k: usize, steps: usize) -> f64 {
    // draft cost sized like a sandbox-scale FFN re-quantization (two
    // 320x1280 matrices), the work a round fans out across the pool
    let mut obj = SynthObjective::with_draft_work(16, 64, 2 * 320 * 1280);
    let mut state = SearchState::new(16, 64, 0);
    let cfg = SearchConfig {
        kinds: TransformKinds::parse("s").unwrap(),
        frac: 0.2,
        sigma_s: 0.1,
        sigma_r: 0.0,
        alpha: Some(0.0),
        log_every: 0,
        batch: k,
        p_alloc: 0.0,
    };
    search::hillclimb::ensure_init(&mut obj, &mut state, &cfg).unwrap();
    let t0 = std::time::Instant::now();
    search::run(&mut obj, &mut state, &cfg, steps).unwrap();
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn bench_batched_engine() {
    println!("== batched proposal engine (synthetic objective) ==");
    println!("   threads = {}", pool::num_threads());
    let steps = step_budget(96);
    let base = synth_proposals_per_sec(1, steps);
    println!("  K=1: {base:8.1} proposals/sec (sequential semantics)");
    for k in [4, 8] {
        let rate = synth_proposals_per_sec(k, steps);
        println!("  K={k}: {rate:8.1} proposals/sec ({:.2}x vs K=1)", rate / base);
    }
}

fn main() -> anyhow::Result<()> {
    // ---- round-based batched proposal engine (no artifacts needed) ---------
    bench_batched_engine();

    let session = match Session::load_default() {
        Ok(s) => s,
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`) — XLA sections skipped");
            return Ok(());
        }
    };
    let mut suite = BenchSuite::new("perf_hotpath");
    let mut rng = Pcg64::new(0);

    // ---- L3 host kernels ---------------------------------------------------
    println!("== L3 host kernels ==");
    let scheme = QuantScheme::new(1, 64);
    let w_down = Tensor::from_vec(320, 1280, (0..320 * 1280).map(|_| rng.normal() as f32).collect());
    let mut out = Tensor::zeros(320, 1280);
    suite.bench("fake_quant_into 320x1280 (RTN codec)", || {
        quant::fake_quant_into(&w_down, scheme, &mut out);
    });
    suite.bench("clip-search quant 320x1280 (AWQ codec)", || {
        std::hint::black_box(quant::clip::fake_quant_clip_search(
            &w_down,
            scheme,
            &quant::clip::AWQ_CLIP_GRID,
        ));
    });
    let t = {
        let mut t = LayerTransform::identity(1280);
        t = t.propose(&mut rng, TransformKinds::all(), 0.1, 1e-2, 1e-5);
        t
    };
    let w_up = Tensor::from_vec(1280, 320, (0..320 * 1280).map(|_| rng.normal() as f32).collect());
    let b_up = Tensor::from_vec(1, 1280, vec![0.0; 1280]);
    suite.bench("apply PSR transform to FFN tensors (opt-base)", || {
        std::hint::black_box(invarexplore::transform::apply_to_tensors(&t, &w_up, &b_up, &w_down));
    });

    // GPTQ blocked requant with transformed hessian (the per-proposal cost)
    let x = Tensor::from_vec(512, 1280, (0..512 * 1280).map(|_| rng.normal() as f32).collect());
    let h = invarexplore::calib::hessian(&x, 0.01);
    suite.bench("GPTQ blocked requant 320x1280 + H-transform", || {
        std::hint::black_box(invarexplore::baselines::gptq::gptq_quantize(
            &w_down,
            &h,
            scheme,
            false,
            Some(&t),
        ));
    });

    // ---- runtime stages ------------------------------------------------------
    println!("== XLA runtime stages (opt-base) ==");
    let model = "opt-base";
    let w = session.weights(model)?;
    let mut engine = Engine::load(&session.manifest, model)?;
    engine.upload_weights(&w)?;
    let pile = session.corpus("pile")?;
    let cs = CalibSet::from_corpus(&pile, 8, session.manifest.seq);
    let batch = engine.upload_batch(&cs.tokens, &cs.targets, &cs.masks)?;

    suite.bench("upload FFN tensor 320x1280 to device", || {
        engine.update_tensor("l0.down.w", &w_down).unwrap();
    });
    suite.bench("device Pallas fake-quant 320x1280", || {
        std::hint::black_box(engine.device_fake_quant(&w_down, scheme).unwrap());
    });
    let x0 = engine.embed(&batch)?;
    suite.bench("embed (B=8, T=128)", || {
        std::hint::black_box(engine.embed(&batch).unwrap());
    });
    suite.bench("one decoder layer (B=8, T=128, d=320)", || {
        std::hint::black_box(engine.run_layer(0, &x0).unwrap());
    });
    suite.bench("head: CE + seq logprob", || {
        std::hint::black_box(engine.run_head(&x0, &batch).unwrap());
    });
    engine.update_tensor("l0.down.w", w.get("l0.down.w"))?; // restore

    // ---- incremental vs full evaluation --------------------------------------
    println!("== evaluator ==");
    for method in [Method::Rtn, Method::Awq] {
        let mut opts = PipelineOpts::new(model, method, scheme);
        opts.calib_seqs = 32;
        let mut run = SearchRun::build(&session, &opts)?;
        run.init()?;
        let n_layers = run.obj.n_layers();

        // probe evals at the two extremes of the prefix cache
        let label_full = format!("{}: proposal at layer 0 (full re-run)", method.name());
        let label_last = format!("{}: proposal at last layer (prefix cache)", method.name());
        let mut try_at = |l: usize, label: &str, suite: &mut BenchSuite| {
            let proposal = run.state.transforms[l].propose(
                &mut run.state.rng,
                TransformKinds::all(),
                0.1,
                1e-2,
                1e-5,
            );
            suite.bench(label, || {
                let _ = search::probe(&mut run.obj, l, &proposal).unwrap();
            });
        };
        try_at(0, &label_full, &mut suite);
        try_at(n_layers - 1, &label_last, &mut suite);

        // end-to-end search-step throughput, sequential and batched rounds
        for k in [1usize, 4, 8] {
            run.cfg.batch = k;
            let stats = suite.bench(
                &format!("{}: full search step (batch K={k})", method.name()),
                || {
                    run.steps(k).unwrap();
                },
            );
            println!(
                "    -> {:.1} proposals/sec ({}, K={k})",
                stats.per_sec() * k as f64,
                method.name()
            );
        }
        run.cfg.batch = 1;
    }

    println!("\n{}", suite.report());
    Ok(())
}

//! Trace-replay load benchmark for the multi-replica router and the
//! tensor-parallel sharded model.
//!
//! A synthetic but production-shaped trace drives everything:
//!
//! * **Zipf prompt lengths and prefix popularity** — a handful of shared
//!   system prompts with Zipf-distributed popularity (a few prompts
//!   dominate, as in real serving), plus Zipf-tailed per-request suffixes;
//! * **MMPP arrivals** — a two-state Markov-modulated Poisson process
//!   (calm / burst) decides how many requests arrive in each replay wave,
//!   so queue depth swings the way bursty traffic swings it.
//!
//! The trace is replayed against replica counts {1, 2, 4}, reporting
//! goodput (generated tokens / s), shed rate, and per-replica TTFT and
//! inter-token-latency p50/p95/p99.  A saturated segment replays the same
//! burst against a small admission watermark to exercise load shedding.
//!
//! Invariants asserted (always — this is what CI `--smoke` pins):
//!
//! 1. non-shed completions are **bit-identical** across replica counts,
//!    prefix cache on/off, and shard counts {1, 2, 4} of the packed model;
//! 2. the overload segment sheds (`shed > 0`), every shed request finishes
//!    as `Rejected`, and nothing panics or hangs;
//! 3. admission-capacity goodput with 4 replicas is **strictly above**
//!    single-replica goodput on the same overloaded trace (count-based:
//!    per-replica watermarks admit ~4x the requests, independent of
//!    machine speed).
//!
//! Runs entirely on a synthetic random model — no artifacts needed.
//! `--smoke` (or env `SERVE_TRACE_REPLAY_SMOKE=1`) shrinks the trace and
//! exits after the assertions — wired into CI.

use std::time::Instant;

use invarexplore::model::{OptConfig, Weights};
use invarexplore::quant::{BitAllocation, QuantScheme};
use invarexplore::serve::{
    Completion, FinishReason, PackedModel, Request, Router, RouterOpts, Scheduler, ServeOpts,
    ShardedModel,
};
use invarexplore::util::bench::{BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn bench_config(smoke: bool) -> OptConfig {
    if smoke {
        OptConfig::test_config()
    } else {
        OptConfig {
            name: "trace-replay".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ffn: 512,
            max_seq: 128,
        }
    }
}

/// Zipf(s)-distributed rank in `1..=n` via inverse-CDF over the exact
/// (small-n) normalization.
fn zipf(rng: &mut Pcg64, n: usize, s: f64) -> usize {
    let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
    let mut u = rng.uniform() * norm;
    for k in 1..=n {
        u -= (k as f64).powf(-s);
        if u <= 0.0 {
            return k;
        }
    }
    n
}

/// Knuth Poisson sampler (λ small enough for the product method).
fn poisson(rng: &mut Pcg64, lambda: f64) -> usize {
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.uniform();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// One request spec: `(id, prompt, max_new)`.
type Spec = (usize, Vec<i32>, usize);

/// The replay trace: requests grouped into arrival waves.
struct Trace {
    waves: Vec<Vec<Spec>>,
    total: usize,
}

/// Build the trace: `n_waves` MMPP arrival waves over `families` shared
/// system prompts with Zipf popularity and Zipf-tailed suffix lengths.
fn build_trace(cfg: &OptConfig, n_waves: usize, families: usize, seed: u64) -> Trace {
    let mut rng = Pcg64::new(seed);
    let shared_len = cfg.max_seq / 4;
    let prefixes: Vec<Vec<i32>> = (0..families)
        .map(|_| (0..shared_len).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect();
    // two-state MMPP: calm vs burst arrival intensity, sticky transitions
    let (lambda_calm, lambda_burst) = (2.0, 6.0);
    let mut burst = false;
    let mut id = 0usize;
    let max_suffix = cfg.max_seq / 4;
    let mut waves = Vec::with_capacity(n_waves);
    for _ in 0..n_waves {
        if rng.uniform() < if burst { 0.4 } else { 0.25 } {
            burst = !burst;
        }
        let lambda = if burst { lambda_burst } else { lambda_calm };
        let arrivals = 1 + poisson(&mut rng, lambda);
        let mut wave = Vec::with_capacity(arrivals);
        for _ in 0..arrivals {
            // popular system prompts dominate (Zipf rank -> family index)
            let fam = zipf(&mut rng, families, 1.2) - 1;
            let mut prompt = prefixes[fam].clone();
            let suffix = zipf(&mut rng, max_suffix, 1.1);
            prompt.extend((0..suffix).map(|_| rng.below(cfg.vocab) as i32));
            let max_new = 1 + zipf(&mut rng, (cfg.max_seq / 8).max(2), 1.1);
            wave.push((id, prompt, max_new));
            id += 1;
        }
        waves.push(wave);
    }
    Trace { waves, total: id }
}

fn request_of(spec: &Spec) -> Request {
    let sampler = if spec.0 % 2 == 0 {
        Sampler::Greedy
    } else {
        Sampler::TopK { k: 4, temperature: 0.9 }
    };
    Request::new(spec.0, spec.1.clone(), spec.2, sampler)
}

/// Replay the whole trace through a router, one `run` per arrival wave.
fn replay(
    router: &mut Router<'_, PackedModel>,
    trace: &Trace,
) -> (Vec<Completion>, invarexplore::serve::RouterStats) {
    let mut done = Vec::with_capacity(trace.total);
    let mut stats = Default::default();
    for wave in &trace.waves {
        for spec in wave {
            router.submit(request_of(spec));
        }
        let (d, s) = router.run();
        done.extend(d);
        stats = s;
    }
    done.sort_by_key(|c| c.id);
    (done, stats)
}

fn is_shed(c: &Completion) -> bool {
    matches!(c.finish, FinishReason::Rejected(_))
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SERVE_TRACE_REPLAY_SMOKE").as_deref() == Ok("1");
    let cfg = bench_config(smoke);
    let w = Weights::random(cfg.clone(), 1);
    let pm = PackedModel::from_allocation(w, &BitAllocation::uniform(QuantScheme::new(2, 32)))
        .expect("packed model builds");
    let (n_waves, families) = if smoke { (4, 3) } else { (16, 5) };
    let trace = build_trace(&cfg, n_waves, families, 42);
    println!(
        "== serve_trace_replay: {} (d={}, L={}, {} requests over {} MMPP waves, \
         {} system prompts{}) ==",
        cfg.name,
        cfg.d_model,
        cfg.n_layers,
        trace.total,
        trace.waves.len(),
        families,
        if smoke { ", SMOKE" } else { "" }
    );
    let mut suite = BenchSuite::new("serve_trace_replay");

    // ---- replay across replica counts: goodput + latency quantiles --------
    let serve = ServeOpts { max_batch: 4, prefix_cache: true, ..Default::default() };
    let mut reference: Option<Vec<Completion>> = None;
    for replicas in [1usize, 2, 4] {
        let opts = RouterOpts { replicas, affinity_tokens: cfg.max_seq / 4, ..Default::default() };
        let mut router = Router::new(&pm, opts, serve);
        let t0 = Instant::now();
        let (done, stats) = replay(&mut router, &trace);
        let wall = t0.elapsed();
        assert_eq!(done.len(), trace.total);
        assert_eq!(stats.shed, 0, "unbounded watermark must not shed");
        let tokens: usize = done.iter().map(|c| c.generated.len()).sum();
        let goodput = tokens as f64 / wall.as_secs_f64().max(1e-9);
        suite.record(
            &format!("replay wall time, {replicas} replica(s)"),
            Stats::one_shot(wall),
        );
        suite.set_counter(&format!("goodput_tok_per_s_r{replicas}"), goodput);
        println!(
            "replicas={replicas}: {} tokens in {wall:.1?} ({goodput:.1} tok/s), \
             routing affinity/balanced {}/{}",
            tokens, stats.affinity_routed, stats.balanced,
        );
        let agg = router.aggregate_metrics();
        for r in 0..replicas {
            let m = router.replica_metrics(r);
            println!(
                "  replica {r}: ttft p50 {:?} p95 {:?} p99 {:?} | itl p50 {:?} p95 {:?} p99 {:?} \
                 ({} finished)",
                m.ttft.quantile(0.5),
                m.ttft.quantile(0.95),
                m.ttft.quantile(0.99),
                m.inter_token.quantile(0.5),
                m.inter_token.quantile(0.95),
                m.inter_token.quantile(0.99),
                m.finished_length + m.finished_stop,
            );
        }
        suite.set_counter(
            &format!("ttft_p95_us_r{replicas}"),
            agg.ttft.quantile(0.95).as_micros() as f64,
        );
        suite.set_counter(
            &format!("itl_p95_us_r{replicas}"),
            agg.inter_token.quantile(0.95).as_micros() as f64,
        );
        // bit-identity: the same trace yields the same completions
        // regardless of how many replicas served it
        match &reference {
            None => reference = Some(done),
            Some(want) => assert_eq!(
                &done, want,
                "completions diverged between 1 and {replicas} replicas"
            ),
        }
    }
    let reference = reference.take().unwrap_or_default();

    // prefix cache off must not change completions either
    {
        let plain = ServeOpts { prefix_cache: false, ..serve };
        let mut router = Router::new(&pm, RouterOpts::default(), plain);
        let (done, _) = replay(&mut router, &trace);
        assert_eq!(done, reference, "completions diverged with prefix cache off");
    }

    // ---- sharded model: shards x {1,2,4} bit-identical to unsharded -------
    for shards in [1usize, 2, 4] {
        let sm = ShardedModel::new(&pm, shards);
        let mut sched = Scheduler::new(&sm, serve);
        let t0 = Instant::now();
        let mut done = Vec::with_capacity(trace.total);
        for wave in &trace.waves {
            for spec in wave {
                sched.submit(request_of(spec));
            }
            let (d, _) = sched.run();
            done.extend(d);
        }
        let wall = t0.elapsed();
        done.sort_by_key(|c| c.id);
        assert_eq!(
            done, reference,
            "sharded ({shards}) completions diverged from single-replica reference"
        );
        suite.record(&format!("replay wall time, {shards} shard(s)"), Stats::one_shot(wall));
        println!("shards={shards}: bit-identical to unsharded reference ({wall:.1?})");
    }

    // ---- overload segment: watermark-bound admission, shedding, goodput ---
    // One giant wave (every request at once) against a small per-replica
    // watermark: 1 replica admits ~watermark requests, 4 replicas ~4x.
    // Goodput is counted in completed (non-shed) requests, so the 4-replica
    // win is a property of admission capacity, not machine speed.
    let watermark = (trace.total / 6).max(2);
    let mut served_by: Vec<(usize, usize, usize)> = Vec::new();
    for replicas in [1usize, 4] {
        let opts = RouterOpts {
            replicas,
            shed_watermark: watermark,
            affinity_tokens: cfg.max_seq / 4,
            ..Default::default()
        };
        let mut router = Router::new(&pm, opts, serve);
        for wave in &trace.waves {
            for spec in wave {
                router.submit(request_of(spec));
            }
        }
        let (done, stats) = router.run();
        assert_eq!(done.len(), trace.total, "every request completes, shed included");
        let shed = done.iter().filter(|c| is_shed(c)).count();
        let served = trace.total - shed;
        assert_eq!(shed, stats.shed, "router stats agree with Rejected completions");
        assert!(stats.shed > 0, "overload segment must shed (watermark {watermark})");
        for c in done.iter().filter(|c| is_shed(c)) {
            assert!(c.generated.is_empty(), "shed request {} generated tokens", c.id);
        }
        // non-shed completions still bit-identical to the unbounded run
        for c in done.iter().filter(|c| !is_shed(c)) {
            assert_eq!(c, &reference[c.id], "overload run diverged on served request {}", c.id);
        }
        println!(
            "overload replicas={replicas}: served {served}/{} ({} shed, rate {:.2})",
            trace.total,
            stats.shed,
            stats.shed_rate(),
        );
        suite.set_counter(&format!("overload_served_r{replicas}"), served as f64);
        suite.set_counter(&format!("overload_shed_rate_r{replicas}"), stats.shed_rate());
        served_by.push((replicas, served, shed));
    }
    let (_, served_1, _) = served_by[0];
    let (_, served_4, _) = served_by[1];
    assert!(
        served_4 > served_1,
        "4-replica goodput ({served_4} served) must strictly beat 1 replica ({served_1})"
    );
    println!(
        "ok: completions replica/shard/prefix-invariant; overload sheds cleanly; \
         4-replica admission goodput {served_4} > single-replica {served_1}"
    );

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("perf trajectory written to {}", out.display());
}

//! Reproduces **Table 1** (main results): {RTN, GPTQ, AWQ, OmniQuant} ±
//! InvarExplore across the three model sizes, on WikiText/C4-analog
//! perplexity and six-task reasoning accuracy.
//!
//! Shape claims under reproduction (paper §4.2): RTN worst; calibrated
//! methods better; +InvarExplore improves every method; improvements shrink
//! as the base method gets stronger; trends consistent across model sizes.
//!
//! Scale: `INVAREXPLORE_STEPS` (default 250), `INVAREXPLORE_FULL=1` → 10K.

use invarexplore::baselines::Method;
use invarexplore::coordinator::{tables, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let t1 = tables::Table1Opts {
        models: session.manifest.model_names().iter().map(|s| s.to_string()).collect(),
        methods: vec![Method::Rtn, Method::Gptq, Method::Awq, Method::OmniQuant],
        scheme: QuantScheme::new(1, 64),
        steps: step_budget(250),
        reasoning_n: 50,
        seed: 0,
    };
    let t0 = std::time::Instant::now();
    let out = tables::table1(&session, &t1)?;
    println!("{out}");
    println!("(table1 regenerated in {:?}; CSV in results/table1_main.csv)", t0.elapsed());
    Ok(())
}

//! Mixed-precision bit-allocation benchmarks — search convergence and
//! heterogeneous packed serving:
//!
//! 1. **search convergence at a fixed budget** — on the synthetic
//!    mixed-precision objective, a transform-only search at the uniform
//!    2x64 allocation vs the same search continued with budget-preserving
//!    bit-swap moves (`p_alloc`); the searched allocation must reach a
//!    strictly lower CE at the same (or lower) bits/param;
//! 2. **heterogeneous packed decode** — tok/s of the fused packed serving
//!    path across allocations (uniform 2-bit, mixed 1..4-bit), plus the
//!    bit-identity pin of mixed packed serving vs unpack-then-dense.
//!
//! Runs entirely on synthetic models — no artifacts needed.  `--smoke` (or
//! env `MIXED_PRECISION_SMOKE=1`) shrinks the workload and asserts the
//! acceptance criteria; wired into CI.  `BENCH_mixed_precision.json` is
//! written on every run (the perf-trajectory artifact CI uploads).

use std::time::Instant;

use invarexplore::model::native::{self, KvCache};
use invarexplore::model::{OptConfig, Weights};
use invarexplore::quant::{BitAllocation, QuantScheme};
use invarexplore::search::hillclimb::SearchConfig;
use invarexplore::search::{self, MixedSynthObjective, SearchState};
use invarexplore::serve::PackedModel;
use invarexplore::transform::TransformKinds;
use invarexplore::util::bench::{self, step_budget, BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;

fn search_cfg(p_alloc: f64) -> SearchConfig {
    SearchConfig {
        kinds: TransformKinds::parse("s").unwrap(),
        frac: 0.2,
        sigma_s: 0.1,
        sigma_r: 0.0,
        alpha: Some(0.0),
        log_every: 0,
        batch: 4,
        p_alloc,
    }
}

/// Transform-only search, then continue the SAME state with bit-swap moves
/// mixed in.  Returns (uniform-CE, mixed-CE, budget, final bits/param,
/// accepted swaps, objective).
fn convergence(
    steps: usize,
    seed: u64,
) -> (f64, f64, f64, f64, usize, MixedSynthObjective) {
    let scheme = QuantScheme::new(2, 64);
    let mut obj = MixedSynthObjective::new(8, 16, scheme);
    let alloc = obj.alloc_state();
    let budget = alloc.budget;
    let mut state = SearchState::new(8, 16, seed).with_alloc(alloc);

    // phase 1: transforms only — the uniform-allocation reference
    search::run(&mut obj, &mut state, &search_cfg(0.0), steps).unwrap();
    let uniform_ce = state.best.ce;

    // phase 2: same budget, same engine, allocation moves enabled
    search::run(&mut obj, &mut state, &search_cfg(0.5), steps).unwrap();
    let mixed_ce = state.best.ce;
    let final_bpp = state.alloc.as_ref().unwrap().bits_per_param();
    (uniform_ce, mixed_ce, budget, final_bpp, state.alloc_accepts, obj)
}

/// tok/s of greedy packed-direct decoding under one allocation.
fn packed_decode_rate(w: &Weights, alloc: &BitAllocation, gen: usize) -> (PackedModel, f64) {
    let pm = PackedModel::from_allocation(w.clone(), alloc).unwrap();
    let mut rng = Pcg64::new(11);
    let prompt: Vec<i32> = (0..8).map(|_| rng.below(w.config.vocab) as i32).collect();
    let mut cache = KvCache::new(pm.config());
    let t0 = Instant::now();
    let mut logits = native::prefill(&pm, &mut cache, &prompt);
    for _ in 1..gen {
        let next = invarexplore::util::sampling::argmax(&logits) as i32;
        logits = native::decode_step(&pm, &mut cache, next);
    }
    let rate = gen as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    (pm, rate)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MIXED_PRECISION_SMOKE").as_deref() == Ok("1");
    if smoke {
        bench::smoke_budget_ms(60);
    }
    let mut suite = BenchSuite::new("mixed_precision");
    println!("== mixed_precision{} ==", if smoke { " (SMOKE)" } else { "" });

    // ---- 1. search convergence: uniform vs searched allocation ------------
    let steps = step_budget(if smoke { 160 } else { 600 });
    let t0 = Instant::now();
    let (uniform_ce, mixed_ce, budget, final_bpp, swaps, obj) = convergence(steps, 7);
    let search_time = t0.elapsed();
    println!(
        "search ({steps}+{steps} steps): uniform 2x64 CE {uniform_ce:.4} -> searched \
         allocation CE {mixed_ce:.4} ({swaps} bit swaps accepted, \
         {final_bpp:.3} bits/param vs budget {budget:.3})"
    );
    suite.record(
        "mixed search step (phase-2 wall clock)",
        Stats::one_shot(search_time / (2 * steps).max(1) as u32),
    );

    // the tentpole acceptance pin: at the same or lower bits/param budget,
    // the searched mixed allocation beats uniform 2x64 STRICTLY
    assert!(
        final_bpp <= budget + 1e-9,
        "searched allocation exceeded budget: {final_bpp} > {budget}"
    );
    assert!(swaps >= 1, "search never accepted a bit swap");
    assert!(
        mixed_ce < uniform_ce,
        "searched allocation must strictly beat uniform: {mixed_ce} vs {uniform_ce}"
    );
    assert!(
        obj.alloc_term() < obj.uniform_alloc_term(),
        "allocation error must drop below the uniform reference"
    );
    println!("ok: searched allocation strictly beats uniform 2x64 at the same budget");

    // ---- 2. heterogeneous packed decode -----------------------------------
    let cfg = if smoke {
        OptConfig::test_config()
    } else {
        OptConfig {
            name: "mixed-bench".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ffn: 512,
            max_seq: 128,
        }
    };
    let w = Weights::random(cfg.clone(), 1);
    let gen = if smoke { 2 } else { 32 };
    let allocs = [
        ("uniform 2-bit", BitAllocation::parse("2x32").unwrap()),
        (
            "mixed 1..4-bit",
            BitAllocation::parse("2x32,ffn_up=4x32,ffn_down=1x32,attn_q=3x32").unwrap(),
        ),
    ];
    for (label, alloc) in &allocs {
        let (pm, rate) = packed_decode_rate(&w, alloc, gen);
        println!(
            "decode ({label}, {}, {:.3} bits/param): {rate:.1} tok/s",
            pm.bits_summary(),
            pm.bits_per_param()
        );
        suite.record(
            &format!("packed decode per token ({label})"),
            Stats::one_shot(std::time::Duration::from_secs_f64(1.0 / rate.max(1e-9))),
        );
    }

    // bit-identity pin: mixed packed serving == unpack-then-dense serving
    let (pm, _) = packed_decode_rate(&w, &allocs[1].1, 2);
    let dense = pm.unpacked_weights();
    let mut rng = Pcg64::new(3);
    let prompt: Vec<i32> = (0..8).map(|_| rng.below(cfg.vocab) as i32).collect();
    let mut c1 = KvCache::new(pm.config());
    let mut c2 = KvCache::new(&dense.config);
    let l1 = native::prefill(&pm, &mut c1, &prompt);
    let l2 = native::prefill(&dense, &mut c2, &prompt);
    assert_eq!(l1, l2, "mixed packed prefill must be bit-identical to dense");
    for t in [1i32, 5] {
        let d1 = native::decode_step(&pm, &mut c1, t);
        let d2 = native::decode_step(&dense, &mut c2, t);
        assert_eq!(d1, d2, "mixed packed decode must be bit-identical to dense");
    }
    println!("ok: mixed-precision packed serving bit-identical to unpack-then-dense");

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("perf trajectory written to {}", out.display());
}

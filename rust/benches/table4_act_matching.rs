//! Reproduces **Table 4** (activation-matching layer count): 0 / 1 / L/2 /
//! L matched layers, with the measured H₀ memory column.
//!
//! Shape claims: more matched layers generally help; 0 layers (CE-only)
//! still beats the AWQ baseline with zero memory overhead.

use invarexplore::coordinator::{tables, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let out = tables::table4(
        &session,
        "opt-base",
        QuantScheme::new(1, 64),
        step_budget(200),
        50,
        0,
    )?;
    println!("{out}");
    println!("(CSV in results/table4_act_matching.csv)");
    Ok(())
}

//! Continuous-batching serving benchmarks on synthetic shared-prefix
//! traffic:
//!
//! 1. **continuous vs drain-loop throughput** — the continuous scheduler
//!    (mid-flight admission + radix-trie prefix cache) against the static
//!    baseline that processes the queue in fixed `max_batch` chunks with a
//!    full barrier between chunks (slots idle while each chunk's straggler
//!    finishes, and every prompt prefills from scratch);
//! 2. **TTFT** — submit→first-token p50/p95 from the serve metrics
//!    histograms (the drain baseline's numbers exclude inter-chunk queue
//!    wait, so they are a lower bound for it);
//! 3. **chunked vs eager KV residency** — peak unique live KV bytes under
//!    paged allocation vs what PR-2's eager `[max_seq, d_model]`-per-layer
//!    caches would have held resident at the same peak.
//!
//! Runs entirely on a synthetic random model — no artifacts needed.
//! `--smoke` (or env `SERVE_CONTINUOUS_SMOKE=1`) shrinks the workload to a
//! couple of decode rounds, asserts the determinism pin (continuous+prefix
//! completions == drained chunk completions) plus prefix-hit and
//! KV-residency invariants, and exits — wired into CI.

use std::time::Instant;

use invarexplore::model::native::KvDtype;
use invarexplore::model::{OptConfig, Weights};
use invarexplore::serve::{Completion, Request, Scheduler, ServeOpts};
use invarexplore::util::bench::{BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn bench_config(smoke: bool) -> OptConfig {
    if smoke {
        OptConfig::test_config()
    } else {
        OptConfig {
            name: "serve-bench".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ffn: 512,
            max_seq: 128,
        }
    }
}

type Spec = (usize, Vec<i32>, usize);

/// Shared-prefix traffic: `n_groups` groups of `per_group` requests; each
/// group shares a `shared_len`-token prompt prefix, and `max_new` varies
/// within a group so fixed chunks straggle.
fn traffic(
    cfg: &OptConfig,
    n_groups: usize,
    per_group: usize,
    prompt_len: usize,
    shared_len: usize,
    gen_max: usize,
    uniform_gen: bool,
) -> Vec<Spec> {
    let mut rng = Pcg64::new(3);
    let mut specs = Vec::new();
    for g in 0..n_groups {
        let shared: Vec<i32> = (0..shared_len).map(|_| rng.below(cfg.vocab) as i32).collect();
        for r in 0..per_group {
            let id = g * per_group + r;
            let mut p = shared.clone();
            p.extend((0..prompt_len - shared_len).map(|_| rng.below(cfg.vocab) as i32));
            let max_new = if uniform_gen { gen_max } else { 1 + id % gen_max };
            specs.push((id, p, max_new));
        }
    }
    specs
}

fn submit_all(s: &mut Scheduler<'_, Weights>, specs: &[Spec]) {
    for (id, p, m) in specs {
        s.submit(Request::new(*id, p.clone(), *m, Sampler::Greedy));
    }
}

fn total_generated(done: &[Completion]) -> usize {
    done.iter().map(|c| c.generated.len()).sum()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SERVE_CONTINUOUS_SMOKE").as_deref() == Ok("1");
    let cfg = bench_config(smoke);
    let w = Weights::random(cfg.clone(), 1);
    let max_batch = 4;
    let (n_groups, per_group) = if smoke { (2, 2) } else { (4, 6) };
    let prompt_len = if smoke { 8 } else { 48 };
    let gen_max = if smoke { 2 } else { 24 };
    let specs =
        traffic(&cfg, n_groups, per_group, prompt_len, prompt_len / 2, gen_max, smoke);
    println!(
        "== serve_continuous: {} (d={}, L={}, {} requests, {}-token prompts, \
         {}-token shared prefixes{}) ==",
        cfg.name,
        cfg.d_model,
        cfg.n_layers,
        specs.len(),
        prompt_len,
        prompt_len / 2,
        if smoke { ", SMOKE" } else { "" }
    );

    // ---- continuous: one scheduler, prefix cache on, mid-flight refill ----
    let mut cont = Scheduler::new(
        &w,
        ServeOpts { max_batch, prefix_cache: true, ..Default::default() },
    );
    let t0 = Instant::now();
    submit_all(&mut cont, &specs);
    let (cont_done, cont_stats) = cont.run();
    let cont_time = t0.elapsed();

    // ---- drain loop: fixed chunks with a barrier, no prefix reuse ---------
    let mut drain = Scheduler::new(&w, ServeOpts { max_batch, ..Default::default() });
    let mut drain_done: Vec<Completion> = Vec::new();
    let t0 = Instant::now();
    for chunk in specs.chunks(max_batch) {
        submit_all(&mut drain, chunk);
        let (done, _) = drain.run();
        drain_done.extend(done);
    }
    let drain_time = t0.elapsed();

    // ---- report -----------------------------------------------------------
    let cont_tok = total_generated(&cont_done);
    let drain_tok = total_generated(&drain_done);
    // per-token wall-clock of each strategy -> BENCH_serve_continuous.json
    // (the perf trajectory CI uploads on every run)
    let mut suite = BenchSuite::new("serve_continuous");
    let per_tok = |d: std::time::Duration, toks: usize| {
        Stats::one_shot(std::time::Duration::from_secs_f64(
            d.as_secs_f64() / toks.max(1) as f64,
        ))
    };
    suite.record("continuous scheduler (per generated token)", per_tok(cont_time, cont_tok));
    suite.record("drain-loop baseline (per generated token)", per_tok(drain_time, drain_tok));
    println!(
        "throughput: continuous {cont_tok} tokens in {cont_time:.1?} \
         ({:.1} tok/s) vs drain-loop {drain_tok} tokens in {drain_time:.1?} ({:.1} tok/s)",
        cont_tok as f64 / cont_time.as_secs_f64().max(1e-9),
        drain_tok as f64 / drain_time.as_secs_f64().max(1e-9),
    );
    let (cm, dm) = (cont.metrics(), drain.metrics());
    println!(
        "ttft: continuous p50 {:?} / p95 {:?} vs drain p50 {:?} / p95 {:?} \
         (drain excludes inter-chunk queue wait)",
        cm.ttft.quantile(0.5),
        cm.ttft.quantile(0.95),
        dm.ttft.quantile(0.5),
        dm.ttft.quantile(0.95),
    );
    println!(
        "prefix cache: {} / {} lookups hit, {} prompt tokens reused \
         ({} prefilled instead of {})",
        cm.prefix_hits,
        cm.prefix_lookups,
        cm.prefix_hit_tokens,
        cont_stats.prefill_tokens,
        cont_stats.prefill_tokens + cont_stats.prefix_hit_tokens,
    );
    println!(
        "kv residency (active sequences): chunked pages peak {} B vs eager \
         full-context {} B ({:.1}%); with prefix-trie retention: {} B",
        dm.kv_live_bytes_peak,
        dm.kv_eager_bytes_peak,
        100.0 * dm.kv_live_bytes_peak as f64 / dm.kv_eager_bytes_peak.max(1) as f64,
        cm.kv_live_bytes_peak,
    );

    // ---- invariants (always; this is what CI smoke pins) ------------------
    assert_eq!(cont_done.len(), specs.len());
    assert_eq!(drain_done.len(), specs.len());
    // determinism: per-request RNG streams make completions independent of
    // batching strategy and prefix caching
    for (a, b) in cont_done.iter().zip(&drain_done) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.generated, b.generated,
            "request {}: continuous vs drain completions diverged",
            a.id
        );
    }
    assert!(
        cm.prefix_hit_tokens > 0,
        "shared-prefix traffic must produce prefix-cache hits"
    );
    assert!(
        cont_stats.prefill_tokens < specs.iter().map(|(_, p, _)| p.len()).sum::<usize>(),
        "prefix reuse must shave prefill tokens"
    );
    assert!(
        dm.kv_live_bytes_peak < dm.kv_eager_bytes_peak,
        "chunked KV must stay under the eager full-context footprint \
         for sequences shorter than max_seq"
    );
    println!("ok: completions batch-strategy-invariant; prefix + paged-KV invariants hold");

    // ---- quantized KV: int8 pages under the same traffic ------------------
    // Same requests, same scheduler, KV stored as int8.  No stop conditions,
    // so every request still finishes at its max_new length; the page
    // positions touched are identical to the f32 run and the live-KV peaks
    // compare page sizes directly.
    let mut int8 = Scheduler::new(
        &w,
        ServeOpts {
            max_batch,
            prefix_cache: true,
            kv_dtype: KvDtype::Int8,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    submit_all(&mut int8, &specs);
    let (int8_done, _) = int8.run();
    let int8_time = t0.elapsed();
    let im = int8.metrics();
    suite.record(
        "continuous scheduler, int8 KV (per generated token)",
        per_tok(int8_time, total_generated(&int8_done)),
    );
    assert_eq!(int8_done.len(), specs.len());
    for (a, b) in int8_done.iter().zip(&cont_done) {
        assert_eq!(
            a.generated.len(),
            b.generated.len(),
            "request {}: int8 KV must still serve to the same length",
            a.id
        );
    }
    assert!(
        cm.kv_live_bytes_peak as f64 >= 3.5 * im.kv_live_bytes_peak as f64,
        "int8 live-KV peak {} B is not >=3.5x under the f32 peak {} B",
        im.kv_live_bytes_peak,
        cm.kv_live_bytes_peak
    );
    println!(
        "kv residency (int8 pages): peak {} B vs f32 {} B ({:.2}x lower)",
        im.kv_live_bytes_peak,
        cm.kv_live_bytes_peak,
        cm.kv_live_bytes_peak as f64 / im.kv_live_bytes_peak.max(1) as f64,
    );

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("perf trajectory written to {}", out.display());
}

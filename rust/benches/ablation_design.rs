//! Ablations of this implementation's own design choices (DESIGN.md §5):
//!
//! 1. **Blocked vs exact GPTQ** — our per-proposal GPTQ restricts Hessian
//!    compensation to quant-group blocks; measure the quality gap and the
//!    speed gap on real trained layers.
//! 2. **σ_r pilot grid** — the rotation random-walk std was re-tuned for
//!    sandbox-scale step budgets (paper: 1e-5 at 10K steps); regenerate the
//!    pilot grid that justified 5e-3.
//! 3. **Prefix-activation cache** — the incremental evaluator's layer-l
//!    restart vs a full re-run, per layer index.
//!
//! Results land in `results/ablation_design.csv`.

use invarexplore::baselines::{gptq, Method};
use invarexplore::calib::{self, CalibSet};
use invarexplore::coordinator::{tables, PipelineOpts, SearchRun, Session};
use invarexplore::quant::{self, QuantScheme};
use invarexplore::search::Objective;
use invarexplore::tensor::ops::matmul_nt;
use invarexplore::tensor::Tensor;
use invarexplore::transform::TransformKinds;
use invarexplore::util::bench::step_budget;
use invarexplore::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let mut csv = CsvWriter::create(
        &tables::results_dir().join("ablation_design.csv"),
        &["ablation", "setting", "metric", "value"],
    )?;

    // ---- 1. blocked vs exact GPTQ -----------------------------------------
    println!("== GPTQ: blocked (group-diagonal) vs exact Hessian ==");
    let w = session.weights("opt-small")?;
    let pile = session.corpus("pile")?;
    let cs = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let stats = calib::capture(&w, &cs);
    let scheme = QuantScheme::new(1, 64);
    for (layer, tname) in [(0usize, "down.w"), (1, "up.w")] {
        let x = if tname == "down.w" { &stats.inputs[layer].down_in } else { &stats.inputs[layer].up_in };
        let wt = w.layer(layer, tname);
        let h = calib::hessian(x, gptq::DAMP);
        let out_err = |wq: &Tensor| {
            let (m, k, n) = (x.rows, x.cols, wt.rows);
            let mut y0 = vec![0.0f32; m * n];
            let mut y1 = vec![0.0f32; m * n];
            matmul_nt(&x.data, &wt.data, m, k, n, &mut y0);
            matmul_nt(&x.data, &wq.data, m, k, n, &mut y1);
            y0.iter().zip(&y1).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let t0 = std::time::Instant::now();
        let blocked = gptq::gptq_quantize(wt, &h, scheme, false, None);
        let t_blocked = t0.elapsed();
        let t0 = std::time::Instant::now();
        let exact = gptq::gptq_quantize(wt, &h, scheme, true, None);
        let t_exact = t0.elapsed();
        let rtn = quant::fake_quant(wt, scheme);
        let (e_b, e_e, e_r) = (out_err(&blocked), out_err(&exact), out_err(&rtn));
        println!(
            "  l{layer}.{tname:7}  output-err  RTN {e_r:9.1}  blocked {e_b:9.1} ({t_blocked:?})  exact {e_e:9.1} ({t_exact:?})"
        );
        let tag = format!("l{layer}.{tname}");
        csv.row(&["gptq_blocked_vs_exact".into(), tag.clone(), "err_rtn".into(), format!("{e_r:.3}")])?;
        csv.row(&["gptq_blocked_vs_exact".into(), tag.clone(), "err_blocked".into(), format!("{e_b:.3}")])?;
        csv.row(&["gptq_blocked_vs_exact".into(), tag.clone(), "err_exact".into(), format!("{e_e:.3}")])?;
        csv.row(&["gptq_blocked_vs_exact".into(), tag.clone(), "t_blocked_ms".into(), format!("{:.2}", t_blocked.as_secs_f64() * 1e3)])?;
        csv.row(&["gptq_blocked_vs_exact".into(), tag, "t_exact_ms".into(), format!("{:.2}", t_exact.as_secs_f64() * 1e3)])?;
    }

    // ---- 2. σ_r pilot grid --------------------------------------------------
    println!("== σ_r pilot grid (rotation-only search, opt-small) ==");
    let steps = step_budget(120);
    for sigma_r in [1e-5f64, 1e-3, 5e-3, 2e-2] {
        let mut opts = PipelineOpts::new("opt-small", Method::Awq, scheme);
        opts.calib_seqs = 16;
        opts.kinds = TransformKinds::parse("r")?;
        let mut run = SearchRun::build(&session, &opts)?;
        run.cfg.sigma_r = sigma_r;
        run.cfg.kinds = opts.kinds;
        run.init()?;
        let l0 = run.state.best.total(run.state.alpha);
        run.steps(steps)?;
        let l1 = run.state.best.total(run.state.alpha);
        let ppl = run.test_ppl(&session, "wiki", 32)?;
        println!("  σ_r {sigma_r:7.0e}: loss {l0:.4} -> {l1:.4}, wiki ppl {ppl:8.2}");
        csv.row(&["sigma_r_pilot".into(), format!("{sigma_r:.0e}"), "wiki_ppl".into(), format!("{ppl:.3}")])?;
        csv.row(&["sigma_r_pilot".into(), format!("{sigma_r:.0e}"), "loss_delta".into(), format!("{:.5}", l0 - l1)])?;
    }

    // ---- 3. prefix-cache benefit ---------------------------------------------
    println!("== prefix-activation cache: proposal cost by mutated layer ==");
    let mut opts = PipelineOpts::new("opt-base", Method::Awq, scheme);
    opts.calib_seqs = 32;
    let mut run = SearchRun::build(&session, &opts)?;
    run.init()?;
    let n_layers = run.obj.n_layers();
    for l in 0..n_layers {
        let proposal = run.state.transforms[l].propose(
            &mut run.state.rng,
            TransformKinds::all(),
            0.1,
            1e-2,
            5e-3,
        );
        let t0 = std::time::Instant::now();
        let reps = 3;
        for _ in 0..reps {
            let _ = invarexplore::search::probe(&mut run.obj, l, &proposal)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!("  mutate layer {l}: {ms:7.1} ms/proposal (re-runs layers {l}..{n_layers})");
        csv.row(&["prefix_cache".into(), format!("layer{l}"), "ms_per_proposal".into(), format!("{ms:.2}")])?;
    }
    csv.flush()?;
    println!("(CSV in results/ablation_design.csv)");
    Ok(())
}

//! Reproduces **Table 3** (bits × group sizes): AWQ ± InvarExplore across
//! quantization settings, with *measured* bits/param from the packed codec.
//!
//! Shape claims: more bits ⇒ monotonically better; smaller groups ⇒ better
//! at slightly more memory; InvarExplore's gain is largest in the hardest
//! setting and vanishes once the base method saturates near FP.

use invarexplore::coordinator::{tables, Session};
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let out = tables::table3(&session, "opt-base", step_budget(200), 50, 0)?;
    println!("{out}");
    println!("(CSV in results/table3_bits_groups.csv)");
    Ok(())
}

//! Reproduces **Figure 1**: optimization curves across calibration sizes —
//! (a) calibration loss, (b) WikiText-analog test perplexity, (c) proposal
//! acceptance ratio — as CSV series + ASCII plots.
//!
//! Shape claims: loss and test ppl fall with steps; fewer calibration
//! sequences ⇒ faster calibration-loss descent but slower test improvement;
//! acceptance starts high and decays toward convergence.

use invarexplore::coordinator::{tables, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let f1 = tables::Figure1Opts {
        model: "opt-base".into(),
        scheme: QuantScheme::new(1, 64),
        calib_seqs: vec![1, 8, 32],
        total_steps: step_budget(320),
        segments: 8,
        seed: 0,
    };
    let out = tables::figure1(&session, &f1)?;
    println!("{out}");
    println!("(CSV in results/figure1_curves.csv + per-run telemetry files)");
    Ok(())
}

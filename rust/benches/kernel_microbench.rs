//! Kernel-layer microbenchmarks for the packed serving path:
//!
//! 1. **dequant bandwidth** — `PackedTensor::dequant_row_into` at the
//!    scalar tier vs the dispatched SIMD tier (AVX2 unpacks 8 codes per
//!    instruction; SSE2 dequant stays scalar by design), reported in GB/s
//!    of produced f32s;
//! 2. **GEMV vs cache-blocked GEMM** — `linear_batch` over k ∈ {1, 4, 16}
//!    activation rows against k independent fused `linear` GEMVs.  The
//!    blocked path dequantizes every ROW_TILE of weight rows once for all
//!    k rows, so it must win strictly for k > 1 at every dispatch level;
//!
//! both swept over bits ∈ {2, 3, 4} × group ∈ {64, 128} — the serving
//! schemes.  Every A/B pair is bit-identical by construction (pinned in
//! `quant::packed`'s tests); this bench re-asserts the k-row identity and
//! measures only speed.
//!
//! Runs on synthetic random weights — no artifacts needed.  `--smoke` (or
//! env `KERNEL_MICROBENCH_SMOKE=1`) shrinks the matrix and the per-case
//! budget; the strict-win assertions still run, so CI catches a SIMD or
//! blocking regression even in smoke.  Writes `BENCH_kernel_microbench.json`
//! (the perf trajectory CI archives) and fails loudly if it cannot.

use invarexplore::obs;
use invarexplore::quant::{self, simd, PackedTensor, QuantScheme, SimdLevel};
use invarexplore::tensor::Tensor;
use invarexplore::util::bench::{self, BenchSuite};
use invarexplore::util::rng::Pcg64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("KERNEL_MICROBENCH_SMOKE").as_deref() == Ok("1");
    // rows = packed output rows, cols = reduction dim (multiple of every
    // swept group so each combo tiles evenly; ragged tails are covered by
    // the exhaustive identity tests, not re-measured here)
    let (rows, cols) = if smoke { (128, 256) } else { (512, 1024) };
    let hw = simd::detect();
    println!(
        "== kernel_microbench: [{rows}x{cols}] weights, detected {hw:?}{} ==",
        if smoke { ", SMOKE" } else { "" }
    );
    if smoke {
        bench::smoke_budget_ms(30);
    }
    let mut suite = BenchSuite::new("kernel_microbench");
    let mut rng = Pcg64::new(11);

    for &bits in &[2usize, 3, 4] {
        for &group in &[64usize, 128] {
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32).collect(),
            );
            let p = PackedTensor::pack(&quant::quantize(&w, QuantScheme::new(bits, group)));

            // ---- dequant bandwidth: scalar tier vs dispatched tier --------
            let mut buf = vec![0.0f32; rows * cols];
            simd::set_simd_level(SimdLevel::Scalar);
            let scalar = suite.bench(&format!("dequant {bits}x{group} scalar"), || {
                for r in 0..rows {
                    p.dequant_row_into(r, &mut buf[r * cols..(r + 1) * cols]);
                }
                std::hint::black_box(&buf);
            });
            simd::set_simd_level(hw);
            let dispatched = suite.bench(&format!("dequant {bits}x{group} simd"), || {
                for r in 0..rows {
                    p.dequant_row_into(r, &mut buf[r * cols..(r + 1) * cols]);
                }
                std::hint::black_box(&buf);
            });
            let gb = (rows * cols * 4) as f64 / 1e9;
            println!(
                "  dequant {bits}x{group}: scalar {:.2} GB/s -> {hw:?} {:.2} GB/s ({:.2}x)",
                gb / scalar.mean.as_secs_f64().max(1e-12),
                gb / dispatched.mean.as_secs_f64().max(1e-12),
                scalar.mean.as_secs_f64() / dispatched.mean.as_secs_f64().max(1e-12),
            );
            // AVX2 vectorizes every serving width (bits <= 4 pack >= 8
            // codes/word); SSE2 dequant is scalar by design, nothing to pin
            if hw == SimdLevel::Avx2 {
                assert!(
                    dispatched.mean < scalar.mean,
                    "AVX2 dequant {bits}x{group} not strictly faster: {:?} vs scalar {:?}",
                    dispatched.mean,
                    scalar.mean
                );
            }

            // ---- GEMV vs cache-blocked multi-row GEMM ---------------------
            let bias = vec![0.0f32; rows];
            for &k in &[1usize, 4, 16] {
                let x = Tensor::from_vec(
                    k,
                    cols,
                    (0..k * cols).map(|_| rng.normal() as f32).collect(),
                );
                let row_views: Vec<Tensor> = (0..k)
                    .map(|r| {
                        Tensor::from_vec(1, cols, x.data[r * cols..(r + 1) * cols].to_vec())
                    })
                    .collect();
                let blocked = suite.bench(&format!("gemm {bits}x{group} k={k} blocked"), || {
                    std::hint::black_box(p.linear_batch(&x, &bias));
                });
                let gemvs = suite.bench(&format!("gemm {bits}x{group} k={k} as GEMVs"), || {
                    for row in &row_views {
                        std::hint::black_box(p.linear(row, &bias));
                    }
                });
                // identity: the blocked call == k row-at-a-time calls
                let batched = p.linear_batch(&x, &bias);
                for (r, row) in row_views.iter().enumerate() {
                    let single = p.linear(row, &bias);
                    assert_eq!(
                        batched.data[r * rows..(r + 1) * rows],
                        single.data[..],
                        "gemm {bits}x{group} k={k}: blocked row {r} diverged from GEMV"
                    );
                }
                println!(
                    "  gemm {bits}x{group} k={k}: blocked {:?} vs {k} GEMVs {:?} ({:.2}x)",
                    blocked.mean,
                    gemvs.mean,
                    gemvs.mean.as_secs_f64() / blocked.mean.as_secs_f64().max(1e-12),
                );
                // for k > 1 the blocked path dequantizes each weight tile
                // once instead of k times — a level-independent strict win
                if k > 1 {
                    assert!(
                        blocked.mean < gemvs.mean,
                        "blocked GEMM {bits}x{group} k={k} not strictly faster: \
                         {:?} vs {:?}",
                        blocked.mean,
                        gemvs.mean
                    );
                }
            }
        }
    }
    simd::set_simd_level(hw);

    // ---- obs: tracing-disabled overhead on the fused GEMV path ------------
    // The recorder's contract is "off = one relaxed atomic load per kernel
    // call".  Measure the instrumented entry point (`linear_into`) against
    // the raw kernel body with the gate compiled out of the loop entirely
    // (`linear_into_raw`), tracing disabled, and pin the overhead under 1%.
    // Min-of-iters per pass and best-of-3 passes damp scheduler noise; the
    // measured fraction lands in the bench JSON as a tracked counter.
    obs::set_enabled(false);
    let w = Tensor::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32).collect());
    let p = PackedTensor::pack(&quant::quantize(&w, QuantScheme::new(3, 64)));
    let x1 = Tensor::from_vec(1, cols, (0..cols).map(|_| rng.normal() as f32).collect());
    let bias = vec![0.0f32; rows];
    let mut out_t = Tensor::zeros(1, rows);
    let budget = std::time::Duration::from_millis(if smoke { 40 } else { 250 });
    let mut overhead = f64::INFINITY;
    for _ in 0..3 {
        let instr = bench::measure(
            || {
                p.linear_into(&x1, &bias, &mut out_t);
                std::hint::black_box(&out_t);
            },
            budget,
            10_000,
        );
        let raw = bench::measure(
            || {
                p.linear_into_raw(&x1, &bias, &mut out_t);
                std::hint::black_box(&out_t);
            },
            budget,
            10_000,
        );
        let r = raw.min.as_secs_f64().max(1e-12);
        overhead = overhead.min((instr.min.as_secs_f64() - r) / r);
    }
    suite.set_counter("trace_off_overhead_frac", overhead);
    println!("  tracing-off overhead on fused GEMV: {:.4}%", overhead * 100.0);
    assert!(
        overhead < 0.01,
        "tracing-disabled overhead {:.3}% on the fused GEMV path exceeds 1%",
        overhead * 100.0
    );

    // ---- obs: achieved GB/s per tier from a traced pass -------------------
    // Brief tracing-on pass so the per-tier kernel counters (the series the
    // perf-history drift check reads) ship with every bench artifact.
    obs::kernel::reset();
    obs::set_enabled(true);
    let x16 = Tensor::from_vec(16, cols, (0..16 * cols).map(|_| rng.normal() as f32).collect());
    for _ in 0..8 {
        std::hint::black_box(p.linear_batch(&x16, &bias));
    }
    obs::set_enabled(false);
    for (name, v) in obs::kernel::snapshot().counters() {
        suite.set_counter(&name, v);
    }
    obs::kernel::reset();

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    let len = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    assert!(len > 0, "BENCH json missing or empty at {}", out.display());
    println!("perf trajectory written to {}", out.display());
}

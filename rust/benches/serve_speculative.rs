//! Self-speculative decoding benchmarks:
//!
//! 1. **chunked verify vs sequential verify** — `native::forward_chunk`
//!    over k+1 tokens against k+1 sequential `decode_step`s replaying the
//!    *same* token trace from the same KV prefix (identical acceptance
//!    trace, identical logits — pinned bitwise), isolating the weight-
//!    traffic amortization the speculative verify path is built on;
//! 2. **end-to-end speculative serving** — `serve::Scheduler` with a
//!    low-bit draft attached vs plain decoding, tok/s and acceptance rate
//!    at batch 1 and batch 4;
//! 3. **the determinism pin** — greedy speculative completions bit-identical
//!    to non-speculative across {batch 1,4} x {FCFS,SPF,EDF} x prefix
//!    cache on/off (plus a stochastic top-k run: acceptance re-samples
//!    through the request RNG, so even sampled completions are identical).
//!
//! Runs entirely on synthetic random models — no artifacts needed.
//! `--smoke` (or env `SERVE_SPECULATIVE_SMOKE=1`) shrinks the workload,
//! asserts the invariants (a: identity matrix, b: chunked verify strictly
//! beats sequential at the same trace), writes
//! `BENCH_serve_speculative.json`, and exits — wired into CI.

use std::time::Instant;

use invarexplore::model::native::{self, KvCache};
use invarexplore::model::{OptConfig, Weights};
use invarexplore::quant::BitAllocation;
use invarexplore::serve::{
    AdmissionPolicy, Completion, PackedModel, Request, Scheduler, ServeOpts, ServeStats,
};
use invarexplore::util::bench::{self, BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

/// Model for the chunked-vs-sequential verify microbench: wide enough that
/// weight streaming dominates (the effect being measured), small enough
/// for CI smoke.
fn verify_config(smoke: bool) -> OptConfig {
    OptConfig {
        name: "spec-verify-bench".into(),
        vocab: 512,
        d_model: 128,
        n_layers: if smoke { 2 } else { 4 },
        n_heads: 8,
        d_ffn: 512,
        max_seq: 96,
    }
}

/// Small model for the scheduler matrix (many runs, tiny forwards).
fn matrix_config() -> OptConfig {
    OptConfig {
        name: "spec-matrix".into(),
        vocab: 96,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 64,
        max_seq: 64,
    }
}

fn packed(w: &Weights, alloc: &str) -> PackedModel {
    PackedModel::from_allocation(w.clone(), &BitAllocation::parse(alloc).unwrap()).unwrap()
}

type Traffic = Vec<(usize, Vec<i32>, usize)>;

/// Shared-prefix traffic over two prompt families.
fn traffic(cfg: &OptConfig, n: usize, gen: usize) -> Traffic {
    let mut rng = Pcg64::new(11);
    let shared: Vec<Vec<i32>> = (0..2)
        .map(|_| (0..6).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut p = shared[i % 2].clone();
            p.extend((0..3 + i % 3).map(|_| rng.below(cfg.vocab) as i32));
            (i, p, gen)
        })
        .collect()
}

fn run_sched(
    target: &PackedModel,
    draft: Option<&PackedModel>,
    specs: &Traffic,
    sampler: Sampler,
    spec: usize,
    max_batch: usize,
    policy: AdmissionPolicy,
    prefix_cache: bool,
) -> (Vec<Completion>, ServeStats) {
    let mut s = Scheduler::new(
        target,
        ServeOpts { max_batch, policy, prefix_cache, seed: 7, spec, ..Default::default() },
    );
    if let Some(d) = draft {
        s = s.with_draft(d);
    }
    for (id, p, m) in specs {
        s.submit(Request::new(*id, p.clone(), *m, sampler));
    }
    s.run()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SERVE_SPECULATIVE_SMOKE").as_deref() == Ok("1");
    let k = 4usize;
    println!("== serve_speculative: draft k={k}{} ==", if smoke { ", SMOKE" } else { "" });
    if smoke {
        bench::smoke_budget_ms(120);
    }
    let mut suite = BenchSuite::new("serve_speculative");

    // ---- (b) chunked verify vs sequential verify, same acceptance trace ----
    let vcfg = verify_config(smoke);
    let vw = Weights::random(vcfg.clone(), 3);
    let target = packed(&vw, "2x32");
    let mut rng = Pcg64::new(5);
    let prompt: Vec<i32> = (0..16).map(|_| rng.below(vcfg.vocab) as i32).collect();
    let mut base = KvCache::new(&vcfg);
    let mut logits = native::prefill(&target, &mut base, &prompt);
    // greedy trace: the exact tokens a (perfect-acceptance) verify replays
    let gen = if smoke { 20 } else { 60 };
    let mut trace = Vec::with_capacity(gen);
    for _ in 0..gen {
        let t = invarexplore::util::sampling::argmax(&logits) as i32;
        trace.push(t);
        logits = native::decode_step(&target, &mut base, t);
    }
    base.truncate(prompt.len());

    // bitwise pin outside the timed loops: every chunk row == its decode_step
    {
        let mut c1 = base.fork_at(prompt.len());
        let mut c2 = base.fork_at(prompt.len());
        for chunk in trace.chunks(k + 1) {
            let rows = native::forward_chunk(&target, &mut c1, chunk);
            for (i, &t) in chunk.iter().enumerate() {
                let step = native::decode_step(&target, &mut c2, t);
                assert_eq!(rows.row(i), step.as_slice(), "verify parity broke at {t}");
            }
        }
        println!("parity: chunked verify bit-identical to sequential decode_steps");
    }

    let chunked = suite.bench("chunked verify (per trace, k+1 rows/pass)", || {
        let mut c = base.fork_at(prompt.len());
        for chunk in trace.chunks(k + 1) {
            std::hint::black_box(native::forward_chunk(&target, &mut c, chunk));
        }
    });
    let sequential = suite.bench("sequential verify (per trace, 1 row/pass)", || {
        let mut c = base.fork_at(prompt.len());
        for &t in &trace {
            std::hint::black_box(native::decode_step(&target, &mut c, t));
        }
    });
    println!(
        "verify ({} tokens, {} model): chunked {:?} vs sequential {:?} p50 ({:.2}x)",
        trace.len(),
        vcfg.name,
        chunked.p50,
        sequential.p50,
        sequential.p50.as_secs_f64() / chunked.p50.as_secs_f64().max(1e-12),
    );
    assert!(
        chunked.p50 < sequential.p50,
        "chunked verify ({:?}) must strictly beat sequential decode_step \
         verification ({:?}) at the same acceptance trace",
        chunked.p50,
        sequential.p50
    );

    // ---- (a) determinism matrix + end-to-end tok/s -------------------------
    let mcfg = matrix_config();
    let mw = Weights::random(mcfg.clone(), 1);
    let mtarget = packed(&mw, "2x16,ffn_up=3x16");
    // aggressive 1-bit draft: worst-case acceptance, identity must hold
    let lowbit_draft = mtarget.draft(&BitAllocation::parse("1x16").unwrap()).unwrap();
    // same-allocation draft: perfect greedy acceptance, best-case tok/s
    let perfect_draft = mtarget.draft(&BitAllocation::parse("2x16,ffn_up=3x16").unwrap()).unwrap();
    let specs = traffic(&mcfg, if smoke { 6 } else { 16 }, if smoke { 6 } else { 24 });

    let strip = |done: Vec<Completion>| -> Vec<(usize, Vec<i32>)> {
        done.into_iter().map(|c| (c.id, c.generated)).collect()
    };
    let reference = strip(
        run_sched(&mtarget, None, &specs, Sampler::Greedy, 0, 1, AdmissionPolicy::Fcfs, false).0,
    );
    for draft in [&lowbit_draft, &perfect_draft] {
        for mb in [1usize, 4] {
            for policy in [
                AdmissionPolicy::Fcfs,
                AdmissionPolicy::ShortestPrompt,
                AdmissionPolicy::Deadline,
            ] {
                for pc in [false, true] {
                    let (done, stats) = run_sched(
                        &mtarget,
                        Some(draft),
                        &specs,
                        Sampler::Greedy,
                        k,
                        mb,
                        policy,
                        pc,
                    );
                    assert_eq!(
                        reference,
                        strip(done),
                        "speculative completions diverged at batch {mb}, {policy:?}, \
                         prefix {pc}"
                    );
                    assert!(stats.verify_chunks > 0, "speculation must actually engage");
                }
            }
        }
    }
    println!("ok: greedy speculative completions bit-identical across batch x policy x prefix");
    // stochastic sampling is covered too: acceptance re-samples through the
    // per-request RNG stream, so top-k completions also match exactly
    let topk = Sampler::TopK { k: 4, temperature: 0.9 };
    let plain_topk =
        strip(run_sched(&mtarget, None, &specs, topk, 0, 1, AdmissionPolicy::Fcfs, false).0);
    let spec_topk = strip(
        run_sched(&mtarget, Some(&lowbit_draft), &specs, topk, k, 4, AdmissionPolicy::Fcfs, true)
            .0,
    );
    assert_eq!(plain_topk, spec_topk, "top-k speculative completions diverged");
    println!("ok: top-k speculative completions bit-identical too");

    // end-to-end tok/s + acceptance at batch 1 and 4 (perfect + low-bit)
    for mb in [1usize, 4] {
        let t0 = Instant::now();
        let (_, plain) =
            run_sched(&mtarget, None, &specs, Sampler::Greedy, 0, mb, AdmissionPolicy::Fcfs, true);
        let plain_time = t0.elapsed();
        let t0 = Instant::now();
        let (_, spec) = run_sched(
            &mtarget,
            Some(&perfect_draft),
            &specs,
            Sampler::Greedy,
            k,
            mb,
            AdmissionPolicy::Fcfs,
            true,
        );
        let spec_time = t0.elapsed();
        let (_, lowbit) = run_sched(
            &mtarget,
            Some(&lowbit_draft),
            &specs,
            Sampler::Greedy,
            k,
            mb,
            AdmissionPolicy::Fcfs,
            true,
        );
        println!(
            "batch {mb}: plain {:.1} tok/s vs speculative {:.1} tok/s \
             (perfect-draft acceptance {:.0}%, {:.2} tokens/verify; \
             1-bit draft acceptance {:.0}%)",
            plain.decoded_tokens as f64 / plain_time.as_secs_f64().max(1e-9),
            spec.decoded_tokens as f64 / spec_time.as_secs_f64().max(1e-9),
            100.0 * spec.spec_accept_rate(),
            spec.spec_tokens_per_verify(),
            100.0 * lowbit.spec_accept_rate(),
        );
        assert!(
            (spec.spec_accept_rate() - 1.0).abs() < 1e-12,
            "a same-allocation draft must reach full greedy acceptance"
        );
        let per_tok = |d: std::time::Duration, toks: usize| {
            Stats::one_shot(std::time::Duration::from_secs_f64(
                d.as_secs_f64() / toks.max(1) as f64,
            ))
        };
        suite.record(
            &format!("speculative decode (per token, batch {mb})"),
            per_tok(spec_time, spec.decoded_tokens),
        );
        suite.record(
            &format!("plain decode (per token, batch {mb})"),
            per_tok(plain_time, plain.decoded_tokens),
        );
    }

    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("perf trajectory written to {}", out.display());
}

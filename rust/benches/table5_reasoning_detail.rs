//! Reproduces **Table 5** (appendix): per-task reasoning accuracy for each
//! of the six tasks, FP32 vs AWQ vs +InvarExplore across model sizes.
//!
//! Shape claim: InvarExplore wins on most (task, model) cells (paper: 58
//! wins / 11 losses / 3 ties).

use invarexplore::coordinator::{tables, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let models: Vec<String> = session.manifest.model_names().iter().map(|s| s.to_string()).collect();
    let out = tables::table5(&session, &models, QuantScheme::new(1, 64), step_budget(200), 60, 0)?;
    println!("{out}");
    println!("(CSV in results/table5_reasoning.csv)");
    Ok(())
}

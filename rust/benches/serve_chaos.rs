//! Chaos benchmark: the PR-9 trace-replay workload under deterministic
//! fault injection ([`invarexplore::serve::fault`]), pinning the serving
//! stack's fault-tolerance contract.
//!
//! Segments (each replays the same seeded MMPP/Zipf trace):
//!
//! 1. **Replica kill** — 4 replicas, the one that owns the most popular
//!    prompt family is killed at round 2 of its scheduler run.  Asserts:
//!    every request yields **exactly one** completion (zero lost, zero
//!    duplicated), count-based goodput stays above 0.6× the no-fault run,
//!    and every request the faults never touched is **bit-identical** to
//!    the no-fault reference.
//! 2. **Transient dispatch errors** — `transient=0.1` over 2 replicas;
//!    same invariants, plus every `Failed` completion must be
//!    fault-touched (no silent collateral damage).
//! 3. **Stall + round budget** — request 0's decode stalls 150 ms against
//!    a 40 ms per-round budget: it must finish `Failed` (mentioning the
//!    budget) while every other request matches the reference.
//! 4. **Optional extra plan** — `SERVE_CHAOS_EXTRA=<spec>` replays the
//!    trace under an operator-supplied plan and checks the generic
//!    invariants; the weekly verify workflow drives a higher-fault matrix
//!    through this hook.
//!
//! Runs entirely on a synthetic random model — no artifacts needed.
//! `--smoke` (or env `SERVE_CHAOS_SMOKE=1`) shrinks the trace and exits
//! after the assertions — wired into CI.

use std::collections::BTreeSet;
use std::time::Instant;

use invarexplore::model::{OptConfig, Weights};
use invarexplore::quant::{BitAllocation, QuantScheme};
use invarexplore::serve::{
    Completion, FaultPlan, FinishReason, PackedModel, Request, Router, RouterOpts, RouterStats,
    ServeOpts,
};
use invarexplore::util::bench::{BenchSuite, Stats};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn bench_config(smoke: bool) -> OptConfig {
    if smoke {
        OptConfig::test_config()
    } else {
        OptConfig {
            name: "chaos".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ffn: 512,
            max_seq: 128,
        }
    }
}

/// Zipf(s)-distributed rank in `1..=n` via inverse-CDF over the exact
/// (small-n) normalization.
fn zipf(rng: &mut Pcg64, n: usize, s: f64) -> usize {
    let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
    let mut u = rng.uniform() * norm;
    for k in 1..=n {
        u -= (k as f64).powf(-s);
        if u <= 0.0 {
            return k;
        }
    }
    n
}

/// Knuth Poisson sampler (λ small enough for the product method).
fn poisson(rng: &mut Pcg64, lambda: f64) -> usize {
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.uniform();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// One request spec: `(id, prompt, max_new)`.
type Spec = (usize, Vec<i32>, usize);

/// The replay trace: requests grouped into arrival waves.
struct Trace {
    waves: Vec<Vec<Spec>>,
    total: usize,
}

/// Build the trace: `n_waves` MMPP arrival waves over `families` shared
/// system prompts with Zipf popularity and Zipf-tailed suffix lengths
/// (same generator shape as `serve_trace_replay`; benches are separate
/// crate roots, so the helper is duplicated rather than shared).
fn build_trace(cfg: &OptConfig, n_waves: usize, families: usize, seed: u64) -> Trace {
    let mut rng = Pcg64::new(seed);
    let shared_len = cfg.max_seq / 4;
    let prefixes: Vec<Vec<i32>> = (0..families)
        .map(|_| (0..shared_len).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect();
    let (lambda_calm, lambda_burst) = (2.0, 6.0);
    let mut burst = false;
    let mut id = 0usize;
    let max_suffix = cfg.max_seq / 4;
    let mut waves = Vec::with_capacity(n_waves);
    for _ in 0..n_waves {
        if rng.uniform() < if burst { 0.4 } else { 0.25 } {
            burst = !burst;
        }
        let lambda = if burst { lambda_burst } else { lambda_calm };
        let arrivals = 1 + poisson(&mut rng, lambda);
        let mut wave = Vec::with_capacity(arrivals);
        for _ in 0..arrivals {
            let fam = zipf(&mut rng, families, 1.2) - 1;
            let mut prompt = prefixes[fam].clone();
            let suffix = zipf(&mut rng, max_suffix, 1.1);
            prompt.extend((0..suffix).map(|_| rng.below(cfg.vocab) as i32));
            let max_new = 1 + zipf(&mut rng, (cfg.max_seq / 8).max(2), 1.1);
            wave.push((id, prompt, max_new));
            id += 1;
        }
        waves.push(wave);
    }
    Trace { waves, total: id }
}

fn request_of(spec: &Spec) -> Request {
    let sampler = if spec.0 % 2 == 0 {
        Sampler::Greedy
    } else {
        Sampler::TopK { k: 4, temperature: 0.9 }
    };
    Request::new(spec.0, spec.1.clone(), spec.2, sampler)
}

/// Replay the whole trace through a router, one `run` per arrival wave.
fn replay(router: &mut Router<'_, PackedModel>, trace: &Trace) -> (Vec<Completion>, RouterStats) {
    let mut done = Vec::with_capacity(trace.total);
    let mut stats = RouterStats::default();
    for wave in &trace.waves {
        for spec in wave {
            router.submit(request_of(spec));
        }
        let (d, s) = router.run();
        done.extend(d);
        stats = s;
    }
    done.sort_by_key(|c| c.id);
    (done, stats)
}

fn served_ok(c: &Completion) -> bool {
    matches!(c.finish, FinishReason::Length | FinishReason::Stop)
}

/// The chaos contract every fault segment must satisfy:
/// exactly one completion per submitted request, every `Failed` completion
/// fault-touched, and every untouched request bit-identical to the
/// no-fault reference.  Returns the count served successfully.
fn assert_chaos_invariants(
    tag: &str,
    done: &[Completion],
    stats: &RouterStats,
    reference: &[Completion],
) -> usize {
    assert_eq!(
        done.len(),
        reference.len(),
        "{tag}: {} completions for {} requests (lost or duplicated work)",
        done.len(),
        reference.len()
    );
    for (i, c) in done.iter().enumerate() {
        // sorted by id with one entry per id 0..n pins exactly-once
        assert_eq!(c.id, i, "{tag}: request {i} missing or duplicated");
    }
    let touched: BTreeSet<usize> = stats.fault_touched.iter().copied().collect();
    for c in done {
        if matches!(c.finish, FinishReason::Failed(_)) {
            assert!(
                touched.contains(&c.id),
                "{tag}: request {} failed without ever being fault-touched",
                c.id
            );
        }
        if !touched.contains(&c.id) {
            assert_eq!(
                c, &reference[c.id],
                "{tag}: untouched request {} diverged from the no-fault reference",
                c.id
            );
        }
    }
    done.iter().filter(|c| served_ok(c)).count()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SERVE_CHAOS_SMOKE").as_deref() == Ok("1");
    let cfg = bench_config(smoke);
    let w = Weights::random(cfg.clone(), 1);
    let pm = PackedModel::from_allocation(w, &BitAllocation::uniform(QuantScheme::new(2, 32)))
        .expect("packed model builds");
    let (n_waves, families) = if smoke { (4, 3) } else { (12, 4) };
    let trace = build_trace(&cfg, n_waves, families, 4242);
    println!(
        "== serve_chaos: {} ({} requests over {} MMPP waves, {} system prompts{}) ==",
        cfg.name,
        trace.total,
        trace.waves.len(),
        families,
        if smoke { ", SMOKE" } else { "" }
    );
    let mut suite = BenchSuite::new("serve_chaos");
    let serve = ServeOpts { max_batch: 4, prefix_cache: true, ..Default::default() };
    let router_opts = |replicas: usize| RouterOpts {
        replicas,
        affinity_tokens: cfg.max_seq / 4,
        retry_backoff_ms: 0,
        ..Default::default()
    };

    // ---- no-fault reference (4 replicas) ----------------------------------
    let (reference, ref_served) = {
        let mut router = Router::new(&pm, router_opts(4), serve);
        let t0 = Instant::now();
        let (done, stats) = replay(&mut router, &trace);
        let wall = t0.elapsed();
        assert_eq!(done.len(), trace.total);
        assert_eq!(stats.replica_deaths, 0);
        let served = done.iter().filter(|c| served_ok(c)).count();
        assert_eq!(served, trace.total, "no-fault run must serve everything");
        suite.record("no-fault replay wall time", Stats::one_shot(wall));
        println!("no-fault reference: {served}/{} served in {wall:.1?}", trace.total);
        (done, served)
    };

    // ---- segment 1: kill 1 of 4 replicas mid-run --------------------------
    {
        // the victim is the home of the trace's first (most popular family)
        // prompt, so it is guaranteed to hold work when the kill fires
        let victim =
            Router::new(&pm, router_opts(4), serve).affinity_replica(&trace.waves[0][0].1);
        let plan = FaultPlan::parse(&format!("seed=11,kill={victim}@2")).expect("valid plan");
        let mut router = Router::new(&pm, router_opts(4), serve).with_fault_plan(plan);
        let t0 = Instant::now();
        let (done, stats) = replay(&mut router, &trace);
        let wall = t0.elapsed();
        assert_eq!(stats.replica_deaths, 1, "replica {victim} must die exactly once");
        assert!(stats.redispatched > 0, "the dead replica's work must redispatch");
        let served = assert_chaos_invariants("kill", &done, &stats, &reference);
        // goodput is counted in successfully served requests, so the bound
        // is a property of recovery, not machine speed
        assert!(
            served as f64 >= 0.6 * ref_served as f64,
            "kill goodput collapsed: {served}/{ref_served} served"
        );
        suite.record("kill replay wall time", Stats::one_shot(wall));
        suite.set_counter("kill_served", served as f64);
        suite.set_counter("kill_redispatched", stats.redispatched as f64);
        suite.set_counter("kill_failed", stats.failed_requests as f64);
        println!(
            "kill replica {victim}@2: {served}/{} served, {} redispatched, {} failed \
             ({wall:.1?})",
            trace.total, stats.redispatched, stats.failed_requests
        );
    }

    // ---- segment 2: transient dispatch errors -----------------------------
    {
        let plan = FaultPlan::parse("seed=12,transient=0.1").expect("valid plan");
        let mut router = Router::new(&pm, router_opts(2), serve).with_fault_plan(plan);
        let (done, stats) = replay(&mut router, &trace);
        let served = assert_chaos_invariants("transient", &done, &stats, &reference);
        assert!(
            served as f64 >= 0.6 * ref_served as f64,
            "transient goodput collapsed: {served}/{ref_served} served"
        );
        suite.set_counter("transient_served", served as f64);
        suite.set_counter("transient_retries", stats.redispatched as f64);
        println!(
            "transient p=0.1: {served}/{} served, {} retries, {} failed",
            trace.total, stats.redispatched, stats.failed_requests
        );
    }

    // ---- segment 3: stall + per-round wall-clock budget -------------------
    {
        // request 0's decode sleeps 150 ms at its round 1 against a 40 ms
        // budget (stalls match by request id, so this fires exactly once;
        // margins wide on both sides for noisy CI boxes)
        let plan = FaultPlan::parse("seed=13,stall=0@1x150").expect("valid plan");
        let budget = ServeOpts { round_budget_ms: Some(40), ..serve };
        let mut router = Router::new(&pm, router_opts(1), budget).with_fault_plan(plan);
        let (done, _stats) = replay(&mut router, &trace);
        assert_eq!(done.len(), trace.total);
        match &done[0].finish {
            FinishReason::Failed(msg) => {
                assert!(msg.contains("round budget"), "unexpected failure: {msg}")
            }
            other => panic!("stalled request 0 must fail the round budget, got {other:?}"),
        }
        for c in done.iter().skip(1) {
            assert_eq!(c, &reference[c.id], "stall leaked into request {}", c.id);
        }
        // round-budget failures are scheduler-level (cumulative in the
        // replica metrics), not router retry exhaustion
        let engine_failed = router.replica_metrics(0).failed;
        assert_eq!(engine_failed, 1, "exactly the stalled request blows the budget");
        suite.set_counter("stall_failed", engine_failed as f64);
        println!("stall 150ms vs 40ms budget: request 0 failed cleanly, rest bit-identical");
    }

    // ---- segment 4: operator-supplied extra plan (verify matrix hook) -----
    if let Ok(spec) = std::env::var("SERVE_CHAOS_EXTRA") {
        if !spec.trim().is_empty() {
            let plan = FaultPlan::parse(&spec).expect("SERVE_CHAOS_EXTRA parses");
            let mut router = Router::new(&pm, router_opts(4), serve).with_fault_plan(plan);
            let (done, stats) = replay(&mut router, &trace);
            let served = assert_chaos_invariants("extra", &done, &stats, &reference);
            println!(
                "extra plan {spec:?}: {served}/{} served, {} deaths, {} redispatched, \
                 {} failed — invariants hold",
                trace.total, stats.replica_deaths, stats.redispatched, stats.failed_requests
            );
            suite.set_counter("extra_served", served as f64);
            suite.set_counter("extra_replica_deaths", stats.replica_deaths as f64);
        }
    }

    println!(
        "ok: zero lost/duplicated completions under kills, transients and stalls; \
         untouched requests bit-identical to the no-fault reference"
    );
    let out = suite.write_json(std::path::Path::new(".")).expect("write BENCH json");
    println!("chaos trajectory written to {}", out.display());
}

//! Few-shot multiple-choice reasoning harness (the lm-eval-harness
//! analogue; paper §4.1 uses 5-shot prompting).
//!
//! Each (example, option) pair becomes one sequence: `shots` demonstration
//! examples (context + correct answer) followed by the test context and the
//! candidate option.  Only the option tokens are masked into the score, so
//! the prediction is `argmax_o Σ log p(option_o tokens | prompt)` — exactly
//! the harness' acc metric.

use crate::io::tasks::TaskExample;
use crate::runtime::Engine;
use crate::util::rng::Pcg64;

/// Accuracy of one task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: String,
    pub accuracy: f64,
    pub n: usize,
}

/// One scored row: sequence + option-masked targets.
struct Row {
    tokens: Vec<i32>,
    targets: Vec<i32>,
    mask: Vec<f32>,
    example: usize,
    option: usize,
}

/// Build the few-shot prompt rows for `examples[..n_eval]`.
fn build_rows(
    examples: &[TaskExample],
    shots: usize,
    n_eval: usize,
    seqlen: usize,
    seed: u64,
) -> Vec<Row> {
    let mut rng = Pcg64::new(seed);
    let mut rows = Vec::new();
    let n_eval = n_eval.min(examples.len());
    for (ei, ex) in examples.iter().take(n_eval).enumerate() {
        // demonstrations: drawn from the examples *after* the eval slice
        // when possible (no leakage), else wrap around excluding ei
        let mut demo_pool: Vec<usize> = (0..examples.len()).filter(|&j| j != ei).collect();
        rng.shuffle(&mut demo_pool);
        let mut prompt: Vec<i32> = Vec::new();
        for &j in demo_pool.iter().take(shots) {
            let d = &examples[j];
            prompt.extend(&d.ctx);
            prompt.extend(&d.options[d.answer]);
        }
        prompt.extend(&ex.ctx);

        for (oi, opt) in ex.options.iter().enumerate() {
            let mut seq = prompt.clone();
            seq.extend(opt);
            // keep the tail if too long (few-shot prefix is droppable)
            if seq.len() > seqlen {
                seq.drain(..seq.len() - seqlen);
            }
            let opt_start = seq.len() - opt.len();
            let mut tokens = vec![0i32; seqlen];
            let mut targets = vec![0i32; seqlen];
            let mut mask = vec![0.0f32; seqlen];
            // tokens[t] predicts targets[t] = seq[t+1]
            for t in 0..seq.len() - 1 {
                tokens[t] = seq[t];
                targets[t] = seq[t + 1];
            }
            tokens[seq.len() - 1] = seq[seq.len() - 1];
            for (t, m) in mask.iter_mut().enumerate().take(seq.len() - 1) {
                // target position t predicts seq[t+1]; option tokens are
                // seq[opt_start..], so mask t where t+1 >= opt_start
                if t + 1 >= opt_start {
                    *m = 1.0;
                }
            }
            rows.push(Row { tokens, targets, mask, example: ei, option: oi });
        }
    }
    rows
}

/// Evaluate one task with the engine's current weights.
pub fn eval_task(
    engine: &Engine,
    task: &str,
    examples: &[TaskExample],
    shots: usize,
    n_eval: usize,
    seed: u64,
) -> crate::Result<TaskResult> {
    let rows = build_rows(examples, shots, n_eval, engine.seq, seed);
    anyhow::ensure!(!rows.is_empty(), "no rows for task {task}");
    let n_eval = rows.iter().map(|r| r.example).max().unwrap() + 1;

    // score rows in engine-batch chunks
    let mut scores: Vec<Vec<f64>> = (0..n_eval)
        .map(|ei| vec![f64::NEG_INFINITY; examples[ei].options.len()])
        .collect();
    let b = engine.batch;
    let mut i = 0;
    while i < rows.len() {
        let end = (i + b).min(rows.len());
        let chunk = &rows[i..end];
        let tokens: Vec<Vec<i32>> = chunk.iter().map(|r| r.tokens.clone()).collect();
        let targets: Vec<Vec<i32>> = chunk.iter().map(|r| r.targets.clone()).collect();
        let mask: Vec<Vec<f32>> = chunk.iter().map(|r| r.mask.clone()).collect();
        let (_ce, lp, _) = engine.eval_batch(&tokens, &targets, &mask)?;
        for (r, score) in chunk.iter().zip(lp) {
            scores[r.example][r.option] = score as f64;
        }
        i = end;
    }

    let mut correct = 0usize;
    for (ei, opts) in scores.iter().enumerate() {
        let pred = opts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == examples[ei].answer {
            correct += 1;
        }
    }
    Ok(TaskResult {
        task: task.to_string(),
        accuracy: 100.0 * correct as f64 / n_eval as f64,
        n: n_eval,
    })
}

/// Evaluate all tasks in the manifest; returns per-task results + average.
pub fn eval_all_tasks(
    engine: &Engine,
    data: &crate::io::manifest::DataInfo,
    shots: usize,
    n_eval: usize,
    seed: u64,
) -> crate::Result<(Vec<TaskResult>, f64)> {
    let mut results = Vec::new();
    for (name, path) in &data.tasks {
        let examples = crate::io::tasks::read(path)?;
        let r = eval_task(engine, name, &examples, shots, n_eval, seed)?;
        crate::debug!("task {name}: acc {:.2} (n={})", r.accuracy, r.n);
        results.push(r);
    }
    let avg = results.iter().map(|r| r.accuracy).sum::<f64>() / results.len().max(1) as f64;
    Ok((results, avg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(ctx: Vec<i32>, options: Vec<Vec<i32>>, answer: usize) -> TaskExample {
        TaskExample { ctx, options, answer }
    }

    #[test]
    fn rows_mask_only_option_targets() {
        let examples = vec![
            ex(vec![1, 2, 3], vec![vec![7], vec![8, 9]], 1),
            ex(vec![1, 4], vec![vec![5], vec![6]], 0),
        ];
        let rows = build_rows(&examples, 1, 1, 32, 0);
        assert_eq!(rows.len(), 2); // 2 options of example 0
        for r in &rows {
            let masked: usize = r.mask.iter().filter(|&&m| m > 0.0).count();
            let opt_len = examples[0].options[r.option].len();
            assert_eq!(masked, opt_len, "option {}", r.option);
            // masked targets are exactly the option tokens
            let opt = &examples[0].options[r.option];
            let masked_targets: Vec<i32> = r
                .mask
                .iter()
                .enumerate()
                .filter(|(_, &m)| m > 0.0)
                .map(|(t, _)| r.targets[t])
                .collect();
            assert_eq!(&masked_targets, opt);
        }
    }

    #[test]
    fn rows_truncate_long_prompts_keep_tail() {
        let long_ctx: Vec<i32> = (0..60).collect();
        let examples = vec![
            ex(long_ctx.clone(), vec![vec![99]], 0),
            ex(long_ctx.clone(), vec![vec![98]], 0),
            ex(long_ctx, vec![vec![97]], 0),
        ];
        let rows = build_rows(&examples, 2, 1, 64, 0);
        // option must still be the masked target even after truncation
        let r = &rows[0];
        let masked_targets: Vec<i32> = r
            .mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > 0.0)
            .map(|(t, _)| r.targets[t])
            .collect();
        assert_eq!(masked_targets, vec![99]);
    }

    #[test]
    fn demos_exclude_eval_example() {
        // with 2 examples and 1 shot, the demo for example 0 must be example 1
        let examples = vec![
            ex(vec![10, 11], vec![vec![1], vec![2]], 0),
            ex(vec![20, 21], vec![vec![3], vec![4]], 1),
        ];
        let rows = build_rows(&examples, 1, 1, 32, 0);
        // prompt must contain example 1's ctx (20, 21) and its answer 4
        let r = &rows[0];
        let toks: Vec<i32> = r.tokens.clone();
        assert!(toks.windows(2).any(|w| w == [20, 21]));
        assert!(toks.contains(&4));
        // and must not contain example 0's own answer token inside the demo
        // region (its ctx appears once, as the test context)
        let count_ctx0 = toks.windows(2).filter(|w| *w == [10, 11]).count();
        assert_eq!(count_ctx0, 1);
    }
}

//! Perplexity over a token corpus with the XLA engine (current weights).

use crate::io::tokens::TokenCorpus;
use crate::runtime::Engine;

/// Perplexity over up to `max_seqs` contiguous sequences of the engine's
/// compiled sequence length (mask-weighted CE across batches, then exp).
pub fn perplexity(engine: &Engine, corpus: &TokenCorpus, max_seqs: usize) -> crate::Result<f64> {
    let seqs = corpus.sequences(max_seqs, engine.seq);
    anyhow::ensure!(!seqs.is_empty(), "corpus too small for one sequence");
    let mut ce_num = 0.0;
    let mut ce_den = 0.0;
    let b = engine.batch;
    let mut i = 0;
    while i < seqs.len() {
        let end = (i + b).min(seqs.len());
        let tokens: Vec<Vec<i32>> = seqs[i..end].iter().map(|(t, _)| t.clone()).collect();
        let targets: Vec<Vec<i32>> = seqs[i..end].iter().map(|(_, t)| t.clone()).collect();
        let mask = vec![vec![1.0f32; engine.seq]; tokens.len()];
        let (ce, _lp, mask_sum) = engine.eval_batch(&tokens, &targets, &mask)?;
        ce_num += ce * mask_sum;
        ce_den += mask_sum;
        i = end;
    }
    Ok((ce_num / ce_den.max(1.0)).exp())
}

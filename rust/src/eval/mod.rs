//! Evaluation harness: perplexity on token corpora and few-shot
//! multiple-choice reasoning (the lm-eval-harness analogue).

pub mod ppl;
pub mod reasoning;

pub use ppl::perplexity;
pub use reasoning::{eval_all_tasks, eval_task, TaskResult};

//! # InvarExplore
//!
//! A Rust + JAX + Pallas reproduction of *"Exploring Model Invariance with
//! Discrete Search for Ultra-Low-Bit Quantization"* (Wen, Cao, Mou; 2025).
//!
//! InvarExplore improves ultra-low-bit (1–3 bit) post-training quantization
//! by searching — with an activation-guided hill-climbing algorithm — over
//! *invariant transformations* of transformer FFN blocks: permutation **P**,
//! per-channel scaling **S** and pairwise rotation **R**.  These leave the
//! FP model's function (nearly) unchanged but redistribute weight outliers,
//! changing the groupwise quantization error and therefore the quantized
//! model's perplexity and downstream accuracy (paper Eqns. 5–23,
//! Algorithm 1).
//!
//! ## Architecture (see DESIGN.md)
//!
//! * **Layer 3 (this crate)** — the coordinator: search loop, quantization
//!   baselines (RTN / GPTQ / AWQ / OmniQuant-lite), transforms, evaluation
//!   harness and every substrate (tensor math, JSON, RNG, thread pool, …).
//! * **Layer 2 (python/compile)** — the OPT-style JAX model, lowered once
//!   to HLO text by `aot.py`.
//! * **Layer 1 (python/compile/kernels)** — the Pallas groupwise fake-quant
//!   kernel, lowered (interpret mode) into the same HLO programs.
//! * **Runtime** — [`runtime`] loads `artifacts/*.hlo.txt` through the
//!   `xla` crate's PJRT CPU client and executes them from the search hot
//!   path.  Python never runs at request time.

// Every unsafe block/impl must carry a `// SAFETY:` comment; `cargo xtask
// lint` enforces the same invariant (plus CLAMPED/PANIC-OK/DETERMINISM
// annotations) tree-wide, and CI denies this lint in clippy.
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod calib;
pub mod cli;
pub mod coordinator;
pub mod eval;
pub mod io;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod tensor;
pub mod transform;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

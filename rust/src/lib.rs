//! # InvarExplore
//!
//! A Rust + JAX + Pallas reproduction of *"Exploring Model Invariance with
//! Discrete Search for Ultra-Low-Bit Quantization"* (Wen, Cao, Mou; 2025).
//!
//! InvarExplore improves ultra-low-bit (1–3 bit) post-training quantization
//! by searching — with an activation-guided hill-climbing algorithm — over
//! *invariant transformations* of transformer FFN blocks: permutation **P**,
//! per-channel scaling **S** and pairwise rotation **R**.  These leave the
//! FP model's function (nearly) unchanged but redistribute weight outliers,
//! changing the groupwise quantization error and therefore the quantized
//! model's perplexity and downstream accuracy (paper Eqns. 5–23,
//! Algorithm 1).
//!
//! ## Architecture (see DESIGN.md)
//!
//! * **Layer 3 (this crate)** — the coordinator: search loop, quantization
//!   baselines (RTN / GPTQ / AWQ / OmniQuant-lite), transforms, evaluation
//!   harness and every substrate (tensor math, JSON, RNG, thread pool, …).
//! * **Layer 2 (python/compile)** — the OPT-style JAX model, lowered once
//!   to HLO text by `aot.py`.
//! * **Layer 1 (python/compile/kernels)** — the Pallas groupwise fake-quant
//!   kernel, lowered (interpret mode) into the same HLO programs.
//! * **Runtime** — [`runtime`] loads `artifacts/*.hlo.txt` through the
//!   `xla` crate's PJRT CPU client and executes them from the search hot
//!   path.  Python never runs at request time.

// Every unsafe block/impl must carry a `// SAFETY:` comment; `cargo xtask
// lint` enforces the same invariant (plus CLAMPED/PANIC-OK/DETERMINISM
// annotations) tree-wide, and CI denies this lint in clippy.
#![warn(clippy::undocumented_unsafe_blocks)]
// The operator surface — everything an integrator touches to quantize,
// pack, serve and observe — must be documented; `cargo doc` runs in CI
// with warnings denied.  Modules still being grown toward full coverage
// carry a module-level `#[allow(missing_docs)]` below.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod baselines;
#[allow(missing_docs)]
pub mod calib;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod io;
#[allow(missing_docs)]
pub mod model;
pub mod obs;
pub mod quant;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod search;
pub mod serve;
#[allow(missing_docs)]
pub mod tensor;
#[allow(missing_docs)]
pub mod transform;
#[allow(missing_docs)]
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! The PJRT runtime: loads the AOT HLO artifacts through the `xla` crate's
//! CPU client and executes them from the search hot path.
//!
//! Layering (DESIGN.md §5.1):
//!
//! * [`client`] — thin wrapper over `PjRtClient`: compile HLO text, move
//!   host data to device buffers, normalize outputs;
//! * [`engine`] — one model's program set (embed / layer / head /
//!   head_logits / quant_* / forward_fp / forward_q*) + device-resident
//!   weight buffers, with the layer-pipelined forward;
//! * [`evaluator`] — the search-facing incremental evaluator: prefix
//!   activation cache + per-layer act-MSE bookkeeping, so a proposal
//!   touching layer *l* re-runs only layers ≥ *l*.

pub mod client;
pub mod engine;
pub mod evaluator;

pub use client::{Program, Runtime};
pub use engine::{BatchBufs, Engine};
pub use evaluator::{Evaluator, Loss};

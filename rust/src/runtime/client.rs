//! PJRT client wrapper: HLO-text loading, compilation, host↔device
//! transfers, output normalization.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;

use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::io::manifest::ProgramInfo;
use crate::tensor::Tensor;

/// Shared PJRT CPU runtime.
pub struct Runtime {
    pub client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> crate::Result<Runtime> {
        let client = PjRtClient::cpu()?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// Compile an HLO-text program.
    pub fn load_program(&self, info: &ProgramInfo) -> crate::Result<Program> {
        let path: &Path = &info.path;
        anyhow::ensure!(path.exists(), "missing HLO artifact {}", path.display());
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t0 = std::time::Instant::now();
        let exe = self.client.compile(&comp)?;
        crate::debug!("compiled {} in {:?}", info.name, t0.elapsed());
        Ok(Program {
            name: info.name.clone(),
            n_params: info.params.len(),
            exe,
        })
    }

    /// Upload an f32 tensor with explicit dims.
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> crate::Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Upload an f32 [`Tensor`] with its natural `[rows, cols]` (or `[cols]`
    /// when `rows == 1` and `vector` is set) shape.
    pub fn buffer_tensor(&self, t: &Tensor, vector: bool) -> crate::Result<PjRtBuffer> {
        if vector {
            assert_eq!(t.rows, 1, "vector upload of a matrix");
            self.buffer_f32(&t.data, &[t.cols])
        } else {
            self.buffer_f32(&t.data, &[t.rows, t.cols])
        }
    }

    /// Upload an i32 batch `[B, T]`.
    pub fn buffer_i32(&self, data: &[i32], dims: &[usize]) -> crate::Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }
}

/// A compiled HLO program.
pub struct Program {
    pub name: String,
    pub n_params: usize,
    exe: PjRtLoadedExecutable,
}

impl Program {
    /// Execute on device buffers, returning raw output buffers.
    ///
    /// Single-output programs (lowered with `return_tuple=False`) yield one
    /// array buffer, directly usable as the next program's input.
    pub fn run_raw(&self, args: &[&PjRtBuffer]) -> crate::Result<Vec<PjRtBuffer>> {
        anyhow::ensure!(
            args.len() == self.n_params,
            "program {}: {} args, expected {}",
            self.name,
            args.len(),
            self.n_params
        );
        let mut out = self.exe.execute_b::<&PjRtBuffer>(args)?;
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "no outputs");
        Ok(out.swap_remove(0))
    }

    /// Execute and fetch all outputs as host literals, decomposing a tuple
    /// root (multi-output programs) into its elements.
    pub fn run_literals(&self, args: &[&PjRtBuffer]) -> crate::Result<Vec<Literal>> {
        let bufs = self.run_raw(args)?;
        let mut out = Vec::new();
        for b in bufs {
            let lit = b.to_literal_sync()?;
            match lit.shape()? {
                xla::Shape::Tuple(_) => out.extend(lit.to_tuple()?),
                _ => out.push(lit),
            }
        }
        Ok(out)
    }

    /// Single-array-output helper: run and keep the result on device.
    pub fn run_one(&self, args: &[&PjRtBuffer]) -> crate::Result<PjRtBuffer> {
        let mut bufs = self.run_raw(args)?;
        anyhow::ensure!(bufs.len() == 1, "program {}: expected 1 output", self.name);
        Ok(bufs.swap_remove(0))
    }
}

/// Fetch a device buffer to a host [`Tensor`], flattening leading dims.
pub fn fetch_tensor(buf: &PjRtBuffer) -> crate::Result<Tensor> {
    let lit = buf.to_literal_sync()?;
    literal_to_tensor(&lit)
}

/// Literal -> Tensor (row-major, leading dims collapsed).
pub fn literal_to_tensor(lit: &Literal) -> crate::Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims = shape.dims();
    let data = lit.to_vec::<f32>()?;
    let (rows, cols) = match dims.len() {
        0 => (1, 1),
        1 => (1, dims[0] as usize),
        n => (
            dims[..n - 1].iter().product::<i64>() as usize,
            dims[n - 1] as usize,
        ),
    };
    Ok(Tensor::from_vec(rows, cols, data))
}

/// Scalar f32 from a literal.
pub fn literal_scalar(lit: &Literal) -> crate::Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

//! One model's execution engine: compiled program set + device-resident
//! weight buffers + the layer-pipelined forward.
//!
//! Weights live on device; per search proposal only the mutated layer's
//! `up.w / up.b / down.w` buffers are refreshed — either pre-quantized on
//! the host (AWQ/OmniQuant clip search, GPTQ compensation) or routed
//! through the standalone Pallas fake-quant program on device (RTN
//! semantics, keeping the L1 kernel on the hot path).

use std::cell::RefCell;
use std::collections::HashMap;

use xla::PjRtBuffer;

use super::client::{fetch_tensor, literal_scalar, literal_to_tensor, Program, Runtime};
use crate::io::manifest::{Manifest, ModelInfo};
use crate::model::Weights;
use crate::quant::QuantScheme;
use crate::tensor::Tensor;

/// Per-layer weight-tensor base names in `layer` program argument order
/// (after the leading `x`).
const LAYER_ARG_ORDER: [&str; 16] = [
    "ln1.w", "ln1.b", "q.w", "q.b", "k.w", "k.b", "v.w", "v.b", "o.w", "o.b",
    "ln2.w", "ln2.b", "up.w", "up.b", "down.w", "down.b",
];

/// Is this parameter uploaded as a rank-1 vector (biases, LN affines)?
pub fn is_vector_param(name: &str) -> bool {
    name.ends_with(".b") || name.ends_with("ln1.w") || name.ends_with("ln2.w") || name.ends_with("lnf.w")
}

/// An uploaded evaluation batch.
pub struct BatchBufs {
    pub tokens: PjRtBuffer,
    pub targets: PjRtBuffer,
    pub mask: PjRtBuffer,
    /// Σ mask — weight of this batch when combining CE across batches.
    pub mask_sum: f64,
    /// Number of non-padding sequences.
    pub n_valid: usize,
}

pub struct Engine {
    pub rt: Runtime,
    pub info: ModelInfo,
    pub batch: usize,
    pub seq: usize,
    prog_embed: Program,
    prog_layer: Program,
    prog_head: Program,
    prog_head_logits: Program,
    /// Lazily compiled fake-quant programs keyed by (rows, cols, bits, group).
    quant_progs: RefCell<HashMap<(usize, usize, usize, usize), Program>>,
    /// Lazily compiled monolith programs (forward_fp / forward_q*).
    monoliths: RefCell<HashMap<String, Program>>,
    /// Device-resident weight buffers by canonical name.
    wbufs: HashMap<String, PjRtBuffer>,
}

impl Engine {
    /// Compile the core pipeline programs for `model` and wrap a runtime.
    pub fn load(manifest: &Manifest, model: &str) -> crate::Result<Engine> {
        let rt = Runtime::cpu()?;
        Self::load_with_runtime(rt, manifest, model)
    }

    pub fn load_with_runtime(rt: Runtime, manifest: &Manifest, model: &str) -> crate::Result<Engine> {
        let info = manifest.model(model)?.clone();
        let prog_embed = rt.load_program(info.program("embed")?)?;
        let prog_layer = rt.load_program(info.program("layer")?)?;
        let prog_head = rt.load_program(info.program("head")?)?;
        let prog_head_logits = rt.load_program(info.program("head_logits")?)?;
        Ok(Engine {
            rt,
            info,
            batch: manifest.batch,
            seq: manifest.seq,
            prog_embed,
            prog_layer,
            prog_head,
            prog_head_logits,
            quant_progs: RefCell::new(HashMap::new()),
            monoliths: RefCell::new(HashMap::new()),
            wbufs: HashMap::new(),
        })
    }

    pub fn n_layers(&self) -> usize {
        self.info.config.n_layers
    }

    // -- weights --------------------------------------------------------------

    /// Upload every parameter of `w` to device.
    pub fn upload_weights(&mut self, w: &Weights) -> crate::Result<()> {
        for (name, t) in w.in_order() {
            let buf = self.rt.buffer_tensor(t, is_vector_param(name))?;
            self.wbufs.insert(name.to_string(), buf);
        }
        Ok(())
    }

    /// Refresh one parameter's device buffer from host data.
    pub fn update_tensor(&mut self, name: &str, t: &Tensor) -> crate::Result<()> {
        let buf = self.rt.buffer_tensor(t, is_vector_param(name))?;
        self.wbufs.insert(name.to_string(), buf);
        Ok(())
    }

    /// Refresh one *weight matrix* by uploading FP values and fake-quantizing
    /// on device via the standalone Pallas kernel program (RTN semantics).
    pub fn update_tensor_device_quant(
        &mut self,
        name: &str,
        t: &Tensor,
        scheme: QuantScheme,
    ) -> crate::Result<()> {
        self.quant_program(t.rows, t.cols, scheme)?;
        let fp = self.rt.buffer_tensor(t, false)?;
        let qbuf = {
            let progs = self.quant_progs.borrow();
            progs[&(t.rows, t.cols, scheme.bits, scheme.group)].run_one(&[&fp])?
        };
        self.wbufs.insert(name.to_string(), qbuf);
        Ok(())
    }

    /// Upload one layer's searched FFN tensors (`up.w`, `up.b`, `down.w` —
    /// the only tensors a proposal touches, Eqns. 21–22).  When
    /// `device_quant` carries a scheme, the two weight matrices are routed
    /// through the standalone Pallas fake-quant program (RTN semantics);
    /// the bias always uploads as-is.
    pub fn upload_ffn(
        &mut self,
        l: usize,
        up_w: &Tensor,
        up_b: &Tensor,
        down_w: &Tensor,
        device_quant: Option<QuantScheme>,
    ) -> crate::Result<()> {
        let (up_name, down_name) = (format!("l{l}.up.w"), format!("l{l}.down.w"));
        match device_quant {
            Some(scheme) => {
                self.update_tensor_device_quant(&up_name, up_w, scheme)?;
                self.update_tensor_device_quant(&down_name, down_w, scheme)?;
            }
            None => {
                self.update_tensor(&up_name, up_w)?;
                self.update_tensor(&down_name, down_w)?;
            }
        }
        self.update_tensor(&format!("l{l}.up.b"), up_b)
    }

    /// Run the standalone Pallas fake-quant program on a host tensor and
    /// fetch the result (used by cross-check tests and the quantize CLI).
    pub fn device_fake_quant(&self, t: &Tensor, scheme: QuantScheme) -> crate::Result<Tensor> {
        self.quant_program(t.rows, t.cols, scheme)?;
        let fp = self.rt.buffer_tensor(t, false)?;
        let progs = self.quant_progs.borrow();
        let out = progs[&(t.rows, t.cols, scheme.bits, scheme.group)].run_one(&[&fp])?;
        fetch_tensor(&out)
    }

    /// Ensure the fake-quant program for this shape/scheme is compiled.
    fn quant_program(&self, rows: usize, cols: usize, scheme: QuantScheme) -> crate::Result<()> {
        let key = (rows, cols, scheme.bits, scheme.group);
        if !self.quant_progs.borrow().contains_key(&key) {
            let name = Manifest::quant_program_name(rows, cols, scheme.bits, scheme.group);
            let prog = self.rt.load_program(self.info.program(&name)?)?;
            self.quant_progs.borrow_mut().insert(key, prog);
        }
        Ok(())
    }

    pub fn weight_buffer(&self, name: &str) -> &PjRtBuffer {
        self.wbufs
            .get(name)
            .unwrap_or_else(|| panic!("weight {name:?} not uploaded"))
    }

    // -- batches --------------------------------------------------------------

    /// Upload a batch, padding to the compiled batch size `B` by repeating
    /// the last sequence with a zero mask.
    pub fn upload_batch(
        &self,
        tokens: &[Vec<i32>],
        targets: &[Vec<i32>],
        mask: &[Vec<f32>],
    ) -> crate::Result<BatchBufs> {
        let (b, t) = (self.batch, self.seq);
        anyhow::ensure!(!tokens.is_empty() && tokens.len() <= b, "bad batch size");
        anyhow::ensure!(tokens.iter().all(|s| s.len() == t), "sequences must have length T");
        let n_valid = tokens.len();

        let mut tok_flat = Vec::with_capacity(b * t);
        let mut tgt_flat = Vec::with_capacity(b * t);
        let mut msk_flat = Vec::with_capacity(b * t);
        for i in 0..b {
            let j = i.min(n_valid - 1);
            tok_flat.extend(&tokens[j]);
            tgt_flat.extend(&targets[j]);
            if i < n_valid {
                msk_flat.extend(&mask[j]);
            } else {
                msk_flat.extend(std::iter::repeat(0.0f32).take(t));
            }
        }
        let mask_sum = msk_flat.iter().map(|&m| m as f64).sum();
        Ok(BatchBufs {
            tokens: self.rt.buffer_i32(&tok_flat, &[b, t])?,
            targets: self.rt.buffer_i32(&tgt_flat, &[b, t])?,
            mask: self.rt.buffer_f32(&msk_flat, &[b, t])?,
            mask_sum,
            n_valid,
        })
    }

    // -- layer-pipelined forward ----------------------------------------------

    /// Embedding stage: tokens -> x `[B, T, D]` (device).
    pub fn embed(&self, b: &BatchBufs) -> crate::Result<PjRtBuffer> {
        self.prog_embed
            .run_one(&[&b.tokens, self.weight_buffer("emb"), self.weight_buffer("pos")])
    }

    /// One decoder block on device.
    pub fn run_layer(&self, l: usize, x: &PjRtBuffer) -> crate::Result<PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(17);
        args.push(x);
        let names: Vec<String> = LAYER_ARG_ORDER.iter().map(|b| format!("l{l}.{b}")).collect();
        for n in &names {
            args.push(self.weight_buffer(n));
        }
        self.prog_layer.run_one(&args)
    }

    /// Head: (ce over mask, per-sequence masked logprob `[B]`).
    pub fn run_head(&self, x: &PjRtBuffer, b: &BatchBufs) -> crate::Result<(f64, Vec<f32>)> {
        let outs = self.prog_head.run_literals(&[
            x,
            &b.targets,
            &b.mask,
            self.weight_buffer("emb"),
            self.weight_buffer("lnf.w"),
            self.weight_buffer("lnf.b"),
        ])?;
        anyhow::ensure!(outs.len() == 2, "head: expected 2 outputs");
        let ce = literal_scalar(&outs[0])? as f64;
        let lp = outs[1].to_vec::<f32>()?;
        Ok((ce, lp))
    }

    /// Head logits `[B*T, V]` (host tensor) — used by the serve example.
    pub fn run_logits(&self, x: &PjRtBuffer) -> crate::Result<Tensor> {
        let out = self.prog_head_logits.run_one(&[
            x,
            self.weight_buffer("emb"),
            self.weight_buffer("lnf.w"),
            self.weight_buffer("lnf.b"),
        ])?;
        fetch_tensor(&out)
    }

    /// Full pipelined forward; returns (ce, logprob, per-layer x buffers —
    /// the prefix-cache entries for the incremental evaluator).
    pub fn forward_full(
        &self,
        b: &BatchBufs,
    ) -> crate::Result<(f64, Vec<f32>, Vec<PjRtBuffer>)> {
        let embed_x = self.embed(b)?;
        let mut layer_outs: Vec<PjRtBuffer> = Vec::with_capacity(self.n_layers());
        {
            let mut cur: &PjRtBuffer = &embed_x;
            for l in 0..self.n_layers() {
                let next = self.run_layer(l, cur)?;
                layer_outs.push(next);
                cur = layer_outs.last().unwrap();
            }
        }
        let (ce, lp) = self.run_head(layer_outs.last().unwrap(), b)?;
        Ok((ce, lp, layer_outs))
    }

    /// Convenience: evaluate (ce, logprob) for host-side batch data with the
    /// currently uploaded weights.
    pub fn eval_batch(
        &self,
        tokens: &[Vec<i32>],
        targets: &[Vec<i32>],
        mask: &[Vec<f32>],
    ) -> crate::Result<(f64, Vec<f32>, f64)> {
        let b = self.upload_batch(tokens, targets, mask)?;
        let mut x = self.embed(&b)?;
        for l in 0..self.n_layers() {
            x = self.run_layer(l, &x)?;
        }
        let (ce, lp) = self.run_head(&x, &b)?;
        Ok((ce, lp[..b.n_valid].to_vec(), b.mask_sum))
    }

    // -- monolithic validation programs ----------------------------------------

    fn monolith(&self, name: &str) -> crate::Result<()> {
        if !self.monoliths.borrow().contains_key(name) {
            let prog = self.rt.load_program(self.info.program(name)?)?;
            self.monoliths.borrow_mut().insert(name.to_string(), prog);
        }
        Ok(())
    }

    fn weight_args(&self, w: &Weights) -> crate::Result<Vec<PjRtBuffer>> {
        w.in_order()
            .into_iter()
            .map(|(n, t)| self.rt.buffer_tensor(t, is_vector_param(n)))
            .collect()
    }

    /// Run the monolithic FP forward: (ce, logprob, acts `[L*B*T, D]`).
    pub fn run_forward_fp(
        &self,
        w: &Weights,
        b: &BatchBufs,
    ) -> crate::Result<(f64, Vec<f32>, Tensor)> {
        self.monolith("forward_fp")?;
        let wargs = self.weight_args(w)?;
        let monoliths = self.monoliths.borrow();
        let prog = &monoliths["forward_fp"];
        let mut args: Vec<&PjRtBuffer> = vec![&b.tokens, &b.targets, &b.mask];
        args.extend(wargs.iter());
        let outs = prog.run_literals(&args)?;
        anyhow::ensure!(outs.len() == 3, "forward_fp: expected 3 outputs");
        Ok((
            literal_scalar(&outs[0])? as f64,
            outs[1].to_vec::<f32>()?,
            literal_to_tensor(&outs[2])?,
        ))
    }

    /// Run the monolithic in-graph-Pallas quantized forward
    /// (`forward_q{bits}x{group}`): (ce, logprob, act_mse).
    pub fn run_forward_quant(
        &self,
        scheme: QuantScheme,
        w: &Weights,
        h0: &Tensor,
        b: &BatchBufs,
    ) -> crate::Result<(f64, Vec<f32>, f64)> {
        let name = format!("forward_q{}x{}", scheme.bits, scheme.group);
        self.monolith(&name)?;
        let cfg = &self.info.config;
        let h0_buf = self.rt.buffer_f32(
            &h0.data,
            &[cfg.n_layers, self.batch, self.seq, cfg.d_model],
        )?;
        let wargs = self.weight_args(w)?;
        let monoliths = self.monoliths.borrow();
        let prog = &monoliths[&name];
        let mut args: Vec<&PjRtBuffer> = vec![&b.tokens, &b.targets, &b.mask, &h0_buf];
        args.extend(wargs.iter());
        let outs = prog.run_literals(&args)?;
        anyhow::ensure!(outs.len() == 3, "{name}: expected 3 outputs");
        Ok((
            literal_scalar(&outs[0])? as f64,
            outs[1].to_vec::<f32>()?,
            literal_scalar(&outs[2])? as f64,
        ))
    }
}

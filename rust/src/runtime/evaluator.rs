//! The search-facing incremental evaluator.
//!
//! Holds the calibration batches on device, the FP activation stack H₀
//! (Eqn. 23), and — for the currently *accepted* model state — a prefix
//! activation cache: the per-batch, per-layer block outputs.  A proposal
//! touching layer *l* then re-runs only layers `l..L` plus the head, and
//! act-MSE contributions of layers `< l` are reused (their inputs and
//! weights are unchanged).
//!
//! CE across batches is combined mask-weighted (each batch's head already
//! averages over its own mask).

use xla::PjRtBuffer;

use super::client::fetch_tensor;
use super::engine::{BatchBufs, Engine};
use crate::calib::CalibSet;
use crate::tensor::Tensor;

/// The two-term search objective (Eqn. 23), pre-α.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Loss {
    pub ce: f64,
    pub act_mse: f64,
}

impl Loss {
    pub fn total(&self, alpha: f64) -> f64 {
        self.ce + alpha * self.act_mse
    }
}

/// Result of evaluating a proposal, holdable until accept/reject.
pub struct Pending {
    pub loss: Loss,
    from_layer: usize,
    /// Recomputed x buffers for layers `from_layer..L`, per batch.
    new_x: Vec<Vec<PjRtBuffer>>,
    /// Recomputed per-layer act-MSE sums for layers `from_layer..L`, per batch.
    new_mse: Vec<Vec<f64>>,
}

/// Round-shared prefix for cold-cache batched evaluation: per-batch block
/// outputs of layers `0..l_max` plus their act-MSE contributions, computed
/// once per round with the accepted weights.
struct SharedPrefix {
    x: Vec<Vec<PjRtBuffer>>,
    mse: Vec<Vec<f64>>,
}

pub struct Evaluator {
    pub engine: Engine,
    batches: Vec<BatchBufs>,
    /// H₀ per batch per layer `[B*T, D]` (host) — empty until captured.
    h0: Vec<Vec<Tensor>>,
    /// Layers whose activations contribute to the MSE term (Table 4).
    match_layers: Vec<usize>,
    /// Accepted-state prefix cache: per batch, per layer block output.
    cache_x: Vec<Vec<PjRtBuffer>>,
    /// Accepted-state per-batch per-layer act-MSE.
    mse: Vec<Vec<f64>>,
    /// Accepted-state loss.
    pub accepted: Loss,
}

impl Evaluator {
    /// Upload calibration batches.  `match_layers` selects the activation-
    /// matching subset (empty = CE-only objective, Table 4 row "0 layers").
    pub fn new(engine: Engine, calib: &CalibSet, match_layers: Vec<usize>) -> crate::Result<Evaluator> {
        let batch = engine.batch;
        let mut batches = Vec::new();
        for chunk in calib.chunks(batch) {
            batches.push(engine.upload_batch(&chunk.tokens, &chunk.targets, &chunk.masks)?);
        }
        for &l in &match_layers {
            anyhow::ensure!(l < engine.n_layers(), "match layer {l} out of range");
        }
        Ok(Evaluator {
            engine,
            batches,
            h0: Vec::new(),
            match_layers,
            cache_x: Vec::new(),
            mse: Vec::new(),
            accepted: Loss { ce: f64::INFINITY, act_mse: 0.0 },
        })
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    pub fn match_layers(&self) -> &[usize] {
        &self.match_layers
    }

    /// Bytes of host memory held by H₀ (the Table-4 "extra memory" column).
    pub fn h0_bytes(&self) -> usize {
        self.h0
            .iter()
            .flat_map(|per_batch| per_batch.iter())
            .map(|t| t.numel() * 4)
            .sum()
    }

    /// Capture H₀ with the *currently uploaded* (FP) weights.  Only the
    /// matched layers are stored (the paper's memory-limit discussion).
    pub fn capture_h0(&mut self) -> crate::Result<f64> {
        self.h0.clear();
        let mut ce_num = 0.0;
        let mut ce_den = 0.0;
        for b in &self.batches {
            let (ce, _, xs) = self.engine.forward_full(b)?;
            let mut per_layer = vec![Tensor::zeros(0, 0); self.engine.n_layers()];
            for &l in &self.match_layers {
                per_layer[l] = fetch_tensor(&xs[l])?;
            }
            self.h0.push(per_layer);
            ce_num += ce * b.mask_sum;
            ce_den += b.mask_sum;
        }
        Ok(ce_num / ce_den.max(1.0))
    }

    /// Full (non-incremental) evaluation with the currently uploaded
    /// weights; rebuilds the prefix cache and sets the accepted state.
    pub fn full_eval(&mut self) -> crate::Result<Loss> {
        let pending = self.eval_from_layer(0)?;
        let loss = pending.loss;
        self.accept(pending);
        Ok(loss)
    }

    /// Evaluate the current device weights assuming only layers
    /// `>= from_layer` changed since the accepted state.
    pub fn eval_from_layer(&mut self, from_layer: usize) -> crate::Result<Pending> {
        self.eval_inner(from_layer, None)
    }

    /// Score a round of proposal candidates, each mutating a *distinct*
    /// layer, independently against the accepted state.
    ///
    /// `swap_in(engine, i)` must upload candidate `i`'s tensors and
    /// `swap_out(engine, i)` must restore that layer's accepted tensors;
    /// the engine therefore holds the accepted weights again when this
    /// returns, and each candidate was scored in isolation.
    ///
    /// The shared prefix — every layer below a candidate's mutation point —
    /// is never recomputed per candidate: with a warm accepted-state cache
    /// it is read from `cache_x`; with a cold cache (no accept yet) it is
    /// computed **once per round** up to the highest candidate layer and
    /// shared by all candidates, instead of once per proposal.  (In the
    /// shipped pipeline the cache is always warm — `init` ends in a full
    /// evaluation — so the cold path serves drivers that score rounds
    /// before a first full eval; committing such a pending falls back to
    /// `full_eval`, see [`Evaluator::can_accept`].)
    pub fn eval_proposals<FI, FO>(
        &mut self,
        layers: &[usize],
        mut swap_in: FI,
        mut swap_out: FO,
    ) -> crate::Result<Vec<Pending>>
    where
        FI: FnMut(&mut Engine, usize) -> crate::Result<()>,
        FO: FnMut(&mut Engine, usize) -> crate::Result<()>,
    {
        let n_layers = self.engine.n_layers();
        let mut seen = vec![false; n_layers];
        for &l in layers {
            anyhow::ensure!(l < n_layers, "proposal layer {l} out of range");
            anyhow::ensure!(!seen[l], "round candidates must mutate distinct layers (dup {l})");
            seen[l] = true;
        }

        let shared = if self.cache_x.is_empty() && layers.iter().any(|&l| l > 0) {
            Some(self.compute_shared_prefix(layers.iter().copied().max().unwrap_or(0))?)
        } else {
            None
        };

        let mut out = Vec::with_capacity(layers.len());
        for (i, &l) in layers.iter().enumerate() {
            // restore the accepted tensors even when the upload or the eval
            // failed, so an error cannot leave candidate weights (or a
            // partial mix from a mid-upload failure) on device
            let evaled = match swap_in(&mut self.engine, i) {
                Ok(()) => self.eval_inner(l, shared.as_ref()),
                Err(e) => Err(e),
            };
            swap_out(&mut self.engine, i)?;
            out.push(evaled?);
        }
        Ok(out)
    }

    /// Run embed + layers `0..l_max` once with the currently uploaded
    /// (accepted) weights — the cold-cache shared prefix of one round.
    fn compute_shared_prefix(&self, l_max: usize) -> crate::Result<SharedPrefix> {
        let mut x = Vec::with_capacity(self.batches.len());
        let mut mse = Vec::with_capacity(self.batches.len());
        for (bi, b) in self.batches.iter().enumerate() {
            let mut xs: Vec<PjRtBuffer> = Vec::with_capacity(l_max);
            let embed_x = self.engine.embed(b)?;
            let mut cur: &PjRtBuffer = &embed_x;
            for l in 0..l_max {
                let next = self.engine.run_layer(l, cur)?;
                xs.push(next);
                cur = xs.last().unwrap();
            }
            let mut mse_layer = vec![0.0f64; l_max];
            if !self.h0.is_empty() {
                for &l in &self.match_layers {
                    if l < l_max {
                        let xh = fetch_tensor(&xs[l])?;
                        mse_layer[l] = xh.mse(&self.h0[bi][l]);
                    }
                }
            }
            x.push(xs);
            mse.push(mse_layer);
        }
        Ok(SharedPrefix { x, mse })
    }

    /// Core incremental evaluation.  The prefix (layers `< from_layer`)
    /// comes from the accepted cache, or from `shared` when the cache is
    /// cold (round-shared prefix).
    fn eval_inner(
        &self,
        from_layer: usize,
        shared: Option<&SharedPrefix>,
    ) -> crate::Result<Pending> {
        let n_layers = self.engine.n_layers();
        anyhow::ensure!(from_layer <= n_layers, "from_layer out of range");
        let use_cache = from_layer > 0 && !self.cache_x.is_empty();

        let mut ce_num = 0.0;
        let mut ce_den = 0.0;
        let mut new_x: Vec<Vec<PjRtBuffer>> = Vec::with_capacity(self.batches.len());
        let mut new_mse: Vec<Vec<f64>> = Vec::with_capacity(self.batches.len());

        for (bi, b) in self.batches.iter().enumerate() {
            let mut xs: Vec<PjRtBuffer> = Vec::with_capacity(n_layers - from_layer);
            {
                // starting activation: embed (l=0), cached prefix, or the
                // round-shared prefix
                let embed_x;
                let mut cur: &PjRtBuffer = if from_layer == 0 {
                    embed_x = self.engine.embed(b)?;
                    &embed_x
                } else if use_cache {
                    &self.cache_x[bi][from_layer - 1]
                } else if let Some(pre) = shared {
                    &pre.x[bi][from_layer - 1]
                } else {
                    // cannot start mid-model without a prefix
                    anyhow::bail!("eval_from_layer({from_layer}) without prefix cache");
                };
                for l in from_layer..n_layers {
                    let next = self.engine.run_layer(l, cur)?;
                    xs.push(next);
                    cur = xs.last().unwrap();
                }
            }
            let (ce, _lp) = self.engine.run_head(xs.last().unwrap(), b)?;
            ce_num += ce * b.mask_sum;
            ce_den += b.mask_sum;

            // act-MSE for recomputed matched layers
            let mut mse_layer = vec![0.0f64; n_layers - from_layer];
            if !self.h0.is_empty() {
                for &l in &self.match_layers {
                    if l >= from_layer {
                        let xh = fetch_tensor(&xs[l - from_layer])?;
                        mse_layer[l - from_layer] = xh.mse(&self.h0[bi][l]);
                    }
                }
            }
            new_x.push(xs);
            new_mse.push(mse_layer);
        }

        // combine: reused prefix MSE + recomputed suffix MSE
        let mut act_mse = 0.0;
        if !self.match_layers.is_empty() && !self.h0.is_empty() {
            let mut total = 0.0;
            for bi in 0..self.batches.len() {
                for &l in &self.match_layers {
                    total += if l >= from_layer {
                        new_mse[bi][l - from_layer]
                    } else if use_cache {
                        self.mse[bi][l]
                    } else if let Some(pre) = shared {
                        pre.mse[bi][l]
                    } else {
                        0.0
                    };
                }
            }
            act_mse = total / (self.batches.len() * self.match_layers.len()) as f64;
        }

        Ok(Pending {
            loss: Loss { ce: ce_num / ce_den.max(1.0), act_mse },
            from_layer,
            new_x,
            new_mse,
        })
    }

    /// Can `p` be committed by splicing into the prefix cache?  False only
    /// for a mid-model pending produced against a cold cache (the round-
    /// shared-prefix path): its buffers cover layers `from_layer..L` only,
    /// so the committer must fall back to a full evaluation instead of
    /// [`Evaluator::accept`].
    pub fn can_accept(&self, p: &Pending) -> bool {
        !self.cache_x.is_empty() || p.from_layer == 0
    }

    /// Commit a pending evaluation: splice its buffers into the prefix cache.
    pub fn accept(&mut self, p: Pending) {
        let n_layers = self.engine.n_layers();
        if self.cache_x.is_empty() {
            assert_eq!(p.from_layer, 0, "first accept must be a full eval");
            self.cache_x = p.new_x;
            self.mse = p.new_mse;
        } else {
            for (bi, xs) in p.new_x.into_iter().enumerate() {
                for (off, x) in xs.into_iter().enumerate() {
                    self.cache_x[bi][p.from_layer + off] = x;
                }
            }
            for (bi, ms) in p.new_mse.into_iter().enumerate() {
                for (off, m) in ms.into_iter().enumerate() {
                    self.mse[bi][p.from_layer + off] = m;
                }
            }
        }
        debug_assert!(self.cache_x.iter().all(|xs| xs.len() == n_layers));
        self.accepted = p.loss;
    }
}

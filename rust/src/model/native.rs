//! Native (pure-Rust) forward pass of the OPT-style decoder.
//!
//! Two roles (DESIGN.md §5.3):
//!
//! 1. **Oracle** — an implementation of exactly the same math as the L2 JAX
//!    model, used by integration tests to pin the HLO programs' numerics.
//! 2. **Calibration tap** — captures the *inputs of every linear layer* on
//!    the calibration set, which the GPTQ/AWQ/OmniQuant baselines need
//!    (Hessians `2XXᵀ`, per-channel activation magnitudes) and which the
//!    XLA programs do not expose.
//!
//! The search hot path does NOT go through this module — it runs the AOT
//! XLA artifacts (see [`crate::runtime`]).  Sequences in a batch are
//! independent (causal attention within each sequence), so the batch loop
//! parallelizes over the thread pool.
//!
//! 3. **Serving substrate** (PR 2) — the incremental-decode path
//!    ([`KvCache`], [`prefill`], [`decode_step`]) that [`crate::serve`]
//!    drives, abstracted over [`DecoderParams`] so the same forward runs on
//!    dense [`Weights`] or directly on the bit-packed deployment form
//!    ([`crate::serve::PackedModel`]) without densifying it.

use std::sync::Arc;

use super::config::OptConfig;
use super::Weights;
use crate::tensor::ops::{self, layer_norm, linear, log_prob_at, relu, softmax_rows};
use crate::tensor::Tensor;
use crate::util::pool;

/// What to capture during a forward pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct Capture {
    /// Post-block residual stream per layer (the H of Eqn. 23).
    pub hidden: bool,
    /// Inputs to every linear layer (for baseline calibration).
    pub linear_inputs: bool,
    /// Final-position logits (for greedy generation in the serve example).
    pub last_logits: bool,
}

/// Captured per-layer linear inputs for one sequence batch, flattened to
/// `[B*T, in_features]`.
#[derive(Debug, Clone)]
pub struct LayerInputs {
    /// Input to q/k/v projections (post-LN1 hidden).
    pub qkv_in: Tensor,
    /// Input to the output projection (concatenated attention output).
    pub o_in: Tensor,
    /// Input to W_up (post-LN2 hidden).
    pub up_in: Tensor,
    /// Input to W_down (ReLU activations) — the paper's FFN hidden.
    pub down_in: Tensor,
}

/// Forward results over a batch of sequences.
#[derive(Debug, Clone)]
pub struct ForwardOutput {
    /// Mean CE over masked positions (natural log).
    pub ce: f64,
    /// Per-sequence summed masked log-prob (the reasoning-eval score).
    pub seq_logprob: Vec<f32>,
    /// Per-layer hidden stacks `[B*T, D]` (empty unless captured).
    pub hidden: Vec<Tensor>,
    /// Per-layer linear inputs (empty unless captured).
    pub linear_inputs: Vec<LayerInputs>,
    /// `[B, vocab]` logits at each sequence's last position (if captured).
    pub last_logits: Vec<Vec<f32>>,
}

/// One sequence's intermediate results.
struct SeqResult {
    ce_sum: f64,
    n_masked: f64,
    logprob: f32,
    hidden: Vec<Tensor>,
    inputs: Vec<LayerInputs>,
    last_logits: Vec<f32>,
}

/// Run the model over `B` sequences of equal length with per-token masks.
///
/// `mask[b][t] == 1.0` marks positions contributing to CE / seq-logprob.
pub fn forward(
    w: &Weights,
    tokens: &[Vec<i32>],
    targets: &[Vec<i32>],
    mask: &[Vec<f32>],
    capture: Capture,
) -> ForwardOutput {
    assert_eq!(tokens.len(), targets.len());
    assert_eq!(tokens.len(), mask.len());
    let threads = pool::num_threads();
    let results: Vec<SeqResult> = pool::parallel_map(tokens.len(), threads, |b| {
        forward_seq(w, &tokens[b], &targets[b], &mask[b], capture)
    });

    let cfg = &w.config;
    let total_ce: f64 = results.iter().map(|r| r.ce_sum).sum();
    let total_masked: f64 = results.iter().map(|r| r.n_masked).sum::<f64>().max(1.0);

    let mut hidden = Vec::new();
    let mut linear_inputs = Vec::new();
    if capture.hidden {
        for l in 0..cfg.n_layers {
            hidden.push(concat_rows(results.iter().map(|r| &r.hidden[l])));
        }
    }
    if capture.linear_inputs {
        for l in 0..cfg.n_layers {
            linear_inputs.push(LayerInputs {
                qkv_in: concat_rows(results.iter().map(|r| &r.inputs[l].qkv_in)),
                o_in: concat_rows(results.iter().map(|r| &r.inputs[l].o_in)),
                up_in: concat_rows(results.iter().map(|r| &r.inputs[l].up_in)),
                down_in: concat_rows(results.iter().map(|r| &r.inputs[l].down_in)),
            });
        }
    }
    ForwardOutput {
        ce: total_ce / total_masked,
        seq_logprob: results.iter().map(|r| r.logprob).collect(),
        hidden,
        linear_inputs,
        last_logits: if capture.last_logits {
            results.into_iter().map(|r| r.last_logits).collect()
        } else {
            Vec::new()
        },
    }
}

fn concat_rows<'a>(parts: impl Iterator<Item = &'a Tensor>) -> Tensor {
    let parts: Vec<&Tensor> = parts.collect();
    let cols = parts[0].cols;
    let rows: usize = parts.iter().map(|t| t.rows).sum();
    let mut out = Tensor::zeros(rows, cols);
    let mut r = 0;
    for p in parts {
        assert_eq!(p.cols, cols);
        out.data[r * cols..(r + p.rows) * cols].copy_from_slice(&p.data);
        r += p.rows;
    }
    out
}

fn forward_seq(
    w: &Weights,
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    capture: Capture,
) -> SeqResult {
    let cfg = &w.config;
    let t_len = tokens.len();
    assert!(t_len <= cfg.max_seq, "sequence longer than max_seq");

    // embed + positions
    let emb = w.get("emb");
    let pos = w.get("pos");
    let mut x = Tensor::zeros(t_len, cfg.d_model);
    for (t, &tok) in tokens.iter().enumerate() {
        let row = emb.row(tok as usize);
        let prow = pos.row(t);
        let dst = x.row_mut(t);
        for c in 0..cfg.d_model {
            dst[c] = row[c] + prow[c];
        }
    }

    let mut hidden = Vec::new();
    let mut inputs = Vec::new();
    for l in 0..cfg.n_layers {
        let (x2, layer_inputs) = block(w, l, &x, capture.linear_inputs);
        x = x2;
        if capture.hidden {
            hidden.push(x.clone());
        }
        if let Some(li) = layer_inputs {
            inputs.push(li);
        }
    }

    // final LN + tied head
    let h = layer_norm(&x, w.bias("lnf.w"), w.bias("lnf.b"));
    // logits [T, V] = h @ emb^T
    let mut logits = Tensor::zeros(t_len, cfg.vocab);
    ops::matmul_nt_par(&h.data, &emb.data, t_len, cfg.d_model, cfg.vocab, &mut logits.data);

    let mut ce_sum = 0.0f64;
    let mut n_masked = 0.0f64;
    let mut logprob = 0.0f32;
    for t in 0..t_len {
        if mask[t] > 0.0 {
            let lp = log_prob_at(logits.row(t), targets[t] as usize);
            ce_sum += -(lp as f64) * mask[t] as f64;
            n_masked += mask[t] as f64;
            logprob += lp * mask[t];
        }
    }

    SeqResult {
        ce_sum,
        n_masked,
        logprob,
        hidden,
        inputs,
        last_logits: if capture.last_logits {
            logits.row(t_len - 1).to_vec()
        } else {
            Vec::new()
        },
    }
}

/// One decoder block; optionally returns the captured linear inputs.
fn block(w: &Weights, l: usize, x: &Tensor, cap: bool) -> (Tensor, Option<LayerInputs>) {
    let cfg = &w.config;
    let (t_len, d) = x.shape();
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();

    // -- attention half ------------------------------------------------------
    let h = layer_norm(
        x,
        &w.layer(l, "ln1.w").data,
        &w.layer(l, "ln1.b").data,
    );
    let q = linear(&h, w.layer(l, "q.w"), &w.layer(l, "q.b").data);
    let k = linear(&h, w.layer(l, "k.w"), &w.layer(l, "k.b").data);
    let v = linear(&h, w.layer(l, "v.w"), &w.layer(l, "v.b").data);

    let mut attn_out = Tensor::zeros(t_len, d);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut qh = Tensor::zeros(t_len, hd);
    let mut kh = Tensor::zeros(t_len, hd);
    let mut vh = Tensor::zeros(t_len, hd);
    for head in 0..heads {
        let c0 = head * hd;
        for t in 0..t_len {
            qh.row_mut(t).copy_from_slice(&q.row(t)[c0..c0 + hd]);
            kh.row_mut(t).copy_from_slice(&k.row(t)[c0..c0 + hd]);
            vh.row_mut(t).copy_from_slice(&v.row(t)[c0..c0 + hd]);
        }
        // scores [T, T] with causal mask
        let mut scores = Tensor::zeros(t_len, t_len);
        ops::matmul_nt(&qh.data, &kh.data, t_len, hd, t_len, &mut scores.data);
        for t in 0..t_len {
            let row = scores.row_mut(t);
            for (c, val) in row.iter_mut().enumerate() {
                *val = if c <= t { *val * scale } else { -1e30 };
            }
        }
        softmax_rows(&mut scores);
        // out_h [T, hd] = scores @ vh  (vh is [T, hd]; need N-layout matmul)
        for t in 0..t_len {
            let srow = scores.row(t);
            let orow = &mut attn_out.row_mut(t)[c0..c0 + hd];
            for (s, vrow) in srow.iter().zip(0..t_len) {
                if *s == 0.0 {
                    continue;
                }
                let vr = vh.row(vrow);
                for c in 0..hd {
                    orow[c] += s * vr[c];
                }
            }
        }
    }
    let o = linear(&attn_out, w.layer(l, "o.w"), &w.layer(l, "o.b").data);
    let mut x1 = x.clone();
    ops::add_assign(&mut x1, &o);

    // -- FFN half (the invariance site) --------------------------------------
    let h2 = layer_norm(
        &x1,
        &w.layer(l, "ln2.w").data,
        &w.layer(l, "ln2.b").data,
    );
    let mut u = linear(&h2, w.layer(l, "up.w"), &w.layer(l, "up.b").data);
    relu(&mut u);
    let down = linear(&u, w.layer(l, "down.w"), &w.layer(l, "down.b").data);
    let mut x2 = x1;
    ops::add_assign(&mut x2, &down);

    let captured = if cap {
        Some(LayerInputs {
            qkv_in: h,
            o_in: attn_out,
            up_in: h2,
            down_in: u,
        })
    } else {
        None
    };
    (x2, captured)
}

// ---------------------------------------------------------------------------
// Incremental decoding (the serving path)
// ---------------------------------------------------------------------------

/// Parameter source for the incremental decoder forward: dense [`Weights`]
/// or the packed deployment form.  `Sync` so independent sequences can
/// decode in parallel against one shared parameter set.
pub trait DecoderParams: Sync {
    fn config(&self) -> &OptConfig;
    /// Dense named tensor (embeddings, positions, LayerNorm params, biases).
    fn dense(&self, name: &str) -> &Tensor;
    /// `x @ W^T + b` for the layer-`l` linear `base` ∈ {q, k, v, o, up, down}.
    fn linear(&self, l: usize, base: &str, x: &Tensor) -> Tensor;
    /// Multi-row variant of [`DecoderParams::linear`] for call sites that
    /// feed a whole chunk of activation rows at once (chunked verify,
    /// batched prefill).  **Bit-identical to `linear` by contract** — it
    /// exists so the packed implementation can route to the cache-blocked
    /// GEMM ([`crate::quant::PackedTensor::linear_batch`]), which
    /// dequantizes each weight tile once for all rows instead of once per
    /// row.  Dense weights already stream `W` once per call, so the
    /// default just delegates.
    fn linear_batch(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        self.linear(l, base, x)
    }
}

impl DecoderParams for Weights {
    fn config(&self) -> &OptConfig {
        &self.config
    }

    fn dense(&self, name: &str) -> &Tensor {
        self.get(name)
    }

    fn linear(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        let w = self.layer(l, &format!("{base}.w"));
        let b = self.layer(l, &format!("{base}.b"));
        linear(x, w, &b.data)
    }
}

/// Positions per KV page (see [`KvCache`]).
pub const KV_PAGE: usize = 16;

/// Channels per quantized-KV scale group: each cached row stores one amax
/// scale per `min(d_model, KV_SCALE_GROUP)` channels (see [`KvDtype`]).
pub const KV_SCALE_GROUP: usize = 64;

/// Storage precision of the KV cache (the `--kv-dtype` serving knob).
///
/// `F32` is the default and keeps every existing bit-identity pin intact —
/// rows are stored exactly as computed.  The quantized modes trade bounded
/// reconstruction error for residency: rows are quantized symmetrically on
/// [`KvCache::put`] with one amax scale per [`KV_SCALE_GROUP`]-channel
/// group (`scale = amax / qmax`, `q = round(x / scale)` clamped to
/// `±qmax`), and dequantized page-wise into a reused scratch buffer on the
/// attention gather.  **Documented error bound**: per element,
/// `|x - x̂| ≤ amax / (2·qmax)` with amax taken over the element's
/// (row, scale-group) — qmax = 127 for `Int8` (≈0.4% of the group's peak)
/// and 7 for `Int4` (≈7%).  Quantization is deterministic, so every
/// fork/truncate/replay invariant still holds bit-identically *within* a
/// dtype (pinned by `prop_fork_append_truncate_roundtrips_under_int8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// Full precision (default): fully bit-identical serving.
    #[default]
    F32,
    /// 8-bit symmetric, one byte per channel plus grouped f32 scales:
    /// ~3.6× lower page residency at ≤ amax/254 per-element error.
    Int8,
    /// 4-bit symmetric, two channels per byte (low nibble first): ~6.4×
    /// lower residency at ≤ amax/14 per-element error; requires an even
    /// `d_model`.
    Int4,
}

impl KvDtype {
    /// Parse the CLI/env spelling (`f32` | `int8` | `int4`).
    pub fn parse(s: &str) -> crate::Result<KvDtype> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(KvDtype::F32),
            "int8" | "i8" => Ok(KvDtype::Int8),
            "int4" | "i4" => Ok(KvDtype::Int4),
            _ => anyhow::bail!("unknown kv dtype {s:?} (f32|int8|int4)"),
        }
    }

    /// Metrics / log label.
    pub fn label(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
            KvDtype::Int4 => "int4",
        }
    }

    /// Largest code magnitude of the symmetric grid.
    fn qmax(self) -> f32 {
        match self {
            KvDtype::F32 => unreachable!("f32 KV rows are not quantized"),
            KvDtype::Int8 => 127.0,
            KvDtype::Int4 => 7.0,
        }
    }
}

/// One KV page: [`KV_PAGE`] rows of `d_model` channels in the cache's
/// dtype.  `Clone` backs the `Arc::make_mut` copy-on-write that
/// [`KvCache::fork_at`] relies on.
#[derive(Clone)]
enum Page {
    /// Rows stored verbatim (`KV_PAGE * d_model` floats).
    F32(Vec<f32>),
    /// Symmetric-quantized rows: `codes` holds `KV_PAGE * d_model` bytes
    /// for `Int8` (one i8 per channel) or half that for `Int4` (two
    /// channels per byte, low nibble first, biased by +7); `scales` holds
    /// one f32 per (row, scale-group).
    Quant { codes: Vec<u8>, scales: Vec<f32> },
}

impl Page {
    fn blank(dtype: KvDtype, d: usize, n_sg: usize) -> Page {
        match dtype {
            KvDtype::F32 => Page::F32(vec![0.0; KV_PAGE * d]),
            KvDtype::Int8 => Page::Quant {
                codes: vec![0; KV_PAGE * d],
                scales: vec![0.0; KV_PAGE * n_sg],
            },
            KvDtype::Int4 => Page::Quant {
                codes: vec![0; KV_PAGE * d / 2],
                scales: vec![0.0; KV_PAGE * n_sg],
            },
        }
    }

    /// Quantize (or copy) one `d`-channel row into page-row `row`.
    fn store_row(&mut self, row: usize, x: &[f32], dtype: KvDtype, sg: usize) {
        let d = x.len();
        match self {
            Page::F32(p) => p[row * d..(row + 1) * d].copy_from_slice(x),
            Page::Quant { codes, scales } => {
                let n_sg = d.div_ceil(sg);
                let qmax = dtype.qmax();
                let srow = &mut scales[row * n_sg..(row + 1) * n_sg];
                for (g, chunk) in x.chunks(sg).enumerate() {
                    let amax = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    srow[g] = amax / qmax;
                }
                let quant = |c: usize| -> f32 {
                    let s = srow[c / sg];
                    if s > 0.0 {
                        (x[c] / s).round().clamp(-qmax, qmax)
                    } else {
                        0.0
                    }
                };
                match dtype {
                    KvDtype::Int8 => {
                        let crow = &mut codes[row * d..(row + 1) * d];
                        for (c, code) in crow.iter_mut().enumerate() {
                            // CLAMPED: quant() clamps to [-qmax, qmax] =
                            // [-127, 127], exact in i8; `as u8` is the
                            // intended two's-complement byte reinterpret,
                            // inverted by `as i8` in load_rows.
                            *code = quant(c) as i8 as u8;
                        }
                    }
                    KvDtype::Int4 => {
                        let crow = &mut codes[row * (d / 2)..(row + 1) * (d / 2)];
                        for (i, byte) in crow.iter_mut().enumerate() {
                            // CLAMPED: quant() clamps to [-qmax, qmax] =
                            // [-7, 7], so the +7 bias lands in [0, 14] —
                            // a valid nibble.
                            let lo = (quant(2 * i) as i32 + 7) as u8;
                            let hi = (quant(2 * i + 1) as i32 + 7) as u8; // CLAMPED: see lo
                            *byte = lo | (hi << 4);
                        }
                    }
                    KvDtype::F32 => unreachable!(),
                }
            }
        }
    }

    /// Dequantize (or copy) the first `rows` rows into `out` (`[rows, d]`).
    fn load_rows(&self, rows: usize, d: usize, dtype: KvDtype, sg: usize, out: &mut [f32]) {
        match self {
            Page::F32(p) => out[..rows * d].copy_from_slice(&p[..rows * d]),
            Page::Quant { codes, scales } => {
                let n_sg = d.div_ceil(sg);
                for r in 0..rows {
                    let srow = &scales[r * n_sg..(r + 1) * n_sg];
                    let orow = &mut out[r * d..(r + 1) * d];
                    match dtype {
                        KvDtype::Int8 => {
                            let crow = &codes[r * d..(r + 1) * d];
                            for (c, (o, &b)) in orow.iter_mut().zip(crow).enumerate() {
                                // CLAMPED: `as i8` is the sign-restoring
                                // reinterpret of the byte written by
                                // store_rows, then widened — no truncation.
                                *o = (b as i8) as f32 * srow[c / sg];
                            }
                        }
                        KvDtype::Int4 => {
                            let crow = &codes[r * (d / 2)..(r + 1) * (d / 2)];
                            for (c, o) in orow.iter_mut().enumerate() {
                                let nib = (crow[c / 2] >> (4 * (c % 2))) & 0xF;
                                *o = (nib as i32 - 7) as f32 * srow[c / sg];
                            }
                        }
                        KvDtype::F32 => unreachable!(),
                    }
                }
            }
        }
    }
}

/// Per-sequence key/value cache with **chunked page allocation**: each layer
/// holds a list of refcounted pages of [`KV_PAGE`] positions, allocated on
/// demand as tokens are fed — a short sequence holds
/// `ceil(len / KV_PAGE)` pages instead of the eager `[max_seq, d_model]`
/// store the PR-2 cache allocated up front (see [`KvCache::eager_bytes`]).
///
/// Pages are `Arc`-shared, which gives two copy-on-write operations the
/// serving layer builds on:
///
/// * [`KvCache::fork_at`] — O(pages) snapshot of a prefix; the fork shares
///   every page with its parent, and either side clones a page lazily the
///   first time it writes to a shared one (`Arc::make_mut`).  This is what
///   the radix-trie prefix cache (`serve::prefix`) hands out on a hit, so
///   requests sharing a prompt prefix skip the shared portion of prefill.
/// * [`KvCache::truncate`] — roll the sequence back to an earlier position
///   (speculative decoding / retry paths), dropping now-unreferenced pages.
///
/// Feeding tokens through [`forward_cached`] appends to the cache, so each
/// new token costs O(len) instead of the O(len²) full-context re-forward
/// the serve example used to do.
pub struct KvCache {
    /// `k[layer][page]` — each page holds [`KV_PAGE`] rows in `dtype`.
    k: Vec<Vec<Arc<Page>>>,
    v: Vec<Vec<Arc<Page>>>,
    len: usize,
    max_seq: usize,
    d_model: usize,
    dtype: KvDtype,
    /// Channels per quantized scale group: `min(d_model, KV_SCALE_GROUP)`.
    scale_group: usize,
}

impl KvCache {
    /// Full-precision cache — the default everywhere; fully bit-identical.
    pub fn new(cfg: &OptConfig) -> KvCache {
        Self::with_dtype(cfg, KvDtype::F32)
    }

    /// Cache storing K/V rows at `dtype` (see [`KvDtype`] for the
    /// error/residency trade and the documented per-element bound).
    pub fn with_dtype(cfg: &OptConfig, dtype: KvDtype) -> KvCache {
        assert!(
            dtype != KvDtype::Int4 || cfg.d_model % 2 == 0,
            "Int4 KV packs two channels per byte and needs an even d_model"
        );
        KvCache {
            k: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
            v: (0..cfg.n_layers).map(|_| Vec::new()).collect(),
            len: 0,
            max_seq: cfg.max_seq,
            d_model: cfg.d_model,
            dtype,
            scale_group: cfg.d_model.min(KV_SCALE_GROUP),
        }
    }

    /// Storage precision of this cache's pages.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions left before the compiled context length is exhausted.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Reset for a new sequence, releasing all pages — so
    /// [`KvCache::allocated_bytes`] / [`KvCache::page_refs`] never report a
    /// previous sequence's pages as resident (the live-KV gauge in
    /// `serve::metrics` is built on them).
    pub fn clear(&mut self) {
        for ps in self.k.iter_mut().chain(self.v.iter_mut()) {
            ps.clear();
        }
        self.len = 0;
    }

    /// Key row of `pos` at layer `l` (must be `< len`, or freshly written).
    /// In-place f32 read — quantized caches must use [`KvCache::gather_k`].
    #[inline]
    pub fn k_row(&self, l: usize, pos: usize) -> &[f32] {
        Self::f32_row(&self.k[l], pos, self.d_model)
    }

    /// Value row of `pos` at layer `l` (f32 caches only, like `k_row`).
    #[inline]
    pub fn v_row(&self, l: usize, pos: usize) -> &[f32] {
        Self::f32_row(&self.v[l], pos, self.d_model)
    }

    #[inline]
    fn f32_row(pages: &[Arc<Page>], pos: usize, d: usize) -> &[f32] {
        let off = (pos % KV_PAGE) * d;
        match &*pages[pos / KV_PAGE] {
            Page::F32(p) => &p[off..off + d],
            Page::Quant { .. } => {
                panic!("k_row/v_row on a quantized KV cache; use gather_k/gather_v")
            }
        }
    }

    /// Materialize rows `0..n` of layer `l`'s keys into `out` (`[n,
    /// d_model]` row-major), dequantizing page-wise — the quantized modes'
    /// attention read: one dequant pass per layer per chunk into a reused
    /// scratch buffer, instead of per-access dequant.  Valid for f32 too
    /// (a straight copy), but [`forward_hidden`]'s f32 path reads rows in
    /// place instead.
    pub fn gather_k(&self, l: usize, n: usize, out: &mut [f32]) {
        Self::gather(&self.k[l], n, self.d_model, self.dtype, self.scale_group, out);
    }

    /// Materialize rows `0..n` of layer `l`'s values (see `gather_k`).
    pub fn gather_v(&self, l: usize, n: usize, out: &mut [f32]) {
        Self::gather(&self.v[l], n, self.d_model, self.dtype, self.scale_group, out);
    }

    fn gather(pages: &[Arc<Page>], n: usize, d: usize, dtype: KvDtype, sg: usize, out: &mut [f32]) {
        assert!(out.len() >= n * d, "KV gather scratch too small");
        let mut done = 0usize;
        for page in pages {
            if done >= n {
                break;
            }
            let rows = (n - done).min(KV_PAGE);
            page.load_rows(rows, d, dtype, sg, &mut out[done * d..(done + rows) * d]);
            done += rows;
        }
        assert_eq!(done, n, "KV gather past allocated pages");
    }

    /// Write the K/V rows of `pos` at layer `l`, allocating (or
    /// copy-on-write cloning) pages as needed and quantizing on the way in
    /// when the cache is not f32.  Does not advance `len`;
    /// [`forward_cached`] commits the new length after all layers wrote.
    pub fn put(&mut self, l: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(pos < self.max_seq, "KV put past max_seq");
        let (dtype, d, sg) = (self.dtype, self.d_model, self.scale_group);
        let n_sg = d.div_ceil(sg);
        let (pi, row) = (pos / KV_PAGE, pos % KV_PAGE);
        let kp = Self::page_mut(&mut self.k[l], pi, dtype, d, n_sg);
        kp.store_row(row, krow, dtype, sg);
        let vp = Self::page_mut(&mut self.v[l], pi, dtype, d, n_sg);
        vp.store_row(row, vrow, dtype, sg);
    }

    fn page_mut(
        pages: &mut Vec<Arc<Page>>,
        pi: usize,
        dtype: KvDtype,
        d: usize,
        n_sg: usize,
    ) -> &mut Page {
        while pages.len() <= pi {
            pages.push(Arc::new(Page::blank(dtype, d, n_sg)));
        }
        Arc::make_mut(&mut pages[pi])
    }

    /// Snapshot the first `pos` cached positions as a new cache sharing
    /// every page with `self` (refcounted; copy-on-write on either side).
    /// `pos` may be anywhere in `0..=len()`, including mid-page.
    pub fn fork_at(&self, pos: usize) -> KvCache {
        assert!(pos <= self.len, "fork_at({pos}) beyond cached len {}", self.len);
        let n_pages = pos.div_ceil(KV_PAGE);
        KvCache {
            k: self.k.iter().map(|ps| ps[..n_pages.min(ps.len())].to_vec()).collect(),
            v: self.v.iter().map(|ps| ps[..n_pages.min(ps.len())].to_vec()).collect(),
            len: pos,
            max_seq: self.max_seq,
            d_model: self.d_model,
            dtype: self.dtype,
            scale_group: self.scale_group,
        }
    }

    /// Roll the sequence back to `pos` positions, dropping whole pages past
    /// the cut (a partially-covered last page is kept; its stale tail is
    /// overwritten before it can be read again).
    pub fn truncate(&mut self, pos: usize) {
        assert!(pos <= self.len, "truncate({pos}) beyond cached len {}", self.len);
        let n_pages = pos.div_ceil(KV_PAGE);
        for ps in self.k.iter_mut().chain(self.v.iter_mut()) {
            ps.truncate(n_pages);
        }
        self.len = pos;
    }

    /// Bytes of one allocated page at this cache's dtype (codes + scales).
    /// At test_config's `d_model = 32`: f32 = 2048 B, Int8 = 576 B
    /// (3.56×), Int4 = 320 B (6.4×) — the serve_continuous smoke's ≥3.5×
    /// residency bar rests on this arithmetic.
    fn page_bytes(&self) -> usize {
        let d = self.d_model;
        let scale_bytes =
            KV_PAGE * d.div_ceil(self.scale_group) * std::mem::size_of::<f32>();
        match self.dtype {
            KvDtype::F32 => KV_PAGE * d * std::mem::size_of::<f32>(),
            KvDtype::Int8 => KV_PAGE * d + scale_bytes,
            KvDtype::Int4 => KV_PAGE * d / 2 + scale_bytes,
        }
    }

    /// Bytes held by this cache's allocated pages (pages shared with a fork
    /// are counted in full here; use [`KvCache::page_refs`] to dedup).
    pub fn allocated_bytes(&self) -> usize {
        let page_bytes = self.page_bytes();
        self.k.iter().chain(self.v.iter()).map(|ps| ps.len() * page_bytes).sum()
    }

    /// `(address, bytes)` of every allocated page — lets callers holding
    /// several forks account unique live KV bytes (dedup by address).
    pub fn page_refs(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let page_bytes = self.page_bytes();
        self.k
            .iter()
            .chain(self.v.iter())
            .flatten()
            .map(move |p| (Arc::as_ptr(p) as usize, page_bytes))
    }

    /// What the PR-2 eager cache allocated per sequence up front:
    /// full-context K and V stores for every layer.
    pub fn eager_bytes(cfg: &OptConfig) -> usize {
        cfg.n_layers * 2 * cfg.max_seq * cfg.d_model * std::mem::size_of::<f32>()
    }
}

/// Shared core of the incremental forwards: feed `tokens` as positions
/// `cache.len()..cache.len() + tokens.len()`, appending their K/V to the
/// cache, and return the post-block residual stream `[t_new, d_model]`.
/// Every op on this path (LayerNorm, the linears, attention, ReLU) computes
/// each row independently, so a k-token chunk is **bit-identical per row**
/// to k sequential one-token calls — the invariant both the prefix cache
/// and the speculative chunked-verify path ([`forward_chunk`]) build on.
fn forward_hidden<P: DecoderParams + ?Sized>(
    p: &P,
    cache: &mut KvCache,
    tokens: &[i32],
) -> Tensor {
    let cfg = p.config();
    let t_new = tokens.len();
    assert!(t_new > 0, "forward_cached: empty token chunk");
    let p0 = cache.len;
    assert!(
        p0 + t_new <= cache.max_seq,
        "KV cache overflow: {p0} cached + {t_new} new > max_seq {}",
        cache.max_seq
    );

    // embed + absolute positions
    let emb = p.dense("emb");
    let pos = p.dense("pos");
    let mut x = Tensor::zeros(t_new, cfg.d_model);
    for (i, &tok) in tokens.iter().enumerate() {
        // Callers (the serving scheduler rejects out-of-vocab prompts at
        // admission) must uphold this; assert so a violation fails with a
        // clear message instead of a wrapped `as usize` row index.
        assert!(
            tok >= 0 && (tok as usize) < cfg.vocab,
            "token id {tok} outside vocab 0..{}",
            cfg.vocab
        );
        let er = emb.row(tok as usize);
        let pr = pos.row(p0 + i);
        let dst = x.row_mut(i);
        for c in 0..cfg.d_model {
            dst[c] = er[c] + pr[c];
        }
    }

    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    // one reusable attention-score buffer for the whole call (hot path:
    // a decode step would otherwise allocate per layer x head)
    let mut scores = vec![0.0f32; p0 + t_new];
    // Quantized KV reads go through one page-wise dequant per layer into
    // these reused scratch buffers; the f32 default keeps reading rows in
    // place through k_row/v_row's inlined page arithmetic (div + mod per
    // access, no gather allocation on the decode hot path).
    let quantized = cache.dtype() != KvDtype::F32;
    let ctx_all = p0 + t_new;
    let (mut kbuf, mut vbuf) = if quantized {
        (vec![0.0f32; ctx_all * cfg.d_model], vec![0.0f32; ctx_all * cfg.d_model])
    } else {
        (Vec::new(), Vec::new())
    };
    for l in 0..cfg.n_layers {
        // -- attention half --------------------------------------------------
        let h = layer_norm(
            &x,
            &p.dense(&format!("l{l}.ln1.w")).data,
            &p.dense(&format!("l{l}.ln1.b")).data,
        );
        let q = p.linear_batch(l, "q", &h);
        let k_new = p.linear_batch(l, "k", &h);
        let v_new = p.linear_batch(l, "v", &h);
        for i in 0..t_new {
            cache.put(l, p0 + i, k_new.row(i), v_new.row(i));
        }
        if quantized {
            cache.gather_k(l, ctx_all, &mut kbuf);
            cache.gather_v(l, ctx_all, &mut vbuf);
        }
        let d = cfg.d_model;
        let mut attn_out = Tensor::zeros(t_new, cfg.d_model);
        for head in 0..heads {
            let c0 = head * hd;
            for i in 0..t_new {
                let qr = &q.row(i)[c0..c0 + hd];
                let ctx = p0 + i + 1; // causal: attend to positions 0..=p0+i
                let scores = &mut scores[..ctx];
                for (j, s) in scores.iter_mut().enumerate() {
                    let kr = if quantized {
                        &kbuf[j * d..(j + 1) * d]
                    } else {
                        cache.k_row(l, j)
                    };
                    *s = ops::dot(qr, &kr[c0..c0 + hd]) * scale;
                }
                let mx = scores.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
                let mut sum = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - mx).exp();
                    sum += *s;
                }
                let inv = 1.0 / sum;
                let orow = &mut attn_out.row_mut(i)[c0..c0 + hd];
                for (j, s) in scores.iter().enumerate() {
                    let wgt = s * inv;
                    if wgt == 0.0 {
                        continue;
                    }
                    let vr = if quantized {
                        &vbuf[j * d..(j + 1) * d]
                    } else {
                        cache.v_row(l, j)
                    };
                    let vr = &vr[c0..c0 + hd];
                    for c in 0..hd {
                        orow[c] += wgt * vr[c];
                    }
                }
            }
        }
        let o = p.linear_batch(l, "o", &attn_out);
        ops::add_assign(&mut x, &o);

        // -- FFN half --------------------------------------------------------
        let h2 = layer_norm(
            &x,
            &p.dense(&format!("l{l}.ln2.w")).data,
            &p.dense(&format!("l{l}.ln2.b")).data,
        );
        let mut u = p.linear_batch(l, "up", &h2);
        relu(&mut u);
        let down = p.linear_batch(l, "down", &u);
        ops::add_assign(&mut x, &down);
    }
    cache.len = p0 + t_new;
    x
}

/// Feed `tokens` as positions `cache.len()..cache.len() + tokens.len()`,
/// appending their K/V to the cache; returns the logits of the *last* fed
/// position (`[vocab]`).  One entry point covers both prompt prefill (many
/// tokens) and incremental decode (one token).
pub fn forward_cached<P: DecoderParams + ?Sized>(
    p: &P,
    cache: &mut KvCache,
    tokens: &[i32],
) -> Vec<f32> {
    let cfg = p.config();
    let x = forward_hidden(p, cache, tokens);

    // final LN + tied head, on the last position only
    let last = Tensor::from_vec(1, cfg.d_model, x.row(tokens.len() - 1).to_vec());
    let hf = layer_norm(&last, &p.dense("lnf.w").data, &p.dense("lnf.b").data);
    let emb = p.dense("emb");
    let mut logits = vec![0.0f32; cfg.vocab];
    ops::matmul_nt(&hf.data, &emb.data, 1, cfg.d_model, cfg.vocab, &mut logits);
    logits
}

/// Chunked incremental forward — the speculative-decoding verify kernel:
/// feed all of `tokens` in one pass and return the logits of **every** fed
/// position as a `[tokens.len(), vocab]` tensor (row `i` is the next-token
/// distribution after `tokens[..=i]`).
///
/// One chunked call streams each weight matrix once for the whole chunk —
/// the fused packed GEMM ([`crate::quant::PackedTensor::linear_into`])
/// decodes a weight tile once and multiplies all k rows against it, and the
/// tied-head projection runs one `[k, vocab]` GEMM instead of k GEMVs — so
/// weight traffic is amortized k× over verifying with k sequential
/// [`decode_step`]s.  Row `i` is **bit-identical** to what the i-th
/// sequential `decode_step` would have returned (pinned by
/// `forward_chunk_bit_identical_to_sequential_decode_steps`), which is what
/// makes speculative verification a pure perf optimization.
pub fn forward_chunk<P: DecoderParams + ?Sized>(
    p: &P,
    cache: &mut KvCache,
    tokens: &[i32],
) -> Tensor {
    // inert guard when tracing is off; the span id carries the chunk width
    let _sp = crate::obs::trace::span("model", "forward_chunk", tokens.len() as u64);
    let cfg = p.config();
    let x = forward_hidden(p, cache, tokens);

    // final LN + tied head over every fed position in one weight pass.
    // Cache-blocked and serial on purpose: matmul_nt_blocked streams each
    // 64-row tile of the embedding matrix once for ALL k chunk rows (the
    // [k, vocab] head is the widest GEMM on the verify path, and the plain
    // row-major loop re-streams the full vocab × d_model matrix per row),
    // while staying serial because verify chunks run inside the
    // scheduler's per-slot parallelism — spawning nested worker scopes per
    // slot per round is the oversubscription decode_step deliberately
    // avoids.  Bit-identical to the plain/parallel matmul either way
    // (pinned by ops::matmul_blocked_bit_identical_to_plain).
    let hf = layer_norm(&x, &p.dense("lnf.w").data, &p.dense("lnf.b").data);
    let emb = p.dense("emb");
    let mut logits = Tensor::zeros(tokens.len(), cfg.vocab);
    ops::matmul_nt_blocked(
        &hf.data,
        &emb.data,
        tokens.len(),
        cfg.d_model,
        cfg.vocab,
        &mut logits.data,
    );
    logits
}

/// Prompt prefill: reset the cache and feed the whole prompt; returns the
/// last-position logits (the distribution of the first generated token).
pub fn prefill<P: DecoderParams + ?Sized>(p: &P, cache: &mut KvCache, prompt: &[i32]) -> Vec<f32> {
    // inert guard when tracing is off; the span id carries the prompt length
    let _sp = crate::obs::trace::span("model", "prefill", prompt.len() as u64);
    cache.clear();
    forward_cached(p, cache, prompt)
}

/// Single-token decode step against the cached context.
pub fn decode_step<P: DecoderParams + ?Sized>(p: &P, cache: &mut KvCache, token: i32) -> Vec<f32> {
    forward_cached(p, cache, &[token])
}

/// Convenience: perplexity of a token stream chunked into sequences.
pub fn perplexity(w: &Weights, tokens: &[u32], seqlen: usize, max_seqs: usize) -> f64 {
    let n = ((tokens.len() - 1) / seqlen).min(max_seqs);
    let mut toks = Vec::new();
    let mut tgts = Vec::new();
    let mut masks = Vec::new();
    for s in 0..n {
        let a = s * seqlen;
        toks.push(tokens[a..a + seqlen].iter().map(|&t| t as i32).collect());
        tgts.push(tokens[a + 1..a + seqlen + 1].iter().map(|&t| t as i32).collect());
        masks.push(vec![1.0f32; seqlen]);
    }
    let out = forward(w, &toks, &tgts, &masks, Capture::default());
    out.ce.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OptConfig;

    fn setup() -> (Weights, Vec<Vec<i32>>, Vec<Vec<i32>>, Vec<Vec<f32>>) {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 1);
        let mut rng = crate::util::rng::Pcg64::new(2);
        let b = 2;
        let t = 16;
        let toks: Vec<Vec<i32>> = (0..b)
            .map(|_| (0..t).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();
        let tgts = toks
            .iter()
            .map(|s| {
                let mut x = s[1..].to_vec();
                x.push(s[0]);
                x
            })
            .collect();
        let mask = vec![vec![1.0; t]; b];
        (w, toks, tgts, mask)
    }

    #[test]
    fn output_shapes() {
        let (w, toks, tgts, mask) = setup();
        let out = forward(
            &w,
            &toks,
            &tgts,
            &mask,
            Capture { hidden: true, linear_inputs: true, last_logits: true },
        );
        assert!(out.ce.is_finite() && out.ce > 0.0);
        assert_eq!(out.seq_logprob.len(), 2);
        assert_eq!(out.hidden.len(), w.config.n_layers);
        assert_eq!(out.hidden[0].shape(), (2 * 16, w.config.d_model));
        assert_eq!(out.linear_inputs[0].down_in.shape(), (2 * 16, w.config.d_ffn));
        assert_eq!(out.last_logits.len(), 2);
        assert_eq!(out.last_logits[0].len(), w.config.vocab);
    }

    #[test]
    fn random_model_ce_near_uniform() {
        // A tiny random model should have CE close to ln(vocab).
        let (w, toks, tgts, mask) = setup();
        let out = forward(&w, &toks, &tgts, &mask, Capture::default());
        let uniform = (w.config.vocab as f64).ln();
        assert!((out.ce - uniform).abs() < 1.0, "ce {} vs uniform {uniform}", out.ce);
    }

    #[test]
    fn mask_gates_loss() {
        let (w, toks, tgts, _) = setup();
        let full = vec![vec![1.0; 16]; 2];
        let half: Vec<Vec<f32>> = (0..2)
            .map(|_| (0..16).map(|t| if t < 8 { 0.0 } else { 1.0 }).collect())
            .collect();
        let a = forward(&w, &toks, &tgts, &full, Capture::default());
        let b = forward(&w, &toks, &tgts, &half, Capture::default());
        assert!((a.ce - b.ce).abs() > 1e-9 || a.seq_logprob != b.seq_logprob);
        // seq_logprob magnitude halves-ish with half the mask
        assert!(b.seq_logprob[0].abs() < a.seq_logprob[0].abs());
    }

    #[test]
    fn causality() {
        // Changing the last token must not change earlier hidden states.
        let (w, mut toks, tgts, mask) = setup();
        let out1 = forward(&w, &toks, &tgts, &mask, Capture { hidden: true, ..Default::default() });
        toks[0][15] = (toks[0][15] + 1) % w.config.vocab as i32;
        let out2 = forward(&w, &toks, &tgts, &mask, Capture { hidden: true, ..Default::default() });
        let h1 = &out1.hidden[w.config.n_layers - 1];
        let h2 = &out2.hidden[w.config.n_layers - 1];
        for t in 0..15 {
            for c in 0..w.config.d_model {
                assert!((h1.at(t, c) - h2.at(t, c)).abs() < 1e-5, "leak at t={t}");
            }
        }
    }

    #[test]
    fn batch_order_independent() {
        let (w, toks, tgts, mask) = setup();
        let fwd = forward(&w, &toks, &tgts, &mask, Capture::default());
        let rev_toks: Vec<_> = toks.iter().rev().cloned().collect();
        let rev_tgts: Vec<_> = tgts.iter().rev().cloned().collect();
        let bwd = forward(&w, &rev_toks, &rev_tgts, &mask, Capture::default());
        assert!((fwd.ce - bwd.ce).abs() < 1e-9);
        assert!((fwd.seq_logprob[0] - bwd.seq_logprob[1]).abs() < 1e-4);
    }

    #[test]
    fn cached_prefill_matches_full_forward_logits() {
        let (w, toks, tgts, mask) = setup();
        let full = forward(
            &w,
            &toks,
            &tgts,
            &mask,
            Capture { last_logits: true, ..Default::default() },
        );
        for (b, seq) in toks.iter().enumerate() {
            let mut cache = KvCache::new(&w.config);
            let logits = prefill(&w, &mut cache, seq);
            assert_eq!(cache.len(), seq.len());
            for (a, f) in logits.iter().zip(&full.last_logits[b]) {
                assert!((a - f).abs() < 1e-3, "seq {b}: {a} vs {f}");
            }
        }
    }

    #[test]
    fn incremental_decode_matches_full_reforward() {
        // feeding tokens one at a time through the KV cache must agree with
        // re-forwarding the full context at every step (the old serve path)
        let (w, toks, ..) = setup();
        let seq = &toks[0];
        let mut cache = KvCache::new(&w.config);
        let mut inc = prefill(&w, &mut cache, &seq[..4]);
        for t in 4..seq.len() {
            let prefix = vec![seq[..t].to_vec()];
            let tgts = vec![vec![0i32; t]];
            let mask = vec![vec![0f32; t]];
            let full = forward(
                &w,
                &prefix,
                &tgts,
                &mask,
                Capture { last_logits: true, ..Default::default() },
            );
            for (a, f) in inc.iter().zip(&full.last_logits[0]) {
                assert!((a - f).abs() < 1e-3, "t={t}: {a} vs {f}");
            }
            inc = decode_step(&w, &mut cache, seq[t]);
        }
        assert_eq!(cache.len(), seq.len());
        assert_eq!(cache.remaining(), w.config.max_seq - seq.len());
    }

    #[test]
    fn cache_clear_resets_state_and_accounting() {
        let (w, toks, ..) = setup();
        let mut cache = KvCache::new(&w.config);
        let a = prefill(&w, &mut cache, &toks[0]);
        let b = prefill(&w, &mut cache, &toks[0]); // clear + refill
        assert_eq!(a, b);
        // clear releases pages: a reused cache never reports the previous
        // sequence's pages as resident
        cache.clear();
        assert_eq!(cache.allocated_bytes(), 0);
        assert_eq!(cache.page_refs().count(), 0);
    }

    #[test]
    #[should_panic(expected = "KV cache overflow")]
    fn cache_overflow_panics() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 1);
        let mut cache = KvCache::new(&cfg);
        let toks = vec![1i32; cfg.max_seq];
        prefill(&w, &mut cache, &toks);
        decode_step(&w, &mut cache, 1); // one past max_seq
    }

    #[test]
    fn chunked_pages_allocate_lazily() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 1);
        let mut cache = KvCache::new(&cfg);
        assert_eq!(cache.allocated_bytes(), 0, "no pages before any token");
        prefill(&w, &mut cache, &[3i32; 5]);
        // 5 tokens fit in one KV_PAGE page per layer per K/V store
        let page_bytes = KV_PAGE * cfg.d_model * 4;
        assert_eq!(cache.allocated_bytes(), cfg.n_layers * 2 * page_bytes);
        assert!(
            cache.allocated_bytes() < KvCache::eager_bytes(&cfg),
            "short sequences must hold fewer bytes than the eager full-context cache"
        );
    }

    #[test]
    fn fork_at_zero_mid_and_len_continue_bit_identically() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 5);
        let mut rng = crate::util::rng::Pcg64::new(11);
        let seq: Vec<i32> = (0..20).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut base = KvCache::new(&cfg);
        prefill(&w, &mut base, &seq);

        for cut in [0usize, 7, seq.len()] {
            let mut fork = base.fork_at(cut);
            assert_eq!(fork.len(), cut);
            let cont: Vec<i32> = (0..4).map(|i| ((cut + i) % cfg.vocab) as i32).collect();
            let from_fork = forward_cached(&w, &mut fork, &cont);
            let mut fresh = KvCache::new(&cfg);
            let full: Vec<i32> = seq[..cut].iter().chain(&cont).copied().collect();
            let from_fresh = forward_cached(&w, &mut fresh, &full);
            assert_eq!(from_fork, from_fresh, "fork at {cut} diverged");
        }

        // copy-on-write: the mid-page fork wrote into a shared page above,
        // but the parent's state must be untouched
        let d = decode_step(&w, &mut base, 1);
        let mut control = KvCache::new(&cfg);
        prefill(&w, &mut control, &seq);
        let d2 = decode_step(&w, &mut control, 1);
        assert_eq!(d, d2, "fork writes leaked into the parent cache");
    }

    #[test]
    fn truncate_rolls_back_then_refills() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 6);
        let seq: Vec<i32> = (0..18).map(|i| (i * 5 % cfg.vocab) as i32).collect();
        let mut cache = KvCache::new(&cfg);
        prefill(&w, &mut cache, &seq);
        cache.truncate(9);
        assert_eq!(cache.len(), 9);
        let alt = [4i32, 9, 2];
        let a = forward_cached(&w, &mut cache, &alt);
        let mut fresh = KvCache::new(&cfg);
        let full: Vec<i32> = seq[..9].iter().chain(&alt).copied().collect();
        let b = forward_cached(&w, &mut fresh, &full);
        assert_eq!(a, b, "decode after truncate diverged from fresh prefix");
        cache.truncate(0);
        assert!(cache.is_empty());
    }

    #[test]
    fn fork_shares_pages_until_write() {
        use std::collections::HashSet;
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 7);
        let mut cache = KvCache::new(&cfg);
        prefill(&w, &mut cache, &[2i32; 20]);
        let fork = cache.fork_at(20);
        let parent: HashSet<usize> = cache.page_refs().map(|(p, _)| p).collect();
        assert!(
            fork.page_refs().all(|(p, _)| parent.contains(&p)),
            "a fresh fork must alias its parent's pages"
        );
        // unique accounting: parent + full fork hold one page set
        let mut seen = HashSet::new();
        let mut unique = 0usize;
        for (ptr, b) in cache.page_refs().chain(fork.page_refs()) {
            if seen.insert(ptr) {
                unique += b;
            }
        }
        assert_eq!(unique, cache.allocated_bytes());
    }

    #[test]
    #[should_panic(expected = "fork_at")]
    fn fork_past_len_panics() {
        let cfg = OptConfig::test_config();
        let cache = KvCache::new(&cfg);
        cache.fork_at(1);
    }

    #[test]
    fn forward_chunk_bit_identical_to_sequential_decode_steps() {
        // the speculative-verify acceptance pin: one chunked forward over k
        // tokens must return, at every row, EXACTLY the logits k sequential
        // single-token decode_steps produce — bit for bit, including across
        // KV page boundaries (KV_PAGE = 16; the chunks below straddle it).
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 8);
        let mut rng = crate::util::rng::Pcg64::new(21);
        let prompt: Vec<i32> = (0..6).map(|_| rng.below(cfg.vocab) as i32).collect();
        for chunk_len in [1usize, 3, 8, 13] {
            let chunk: Vec<i32> =
                (0..chunk_len).map(|_| rng.below(cfg.vocab) as i32).collect();
            let mut seq_cache = KvCache::new(&cfg);
            prefill(&w, &mut seq_cache, &prompt);
            let seq_logits: Vec<Vec<f32>> =
                chunk.iter().map(|&t| decode_step(&w, &mut seq_cache, t)).collect();
            let mut chunk_cache = KvCache::new(&cfg);
            prefill(&w, &mut chunk_cache, &prompt);
            let chunked = forward_chunk(&w, &mut chunk_cache, &chunk);
            assert_eq!(chunked.shape(), (chunk_len, cfg.vocab));
            assert_eq!(chunk_cache.len(), seq_cache.len());
            for (i, row) in seq_logits.iter().enumerate() {
                assert_eq!(
                    chunked.row(i),
                    row.as_slice(),
                    "chunk len {chunk_len}: row {i} diverged from sequential decode"
                );
            }
        }
    }

    /// Wide single-layer config so rollback chunks can straddle multiple
    /// KV pages (test_config's max_seq of 32 only holds 2 pages).
    fn rollback_config() -> OptConfig {
        OptConfig {
            name: "rollback-test".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ffn: 32,
            max_seq: 96,
        }
    }

    #[test]
    fn prop_fork_append_truncate_roundtrips_bit_identically() {
        // speculation's rollback invariant: fork_at → append k tokens →
        // truncate back must leave a cache whose continuations are
        // bit-identical to never having appended, for k straddling page
        // boundaries — and the parent must never see the fork's writes.
        let cfg = rollback_config();
        let w = Weights::random(cfg.clone(), 13);
        crate::util::propcheck::check("fork/append/truncate identity", 12, |rng| {
            let p = 1 + rng.below(2 * KV_PAGE + 4); // prefix crosses 0..=2 boundaries
            let seq: Vec<i32> = (0..p).map(|_| rng.below(cfg.vocab) as i32).collect();
            let mut base = KvCache::new(&cfg);
            prefill(&w, &mut base, &seq);
            for k in [1usize, KV_PAGE - 1, KV_PAGE, 2 * KV_PAGE] {
                let mut fork = base.fork_at(p);
                let junk: Vec<i32> = (0..k).map(|_| rng.below(cfg.vocab) as i32).collect();
                forward_chunk(&w, &mut fork, &junk);
                fork.truncate(p);
                if fork.len() != p {
                    return Err(format!("p={p} k={k}: truncate left len {}", fork.len()));
                }
                // the rolled-back fork continues exactly like a fresh prefix
                let cont: Vec<i32> = (0..3).map(|_| rng.below(cfg.vocab) as i32).collect();
                let a = forward_cached(&w, &mut fork, &cont);
                let mut fresh = KvCache::new(&cfg);
                let full: Vec<i32> = seq.iter().chain(&cont).copied().collect();
                let b = forward_cached(&w, &mut fresh, &full);
                if a != b {
                    return Err(format!("p={p} k={k}: rolled-back continuation diverged"));
                }
            }
            // the parent never saw any of the forks' speculative writes
            let d = decode_step(&w, &mut base, 1);
            let mut control = KvCache::new(&cfg);
            prefill(&w, &mut control, &seq);
            let d2 = decode_step(&w, &mut control, 1);
            crate::util::propcheck::ensure(d == d2, format!("p={p}: parent corrupted"))
        });
    }

    #[test]
    fn kv_dtype_parse_forms() {
        assert_eq!(KvDtype::parse("f32").unwrap(), KvDtype::F32);
        assert_eq!(KvDtype::parse("INT8").unwrap(), KvDtype::Int8);
        assert_eq!(KvDtype::parse("i8").unwrap(), KvDtype::Int8);
        assert_eq!(KvDtype::parse("int4").unwrap(), KvDtype::Int4);
        assert!(KvDtype::parse("bf16").is_err());
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::Int8.label(), "int8");
    }

    #[test]
    fn quantized_kv_gather_error_within_documented_bound() {
        // the KvDtype contract: per element, |x - x̂| ≤ amax / (2·qmax)
        // with amax over the element's (row, scale-group) — checked across
        // a page boundary and a partially-filled last page
        let cfg = OptConfig::test_config();
        let d = cfg.d_model;
        let sg = d.min(KV_SCALE_GROUP);
        let rows = KV_PAGE + 5;
        let mut rng = crate::util::rng::Pcg64::new(31);
        let rowsf: Vec<Vec<f32>> = (0..rows)
            .map(|_| (0..d).map(|_| (rng.uniform() as f32 - 0.5) * 6.0).collect())
            .collect();
        for (dtype, qmax) in [(KvDtype::Int8, 127.0f32), (KvDtype::Int4, 7.0f32)] {
            let mut cache = KvCache::with_dtype(&cfg, dtype);
            for (pos, r) in rowsf.iter().enumerate() {
                cache.put(0, pos, r, r);
            }
            let mut got = vec![0.0f32; rows * d];
            cache.gather_k(0, rows, &mut got);
            for (pos, r) in rowsf.iter().enumerate() {
                for (g, chunk) in r.chunks(sg).enumerate() {
                    let amax = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    // small slack for the f32 divide/round round-trip
                    let bound = amax / (2.0 * qmax) * 1.001 + 1e-7;
                    for (c, &exact) in chunk.iter().enumerate() {
                        let approx = got[pos * d + g * sg + c];
                        assert!(
                            (approx - exact).abs() <= bound,
                            "{dtype:?} pos {pos} ch {}: |{approx} - {exact}| > {bound}",
                            g * sg + c
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_kv_pages_shrink_residency() {
        let cfg = OptConfig::test_config(); // d_model 32 → one scale group
        let w = Weights::random(cfg.clone(), 1);
        let prompt = vec![3i32; 20]; // 2 pages per layer per store
        let mut sizes = Vec::new();
        for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Int4] {
            let mut cache = KvCache::with_dtype(&cfg, dtype);
            prefill(&w, &mut cache, &prompt);
            // page_refs and allocated_bytes must agree per dtype (the
            // live-KV gauge in serve::metrics dedups over page_refs)
            assert_eq!(
                cache.page_refs().map(|(_, b)| b).sum::<usize>(),
                cache.allocated_bytes()
            );
            sizes.push(cache.allocated_bytes() as f64);
        }
        // f32 page 16·32·4 = 2048 B; Int8 = 16·32 + 16·4 = 576 B; Int4 =
        // 16·16 + 16·4 = 320 B — the serve_continuous ≥3.5× residency bar
        assert!(sizes[0] / sizes[1] >= 3.5, "int8 residency ratio {}", sizes[0] / sizes[1]);
        assert!(sizes[0] / sizes[2] >= 6.0, "int4 residency ratio {}", sizes[0] / sizes[2]);
    }

    #[test]
    fn quantized_kv_logits_within_documented_tolerance() {
        // documented serving tolerance: with quantized KV the last-token
        // logits stay within a small fraction of the f32 logit range
        // (Int8 ≤ 5%, Int4 ≤ 30% on the test model), and the induced
        // log-prob (CE) shift is bounded by twice the max logit shift
        // (log-softmax is 2-Lipschitz in ‖·‖∞).
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 9);
        let mut rng = crate::util::rng::Pcg64::new(41);
        let prompt: Vec<i32> = (0..24).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut exact = KvCache::new(&cfg);
        let ref_logits = prefill(&w, &mut exact, &prompt);
        let mx = ref_logits.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
        let mn = ref_logits.iter().fold(f32::INFINITY, |m, v| m.min(*v));
        let range = mx - mn;
        for (dtype, frac) in [(KvDtype::Int8, 0.05f32), (KvDtype::Int4, 0.30f32)] {
            let mut qc = KvCache::with_dtype(&cfg, dtype);
            let ql = prefill(&w, &mut qc, &prompt);
            let tol = range * frac + 1e-3;
            let mut worst = 0.0f32;
            for (a, b) in ql.iter().zip(&ref_logits) {
                worst = worst.max((a - b).abs());
            }
            assert!(worst <= tol, "{dtype:?}: max logit shift {worst} > {tol}");
            let lp_q = log_prob_at(&ql, prompt[0] as usize);
            let lp_f = log_prob_at(&ref_logits, prompt[0] as usize);
            assert!(
                (lp_q - lp_f).abs() <= 2.0 * worst + 1e-5,
                "{dtype:?}: CE shift {} exceeds the 2×logit-shift bound",
                (lp_q - lp_f).abs()
            );
        }
    }

    #[test]
    fn prop_fork_append_truncate_roundtrips_under_int8() {
        // the PR-5 rollback property re-run with a quantized cache:
        // quantization is deterministic, so a rolled-back Int8 fork must
        // continue BIT-identically to a fresh Int8 prefill of the same
        // prefix, the parent must never see the fork's writes, and every
        // gathered prefix row must stay within the documented per-element
        // error bound of its f32 twin.
        let cfg = rollback_config();
        let w = Weights::random(cfg.clone(), 13);
        let sg = cfg.d_model.min(KV_SCALE_GROUP);
        crate::util::propcheck::check("int8 fork/append/truncate identity", 8, |rng| {
            let p = 1 + rng.below(2 * KV_PAGE + 4);
            let seq: Vec<i32> = (0..p).map(|_| rng.below(cfg.vocab) as i32).collect();
            let mut base = KvCache::with_dtype(&cfg, KvDtype::Int8);
            prefill(&w, &mut base, &seq);
            for k in [1usize, KV_PAGE, 2 * KV_PAGE] {
                let mut fork = base.fork_at(p);
                let junk: Vec<i32> = (0..k).map(|_| rng.below(cfg.vocab) as i32).collect();
                forward_chunk(&w, &mut fork, &junk);
                fork.truncate(p);
                let cont: Vec<i32> = (0..3).map(|_| rng.below(cfg.vocab) as i32).collect();
                let a = forward_cached(&w, &mut fork, &cont);
                let mut fresh = KvCache::with_dtype(&cfg, KvDtype::Int8);
                let full: Vec<i32> = seq.iter().chain(&cont).copied().collect();
                let b = forward_cached(&w, &mut fresh, &full);
                if a != b {
                    return Err(format!("p={p} k={k}: int8 rollback diverged"));
                }
            }
            // parent untouched by any fork write
            let d1 = decode_step(&w, &mut base, 1);
            let mut control = KvCache::with_dtype(&cfg, KvDtype::Int8);
            prefill(&w, &mut control, &seq);
            let d2 = decode_step(&w, &mut control, 1);
            if d1 != d2 {
                return Err(format!("p={p}: parent corrupted by fork writes"));
            }
            // gather error vs an f32 twin ≤ amax / (2·127) per element
            let mut twin = KvCache::new(&cfg);
            prefill(&w, &mut twin, &seq);
            let d = cfg.d_model;
            let mut got = vec![0.0f32; (p + 1) * d];
            base.gather_k(0, p + 1, &mut got); // +1: the decode_step row
            for pos in 0..p {
                let exact = twin.k_row(0, pos);
                for (g, chunk) in exact.chunks(sg).enumerate() {
                    let amax = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    let bound = amax / (2.0 * 127.0) * 1.001 + 1e-7;
                    for (c, &e) in chunk.iter().enumerate() {
                        let a = got[pos * d + g * sg + c];
                        if (a - e).abs() > bound {
                            return Err(format!(
                                "p={p} pos={pos} ch={}: gather error {} > bound {bound}",
                                g * sg + c,
                                (a - e).abs()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "use gather_k/gather_v")]
    fn quantized_cache_rejects_in_place_row_reads() {
        let cfg = OptConfig::test_config();
        let mut cache = KvCache::with_dtype(&cfg, KvDtype::Int8);
        let row = vec![0.5f32; cfg.d_model];
        cache.put(0, 0, &row, &row);
        cache.k_row(0, 0);
    }

    #[test]
    fn perplexity_positive() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 3);
        let mut rng = crate::util::rng::Pcg64::new(4);
        let toks: Vec<u32> = (0..200).map(|_| rng.below(cfg.vocab) as u32).collect();
        let ppl = perplexity(&w, &toks, 16, 4);
        assert!(ppl > 1.0 && ppl.is_finite());
    }
}

//! Model hyper-parameters (mirrors `python/compile/model.py::OptConfig`).

use crate::util::json::Json;

/// OPT-style decoder configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OptConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
}

/// Per-layer parameter base names in canonical order (mirrors
/// `model.LAYER_PARAM_NAMES`).
pub const LAYER_PARAM_NAMES: [&str; 16] = [
    "ln1.w", "ln1.b", "q.w", "q.b", "k.w", "k.b", "v.w", "v.b", "o.w", "o.b",
    "ln2.w", "ln2.b", "up.w", "up.b", "down.w", "down.b",
];

/// Quantizable linear weights within a layer (mirrors `LAYER_QUANT_NAMES`).
pub const LAYER_QUANT_NAMES: [&str; 6] = ["q.w", "k.w", "v.w", "o.w", "up.w", "down.w"];

/// Split a canonical parameter name into its optional layer prefix and base
/// name: `"l3.up.w"` → `(Some(3), "up.w")`, `"emb"` → `(None, "emb")`.
/// The single source of truth for the `l<i>.` grammar — shared by shape
/// lookup, bit-allocation selectors, and the allocation search.
pub fn split_layer_prefix(name: &str) -> (Option<usize>, &str) {
    if let Some((head, rest)) = name.split_once('.') {
        if head.len() > 1 && head.starts_with('l') && head[1..].chars().all(|c| c.is_ascii_digit()) {
            if let Ok(l) = head[1..].parse() {
                return (Some(l), rest);
            }
        }
    }
    (None, name)
}

impl OptConfig {
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Canonical flat parameter-name order (mirrors `model.param_names`).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["emb".to_string(), "pos".to_string()];
        for i in 0..self.n_layers {
            for base in LAYER_PARAM_NAMES {
                names.push(format!("l{i}.{base}"));
            }
        }
        names.push("lnf.w".to_string());
        names.push("lnf.b".to_string());
        names
    }

    /// Names of all quantizable linear weights, layer by layer (the tensor
    /// universe a mixed-precision [`crate::quant::BitAllocation`] ranges
    /// over).
    pub fn quant_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            for base in LAYER_QUANT_NAMES {
                out.push(format!("l{i}.{base}"));
            }
        }
        out
    }

    /// Total parameter count (tied LM head: emb counted once).
    pub fn num_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * (d * d + d) + 2 * self.d_ffn * d + self.d_ffn + d + 4 * d;
        self.vocab * d + self.max_seq * d + self.n_layers * per_layer + 2 * d
    }

    /// Expected shape of a named parameter.
    pub fn param_shape(&self, name: &str) -> crate::Result<(usize, usize)> {
        let (d, f, v, t) = (self.d_model, self.d_ffn, self.vocab, self.max_seq);
        let (_, base) = split_layer_prefix(name);
        Ok(match base {
            "emb" => (v, d),
            "pos" => (t, d),
            "q.w" | "k.w" | "v.w" | "o.w" => (d, d),
            "q.b" | "k.b" | "v.b" | "o.b" => (1, d),
            "up.w" => (f, d),
            "up.b" => (1, f),
            "down.w" => (d, f),
            "down.b" => (1, d),
            "ln1.w" | "ln1.b" | "ln2.w" | "ln2.b" | "lnf.w" | "lnf.b" => (1, d),
            _ => anyhow::bail!("unknown parameter {name:?}"),
        })
    }

    pub fn from_json(j: &Json) -> crate::Result<OptConfig> {
        Ok(OptConfig {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            vocab: j.req("vocab")?.as_usize().unwrap(),
            d_model: j.req("d_model")?.as_usize().unwrap(),
            n_layers: j.req("n_layers")?.as_usize().unwrap(),
            n_heads: j.req("n_heads")?.as_usize().unwrap(),
            d_ffn: j.req("d_ffn")?.as_usize().unwrap(),
            max_seq: j.req("max_seq")?.as_usize().unwrap(),
        })
    }

    /// A small config for unit tests (no artifacts needed).
    pub fn test_config() -> OptConfig {
        OptConfig {
            name: "test".into(),
            vocab: 96,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 64,
            max_seq: 32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_names_order_and_count() {
        let cfg = OptConfig::test_config();
        let names = cfg.param_names();
        assert_eq!(names.len(), 2 + 16 * 2 + 2);
        assert_eq!(names[0], "emb");
        assert_eq!(names[2], "l0.ln1.w");
        assert_eq!(names[names.len() - 1], "lnf.b");
    }

    #[test]
    fn shapes_cover_all_names() {
        let cfg = OptConfig::test_config();
        for n in cfg.param_names() {
            let (r, c) = cfg.param_shape(&n).unwrap();
            assert!(r > 0 && c > 0, "{n}");
        }
        assert!(cfg.param_shape("bogus").is_err());
        // lnf.w is NOT a layer param: shape (1, d)
        assert_eq!(cfg.param_shape("lnf.w").unwrap(), (1, 32));
        assert_eq!(cfg.param_shape("l1.up.w").unwrap(), (64, 32));
    }

    #[test]
    fn num_params_matches_shapes() {
        let cfg = OptConfig::test_config();
        let total: usize = cfg
            .param_names()
            .iter()
            .map(|n| {
                let (r, c) = cfg.param_shape(n).unwrap();
                r * c
            })
            .sum();
        assert_eq!(total, cfg.num_params());
    }

    #[test]
    fn split_layer_prefix_grammar() {
        assert_eq!(split_layer_prefix("l3.up.w"), (Some(3), "up.w"));
        assert_eq!(split_layer_prefix("l12.q.w"), (Some(12), "q.w"));
        assert_eq!(split_layer_prefix("emb"), (None, "emb"));
        assert_eq!(split_layer_prefix("lnf.w"), (None, "lnf.w")); // not a layer
        assert_eq!(split_layer_prefix("up.w"), (None, "up.w"));
    }

    #[test]
    fn from_json_parses() {
        let j = crate::util::json::parse(
            r#"{"name": "x", "vocab": 10, "d_model": 8, "n_layers": 1,
                "n_heads": 2, "d_ffn": 16, "max_seq": 4}"#,
        )
        .unwrap();
        let cfg = OptConfig::from_json(&j).unwrap();
        assert_eq!(cfg.head_dim(), 4);
    }
}

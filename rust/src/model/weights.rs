//! Named weight set with layer views, loading from `.iwt`, and synthetic
//! initialization for tests.

use std::collections::BTreeMap;
use std::path::Path;

use super::config::{OptConfig, LAYER_PARAM_NAMES};
use crate::io::iwt;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// The full parameter set of one model, keyed by canonical names
/// (`emb`, `pos`, `l{i}.q.w`, …, `lnf.b`).  Bias/LN vectors are stored as
/// `[1, n]` tensors.
#[derive(Debug, Clone)]
pub struct Weights {
    pub config: OptConfig,
    map: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn new(config: OptConfig, map: BTreeMap<String, Tensor>) -> crate::Result<Weights> {
        // validate completeness + shapes up front; everything downstream
        // can then index without checking.
        for name in config.param_names() {
            let t = map
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("weights missing parameter {name:?}"))?;
            let expect = config.param_shape(&name)?;
            anyhow::ensure!(
                t.shape() == expect,
                "parameter {name:?}: shape {:?} != expected {:?}",
                t.shape(),
                expect
            );
        }
        Ok(Weights { config, map })
    }

    /// Load from an `.iwt` file, validating against `config`.
    pub fn load(path: &Path, config: OptConfig) -> crate::Result<Weights> {
        let file = iwt::read(path)?;
        let map: BTreeMap<String, Tensor> = file.tensors.into_iter().collect();
        Weights::new(config, map)
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("unknown parameter {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.map
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown parameter {name:?}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        let expect = self.config.param_shape(name).expect("known parameter");
        assert_eq!(t.shape(), expect, "set {name:?}: bad shape");
        self.map.insert(name.to_string(), t);
    }

    /// Layer-scoped accessor: `layer(i, "up.w")`.
    pub fn layer(&self, i: usize, base: &str) -> &Tensor {
        self.get(&format!("l{i}.{base}"))
    }

    pub fn layer_mut(&mut self, i: usize, base: &str) -> &mut Tensor {
        self.get_mut(&format!("l{i}.{base}"))
    }

    /// Bias slice view (biases are `[1, n]`).
    pub fn bias(&self, name: &str) -> &[f32] {
        &self.get(name).data
    }

    /// All tensors in canonical parameter order (the HLO argument order).
    pub fn in_order(&self) -> Vec<(&str, &Tensor)> {
        // param_names allocates Strings; map back to stored keys for &str.
        self.config
            .param_names()
            .into_iter()
            .map(|n| {
                let (k, v) = self.map.get_key_value(&n).expect("validated complete");
                (k.as_str(), v)
            })
            .collect()
    }

    /// Names of all quantizable linear weights, layer by layer.
    pub fn quant_names(&self) -> Vec<String> {
        self.config.quant_names()
    }

    /// Random weights for tests (same scale scheme as the python init).
    pub fn random(config: OptConfig, seed: u64) -> Weights {
        let mut rng = Pcg64::new(seed);
        let mut map = BTreeMap::new();
        for name in config.param_names() {
            let (r, c) = config.param_shape(&name).unwrap();
            let t = if name.ends_with("ln1.w") || name.ends_with("ln2.w") || name.ends_with("lnf.w")
            {
                Tensor::from_vec(r, c, vec![1.0; r * c])
            } else if name.ends_with(".b") {
                Tensor::from_vec(r, c, vec![0.0; r * c])
            } else {
                let scale = 0.08;
                Tensor::from_vec(
                    r,
                    c,
                    (0..r * c).map(|_| (rng.normal() as f32) * scale).collect(),
                )
            };
            map.insert(name, t);
        }
        Weights { config, map }
    }

    /// Deep-copy the 16 tensors of one layer (proposal scratch space).
    pub fn snapshot_layer(&self, i: usize) -> Vec<(String, Tensor)> {
        LAYER_PARAM_NAMES
            .iter()
            .map(|base| {
                let name = format!("l{i}.{base}");
                let t = self.get(&name).clone();
                (name, t)
            })
            .collect()
    }

    /// Restore a snapshot taken by [`Weights::snapshot_layer`].
    pub fn restore(&mut self, snapshot: Vec<(String, Tensor)>) {
        for (name, t) in snapshot {
            self.map.insert(name, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_validate() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 0);
        assert_eq!(w.get("emb").shape(), (cfg.vocab, cfg.d_model));
        assert_eq!(w.layer(0, "up.w").shape(), (cfg.d_ffn, cfg.d_model));
        assert_eq!(w.in_order().len(), cfg.param_names().len());
        assert_eq!(w.quant_names().len(), 6 * cfg.n_layers);
    }

    #[test]
    fn missing_param_rejected() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 0);
        let mut map: BTreeMap<String, Tensor> =
            w.in_order().into_iter().map(|(n, t)| (n.to_string(), t.clone())).collect();
        map.remove("l0.up.w");
        assert!(Weights::new(cfg, map).is_err());
    }

    #[test]
    fn bad_shape_rejected() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 0);
        let mut map: BTreeMap<String, Tensor> =
            w.in_order().into_iter().map(|(n, t)| (n.to_string(), t.clone())).collect();
        map.insert("l0.up.w".into(), Tensor::zeros(2, 2));
        assert!(Weights::new(cfg, map).is_err());
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let cfg = OptConfig::test_config();
        let mut w = Weights::random(cfg, 0);
        let before = w.layer(1, "up.w").clone();
        let snap = w.snapshot_layer(1);
        w.layer_mut(1, "up.w").data[0] += 5.0;
        assert_ne!(w.layer(1, "up.w").data[0], before.data[0]);
        w.restore(snap);
        assert_eq!(w.layer(1, "up.w"), &before);
    }

    #[test]
    fn iwt_roundtrip_through_weights() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 7);
        let dir = std::env::temp_dir().join("invarexplore_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.iwt");
        let entries: Vec<(String, &Tensor, Vec<usize>)> = w
            .in_order()
            .into_iter()
            .map(|(n, t)| {
                let shape = if t.rows == 1 && !n.ends_with('w') || t.rows == 1 {
                    vec![t.cols]
                } else {
                    vec![t.rows, t.cols]
                };
                (n.to_string(), t, shape)
            })
            .collect();
        iwt::write(&p, &entries, &BTreeMap::new()).unwrap();
        let back = Weights::load(&p, cfg).unwrap();
        assert_eq!(back.get("l0.q.w"), w.get("l0.q.w"));
        assert_eq!(back.get("lnf.b"), w.get("lnf.b"));
    }
}

//! The OPT-style model on the Rust side: configuration, named weight set,
//! and a full native (pure-Rust) forward pass used as (a) the numerics
//! oracle for the HLO programs and (b) the activation tap for baseline
//! calibration (GPTQ Hessians, AWQ activation scales).

pub mod config;
pub mod native;
pub mod weights;

pub use config::OptConfig;
pub use weights::Weights;

//! The real search objective: transform → re-quantize → evaluate on the
//! AOT XLA programs.
//!
//! Per proposal for layer *l*, only three tensors change: `up.w`, `up.b`,
//! `down.w` (Eqns. 21–22; `down.b` is untouched).  The two weight matrices
//! are re-quantized under the base method's semantics — on device through
//! the standalone Pallas fake-quant program for RTN (keeping the L1 kernel
//! on the hot path), or on host for the clip-search / GPTQ quantizers —
//! and the incremental evaluator re-runs only layers ≥ *l*.

use super::hillclimb::Objective;
use crate::baselines::{Prepared, Quantizer};
use crate::runtime::{Evaluator, Loss};
use crate::runtime::evaluator::Pending;
use crate::tensor::Tensor;
use crate::transform::{apply_to_tensors, LayerTransform};

/// Accepted quantized tensors of one layer (for cheap proposal revert).
struct LayerTensors {
    up_w: Tensor,
    up_b: Tensor,
    down_w: Tensor,
}

pub struct XlaObjective {
    prepared: Prepared,
    pub eval: Evaluator,
    /// Accepted quantized FFN tensors per layer (revert source).
    accepted: Vec<LayerTensors>,
    /// In-flight proposal: (layer, evaluator pending, tensors).
    pending: Option<(usize, Pending, LayerTensors)>,
    /// Quantize RTN proposals on device via the Pallas program.
    pub device_quant: bool,
}

impl XlaObjective {
    /// `eval` must already hold the uploaded FP weights of `prepared.fp`
    /// and captured H₀ (see `coordinator::pipeline`).
    ///
    /// RTN proposals *can* run the fake-quant on device through the
    /// standalone Pallas program (`INVAREXPLORE_DEVICE_QUANT=1`).  Under the
    /// CPU PJRT client the interpret-mode kernel executes its grid as an
    /// XLA while-loop (~75× the host codec, see EXPERIMENTS.md §Perf), so
    /// the default is the bit-identical host codec; the Pallas path is
    /// exercised by the cross-check tests and is the intended TPU route.
    pub fn new(prepared: Prepared, eval: Evaluator) -> XlaObjective {
        let device_quant = matches!(prepared.quantizer, Quantizer::Plain)
            && std::env::var("INVAREXPLORE_DEVICE_QUANT").as_deref() == Ok("1");
        XlaObjective {
            prepared,
            eval,
            accepted: Vec::new(),
            pending: None,
            device_quant,
        }
    }

    fn config(&self) -> &crate::model::OptConfig {
        &self.prepared.fp.config
    }

    /// Quantize + upload the FFN tensors of layer `l` under transform `t`.
    fn push_layer(&mut self, l: usize, t: &LayerTransform) -> crate::Result<LayerTensors> {
        let fp = &self.prepared.fp;
        let (up_w_t, up_b_t, down_w_t) = apply_to_tensors(
            t,
            fp.layer(l, "up.w"),
            fp.layer(l, "up.b"),
            fp.layer(l, "down.w"),
        );
        let (up_name, down_name) = (format!("l{l}.up.w"), format!("l{l}.down.w"));
        let engine = &mut self.eval.engine;
        let (up_q, down_q);
        if self.device_quant {
            // RTN semantics via the on-device Pallas kernel program
            engine.update_tensor_device_quant(&up_name, &up_w_t, self.prepared.scheme)?;
            engine.update_tensor_device_quant(&down_name, &down_w_t, self.prepared.scheme)?;
            // host copies kept for revert (re-quantized identically on revert
            // upload; cheap since fake-quant is deterministic)
            up_q = up_w_t;
            down_q = down_w_t;
        } else {
            up_q = self.prepared.quantize_tensor(&up_name, &up_w_t, Some(t));
            down_q = self.prepared.quantize_tensor(&down_name, &down_w_t, Some(t));
            engine.update_tensor(&up_name, &up_q)?;
            engine.update_tensor(&down_name, &down_q)?;
        }
        engine.update_tensor(&format!("l{l}.up.b"), &up_b_t)?;
        Ok(LayerTensors { up_w: up_q, up_b: up_b_t, down_w: down_q })
    }

    /// Re-upload the accepted tensors of layer `l` (proposal revert).
    fn restore_layer(&mut self, l: usize) -> crate::Result<()> {
        // move tensors out to appease the borrow checker, then put back
        let tensors = std::mem::replace(
            &mut self.accepted[l],
            LayerTensors {
                up_w: Tensor::zeros(0, 0),
                up_b: Tensor::zeros(0, 0),
                down_w: Tensor::zeros(0, 0),
            },
        );
        let engine = &mut self.eval.engine;
        if self.device_quant {
            engine.update_tensor_device_quant(&format!("l{l}.up.w"), &tensors.up_w, self.prepared.scheme)?;
            engine.update_tensor_device_quant(&format!("l{l}.down.w"), &tensors.down_w, self.prepared.scheme)?;
        } else {
            engine.update_tensor(&format!("l{l}.up.w"), &tensors.up_w)?;
            engine.update_tensor(&format!("l{l}.down.w"), &tensors.down_w)?;
        }
        engine.update_tensor(&format!("l{l}.up.b"), &tensors.up_b)?;
        self.accepted[l] = tensors;
        Ok(())
    }
}

impl Objective for XlaObjective {
    fn n_layers(&self) -> usize {
        self.config().n_layers
    }

    fn d_ffn(&self) -> usize {
        self.config().d_ffn
    }

    /// Quantize every linear under the base method (identity transforms),
    /// upload, and run the first full evaluation.
    fn init(&mut self) -> crate::Result<Loss> {
        let fp = &self.prepared.fp;
        let cfg = self.config().clone();
        // attention projections: quantized once, never touched by the search
        for l in 0..cfg.n_layers {
            for base in ["q.w", "k.w", "v.w", "o.w"] {
                let name = format!("l{l}.{base}");
                if self.device_quant {
                    let t = fp.get(&name).clone();
                    self.eval
                        .engine
                        .update_tensor_device_quant(&name, &t, self.prepared.scheme)?;
                } else {
                    let q = self.prepared.quantize_tensor(&name, fp.get(&name), None);
                    self.eval.engine.update_tensor(&name, &q)?;
                }
            }
        }
        // FFN tensors via the shared path (identity transform)
        self.accepted.clear();
        for l in 0..cfg.n_layers {
            let t = LayerTransform::identity(cfg.d_ffn);
            let tensors = self.push_layer(l, &t)?;
            self.accepted.push(tensors);
        }
        self.eval.full_eval()
    }

    fn try_layer(&mut self, l: usize, t: &LayerTransform) -> crate::Result<Loss> {
        anyhow::ensure!(self.pending.is_none(), "overlapping proposals");
        let tensors = self.push_layer(l, t)?;
        let pending = self.eval.eval_from_layer(l)?;
        let loss = pending.loss;
        self.pending = Some((l, pending, tensors));
        Ok(loss)
    }

    fn accept(&mut self) -> crate::Result<()> {
        let (l, pending, tensors) = self.pending.take().expect("no pending proposal");
        self.eval.accept(pending);
        self.accepted[l] = tensors;
        Ok(())
    }

    fn reject(&mut self) -> crate::Result<()> {
        let (l, _pending, _tensors) = self.pending.take().expect("no pending proposal");
        self.restore_layer(l)?;
        Ok(())
    }
}

//! The real search objective: transform → re-quantize → evaluate on the
//! AOT XLA programs, speaking the draft / evaluate / commit protocol.
//!
//! Per proposal for layer *l*, only three tensors change: `up.w`, `up.b`,
//! `down.w` (Eqns. 21–22; `down.b` is untouched).  **Drafting** — transform
//! application plus re-quantization under the base method's semantics — is
//! pure host-side work on the base FP weights, independent of every other
//! layer's accepted state, so a round of K drafts fans out across
//! [`crate::util::pool::parallel_map`].  **Evaluation** swaps each
//! candidate's tensors onto the device, scores it through the incremental
//! evaluator (layers ≥ *l* only), and restores the accepted tensors, so
//! candidates never observe each other.  **Commit** re-uploads the chosen
//! candidate and splices its pending activation buffers into the accepted
//! prefix cache — no re-evaluation.
//!
//! RTN proposals can re-quantize on device through the standalone Pallas
//! fake-quant program (`INVAREXPLORE_DEVICE_QUANT=1`); the clip-search /
//! GPTQ quantizers always run on host.

use std::collections::HashMap;

use super::hillclimb::{Draft, DraftRequest, Objective};
use crate::baselines::{Prepared, Quantizer};
use crate::runtime::evaluator::Pending;
use crate::runtime::{Evaluator, Loss};
use crate::tensor::Tensor;
use crate::transform::{apply_to_tensors, LayerTransform};
use crate::util::pool;

/// The three searched tensors of one layer: draft payload and accepted
/// revert source.  Host-quantized values, or FP-transformed values when the
/// Pallas device-quant path re-quantizes at upload.
struct LayerTensors {
    up_w: Tensor,
    up_b: Tensor,
    down_w: Tensor,
}

/// Host-side drafting: apply `t` to layer `l` of the base FP weights and
/// re-quantize under the method's semantics.  `&Prepared` only — safe to
/// fan out across worker threads.
fn draft_tensors(prepared: &Prepared, device_quant: bool, l: usize, t: &LayerTransform) -> LayerTensors {
    let fp = &prepared.fp;
    let (up_w_t, up_b_t, down_w_t) = apply_to_tensors(
        t,
        fp.layer(l, "up.w"),
        fp.layer(l, "up.b"),
        fp.layer(l, "down.w"),
    );
    if device_quant {
        // FP values; the Pallas program quantizes at upload (deterministic,
        // so accepted copies re-quantize identically on revert)
        LayerTensors { up_w: up_w_t, up_b: up_b_t, down_w: down_w_t }
    } else {
        let up_q = prepared.quantize_tensor(&format!("l{l}.up.w"), &up_w_t, Some(t));
        let down_q = prepared.quantize_tensor(&format!("l{l}.down.w"), &down_w_t, Some(t));
        LayerTensors { up_w: up_q, up_b: up_b_t, down_w: down_q }
    }
}

pub struct XlaObjective {
    prepared: Prepared,
    pub eval: Evaluator,
    /// Accepted quantized FFN tensors per layer (revert source).
    accepted: Vec<LayerTensors>,
    /// Pending evaluations of the most recent `eval_drafts` batch, keyed by
    /// layer; cleared by any commit (the batch's other losses go stale).
    round: HashMap<usize, Pending>,
    /// Quantize RTN proposals on device via the Pallas program.
    pub device_quant: bool,
}

impl XlaObjective {
    /// `eval` must already hold the uploaded FP weights of `prepared.fp`
    /// and captured H₀ (see `coordinator::pipeline`).
    ///
    /// RTN proposals *can* run the fake-quant on device through the
    /// standalone Pallas program (`INVAREXPLORE_DEVICE_QUANT=1`).  Under the
    /// CPU PJRT client the interpret-mode kernel executes its grid as an
    /// XLA while-loop (~75× the host codec, see EXPERIMENTS.md §Perf), so
    /// the default is the bit-identical host codec; the Pallas path is
    /// exercised by the cross-check tests and is the intended TPU route.
    pub fn new(prepared: Prepared, eval: Evaluator) -> XlaObjective {
        let device_quant = matches!(prepared.quantizer, Quantizer::Plain)
            && std::env::var("INVAREXPLORE_DEVICE_QUANT").as_deref() == Ok("1");
        XlaObjective {
            prepared,
            eval,
            accepted: Vec::new(),
            round: HashMap::new(),
            device_quant,
        }
    }

    fn config(&self) -> &crate::model::OptConfig {
        &self.prepared.fp.config
    }

    fn quant_scheme(&self) -> Option<crate::quant::QuantScheme> {
        self.device_quant.then_some(self.prepared.scheme)
    }

    fn payload(draft: &Draft) -> &LayerTensors {
        draft
            .payload
            .downcast_ref::<LayerTensors>()
            .expect("XlaObjective drafts carry LayerTensors payloads")
    }
}

impl Objective for XlaObjective {
    fn n_layers(&self) -> usize {
        self.config().n_layers
    }

    fn d_ffn(&self) -> usize {
        self.config().d_ffn
    }

    /// Quantize every linear under the base method (identity transforms),
    /// upload, and run the first full evaluation.
    fn init(&mut self) -> crate::Result<Loss> {
        let fp = &self.prepared.fp;
        let cfg = self.config().clone();
        // attention projections: quantized once, never touched by the search
        for l in 0..cfg.n_layers {
            for base in ["q.w", "k.w", "v.w", "o.w"] {
                let name = format!("l{l}.{base}");
                if self.device_quant {
                    let t = fp.get(&name).clone();
                    self.eval
                        .engine
                        .update_tensor_device_quant(&name, &t, self.prepared.scheme)?;
                } else {
                    let q = self.prepared.quantize_tensor(&name, fp.get(&name), None);
                    self.eval.engine.update_tensor(&name, &q)?;
                }
            }
        }
        // FFN tensors via the shared drafting path (identity transform)
        self.accepted.clear();
        self.round.clear();
        for l in 0..cfg.n_layers {
            let t = LayerTransform::identity(cfg.d_ffn);
            let tensors = draft_tensors(&self.prepared, self.device_quant, l, &t);
            self.eval.engine.upload_ffn(
                l,
                &tensors.up_w,
                &tensors.up_b,
                &tensors.down_w,
                self.quant_scheme(),
            )?;
            self.accepted.push(tensors);
        }
        self.eval.full_eval()
    }

    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
        let prepared = &self.prepared;
        let device_quant = self.device_quant;
        let threads = pool::num_threads().min(reqs.len().max(1));
        Ok(pool::parallel_map(reqs.len(), threads, |i| {
            let r = &reqs[i];
            let tensors = draft_tensors(prepared, device_quant, r.layer, &r.transform);
            Draft {
                layer: r.layer,
                transform: r.transform.clone(),
                payload: Box::new(tensors),
            }
        }))
    }

    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
        anyhow::ensure!(
            self.accepted.len() == self.n_layers(),
            "eval_drafts before init"
        );
        self.round.clear();
        let layers: Vec<usize> = drafts.iter().map(|d| d.layer).collect();
        let scheme = self.quant_scheme();
        let accepted = &self.accepted;
        let pendings = self.eval.eval_proposals(
            &layers,
            |engine, i| {
                let t = Self::payload(&drafts[i]);
                engine.upload_ffn(drafts[i].layer, &t.up_w, &t.up_b, &t.down_w, scheme)
            },
            |engine, i| {
                let a = &accepted[drafts[i].layer];
                engine.upload_ffn(drafts[i].layer, &a.up_w, &a.up_b, &a.down_w, scheme)
            },
        )?;
        let mut losses = Vec::with_capacity(pendings.len());
        for (d, p) in drafts.iter().zip(pendings) {
            losses.push(p.loss);
            self.round.insert(d.layer, p);
        }
        Ok(losses)
    }

    // Commit re-uploads the chosen tensors because eval_drafts always
    // restores the accepted state (isolation).  That costs one extra FFN
    // upload per *accepted* proposal vs the old leave-candidate-on-device
    // flow — small next to the suffix evaluation a proposal already pays,
    // and it keeps the protocol stateless between eval and commit.
    fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
        let pending = self.round.remove(&draft.layer).ok_or_else(|| {
            anyhow::anyhow!("commit without a pending eval for layer {}", draft.layer)
        })?;
        // any other pendings of the batch are stale once the model changes
        self.round.clear();
        let tensors = *draft
            .payload
            .downcast::<LayerTensors>()
            .map_err(|_| anyhow::anyhow!("XlaObjective drafts carry LayerTensors payloads"))?;
        self.eval.engine.upload_ffn(
            draft.layer,
            &tensors.up_w,
            &tensors.up_b,
            &tensors.down_w,
            self.quant_scheme(),
        )?;
        // a cold-cache pending (round-shared-prefix path) only covers its
        // suffix layers; it cannot splice, so rebuild via a full evaluation
        let loss = if self.eval.can_accept(&pending) {
            let loss = pending.loss;
            self.eval.accept(pending);
            loss
        } else {
            self.eval.full_eval()?
        };
        self.accepted[draft.layer] = tensors;
        Ok(loss)
    }
}

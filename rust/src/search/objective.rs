//! The real search objective: apply a move → re-quantize → evaluate on the
//! AOT XLA programs, speaking the draft / evaluate / commit protocol.
//!
//! **Transform moves** (Eqns. 21–22): per proposal for layer *l*, only
//! three tensors change — `up.w`, `up.b`, `down.w` (`down.b` is untouched).
//! Drafting — transform application plus re-quantization under the base
//! method's semantics and the tensor's *allocated* scheme — is pure
//! host-side work on the base FP weights, independent of every other
//! layer's accepted state, so a round of K drafts fans out across
//! [`crate::util::pool::parallel_map`].
//!
//! **Bit-swap moves** (mixed-precision PR): the donor and receiver tensors
//! are re-quantized from the base FP weights under their new bit widths
//! (FFN tensors first re-apply the accepted transform that rides along on
//! the [`BitSwap`]), swapped onto the device for scoring from the lowest
//! affected layer, and folded into the accepted allocation on commit.
//!
//! **Evaluation** swaps each candidate's tensors onto the device, scores it
//! through the incremental evaluator (layers ≥ the candidate's entry layer
//! only), and restores the accepted tensors, so candidates never observe
//! each other.  **Commit** re-uploads the chosen candidate and splices its
//! pending activation buffers into the accepted prefix cache — no
//! re-evaluation.
//!
//! RTN proposals can re-quantize on device through the standalone Pallas
//! fake-quant program (`INVAREXPLORE_DEVICE_QUANT=1`) — *uniform
//! allocations only*: the device path routes whole layers through one
//! scheme, so allocation moves always use the host codec.

use std::collections::HashMap;

use super::alloc::BitSwap;
use super::hillclimb::{Draft, DraftRequest, Move, Objective};
use crate::baselines::{Prepared, Quantizer};
use crate::runtime::evaluator::Pending;
use crate::runtime::{Evaluator, Loss};
use crate::tensor::Tensor;
use crate::transform::{apply_to_tensors, LayerTransform};
use crate::util::pool;

/// The three searched tensors of one layer: draft payload and accepted
/// revert source.  Host-quantized values, or FP-transformed values when the
/// Pallas device-quant path re-quantizes at upload.
struct LayerTensors {
    up_w: Tensor,
    up_b: Tensor,
    down_w: Tensor,
}

/// Bit-swap draft payload: the two re-quantized tensors under their new
/// schemes, plus the swap itself (commit updates the accepted allocation).
struct SwapTensors {
    donor: (String, usize, Tensor),
    receiver: (String, usize, Tensor),
}

/// Draft payload — transform or bit swap.
enum Payload {
    Ffn(LayerTensors),
    Swap(SwapTensors),
}

/// Host-side drafting of a transform move: apply `t` to layer `l` of the
/// base FP weights and re-quantize under the method's semantics at each
/// tensor's allocated scheme.  `&Prepared` only — safe to fan out across
/// worker threads.
fn draft_tensors(prepared: &Prepared, device_quant: bool, l: usize, t: &LayerTransform) -> LayerTensors {
    let fp = &prepared.fp;
    let (up_w_t, up_b_t, down_w_t) = apply_to_tensors(
        t,
        fp.layer(l, "up.w"),
        fp.layer(l, "up.b"),
        fp.layer(l, "down.w"),
    );
    if device_quant {
        // FP values; the Pallas program quantizes at upload (deterministic,
        // so accepted copies re-quantize identically on revert)
        LayerTensors { up_w: up_w_t, up_b: up_b_t, down_w: down_w_t }
    } else {
        let up_q = prepared.quantize_tensor(&format!("l{l}.up.w"), &up_w_t, Some(t));
        let down_q = prepared.quantize_tensor(&format!("l{l}.down.w"), &down_w_t, Some(t));
        LayerTensors { up_w: up_q, up_b: up_b_t, down_w: down_q }
    }
}

/// Re-quantize one swap-eligible tensor from the base FP weights at an
/// explicit scheme, re-applying the layer's accepted FFN transform when one
/// is given.  Shared by bit-swap drafting and allocation-checkpoint
/// restore.
fn requant_at(
    prepared: &Prepared,
    name: &str,
    layer: usize,
    transform: Option<&LayerTransform>,
    scheme: crate::quant::QuantScheme,
) -> Tensor {
    let fp = &prepared.fp;
    let src;
    let (w, t): (&Tensor, Option<&LayerTransform>) = match transform {
        Some(t) if name.ends_with("up.w") || name.ends_with("down.w") => {
            let (up_w_t, _, down_w_t) = apply_to_tensors(
                t,
                fp.layer(layer, "up.w"),
                fp.layer(layer, "up.b"),
                fp.layer(layer, "down.w"),
            );
            src = if name.ends_with("up.w") { up_w_t } else { down_w_t };
            (&src, Some(t))
        }
        _ => (fp.get(name), transform),
    };
    prepared.quantize_tensor_with(name, w, scheme, t)
}

/// Host-side drafting of one side of a bit swap: re-quantize `name` at
/// `bits_delta` bits relative to its accepted scheme.
fn draft_swap_tensor(
    prepared: &Prepared,
    name: &str,
    layer: usize,
    transform: &Option<LayerTransform>,
    bits_delta: i64,
) -> Tensor {
    let old = prepared.alloc.scheme_for(name);
    let bits = (old.bits as i64 + bits_delta) as usize;
    let scheme = crate::quant::QuantScheme::new(bits, old.group);
    requant_at(prepared, name, layer, transform.as_ref(), scheme)
}

pub struct XlaObjective {
    prepared: Prepared,
    pub eval: Evaluator,
    /// Accepted quantized FFN tensors per layer (revert source).
    accepted: Vec<LayerTensors>,
    /// Accepted quantized attention tensors (bit-swap revert source).
    accepted_attn: HashMap<String, Tensor>,
    /// Pending evaluations of the most recent `eval_drafts` batch, keyed by
    /// layer; cleared by any commit (the batch's other losses go stale).
    round: HashMap<usize, Pending>,
    /// Quantize RTN proposals on device via the Pallas program.
    pub device_quant: bool,
}

impl XlaObjective {
    /// `eval` must already hold the uploaded FP weights of `prepared.fp`
    /// and captured H₀ (see `coordinator::pipeline`).
    ///
    /// RTN proposals *can* run the fake-quant on device through the
    /// standalone Pallas program (`INVAREXPLORE_DEVICE_QUANT=1`).  Under the
    /// CPU PJRT client the interpret-mode kernel executes its grid as an
    /// XLA while-loop (~75× the host codec, see EXPERIMENTS.md §Perf), so
    /// the default is the bit-identical host codec; the Pallas path is
    /// exercised by the cross-check tests and is the intended TPU route.
    /// Mixed (non-uniform) allocations always use the host codec.
    pub fn new(prepared: Prepared, eval: Evaluator) -> XlaObjective {
        let device_quant = matches!(prepared.quantizer, Quantizer::Plain)
            && prepared.alloc.is_uniform()
            && std::env::var("INVAREXPLORE_DEVICE_QUANT").as_deref() == Ok("1");
        XlaObjective {
            prepared,
            eval,
            accepted: Vec::new(),
            accepted_attn: HashMap::new(),
            round: HashMap::new(),
            device_quant,
        }
    }

    fn config(&self) -> &crate::model::OptConfig {
        &self.prepared.fp.config
    }

    /// The accepted per-tensor allocation (bit swaps commit into it).
    pub fn allocation(&self) -> &crate::quant::BitAllocation {
        &self.prepared.alloc
    }

    fn quant_scheme(&self) -> Option<crate::quant::QuantScheme> {
        self.device_quant.then_some(self.prepared.scheme)
    }

    fn payload(draft: &Draft) -> &Payload {
        draft
            .payload
            .downcast_ref::<Payload>()
            .expect("XlaObjective drafts carry Payload")
    }

    /// Re-materialize a checkpointed per-tensor allocation (the resume
    /// path): every tensor whose scheme differs from the current accepted
    /// allocation is re-quantized from the base FP weights — FFN tensors
    /// under the checkpoint's accepted `transforms` — re-uploaded, and
    /// folded into the accepted allocation; returns a fresh full
    /// evaluation.  Must run after `init` (and after the transforms
    /// themselves have been re-committed).
    pub fn restore_allocation(
        &mut self,
        entries: &[super::alloc::AllocEntry],
        transforms: &[LayerTransform],
    ) -> crate::Result<Loss> {
        anyhow::ensure!(
            self.accepted.len() == self.n_layers(),
            "restore_allocation before init"
        );
        anyhow::ensure!(
            !self.device_quant,
            "allocation restore requires the host quantizer (unset INVAREXPLORE_DEVICE_QUANT)"
        );
        self.round.clear();
        for e in entries {
            if self.prepared.alloc.scheme_for(&e.name) == e.scheme {
                continue;
            }
            let is_ffn = e.name.ends_with("up.w") || e.name.ends_with("down.w");
            let t = if is_ffn { transforms.get(e.layer) } else { None };
            let q = requant_at(&self.prepared, &e.name, e.layer, t, e.scheme);
            self.eval.engine.update_tensor(&e.name, &q)?;
            self.prepared.alloc.set_scheme(&e.name, e.scheme);
            if e.name.ends_with("up.w") {
                self.accepted[e.layer].up_w = q;
            } else if e.name.ends_with("down.w") {
                self.accepted[e.layer].down_w = q;
            } else {
                self.accepted_attn.insert(e.name.clone(), q);
            }
        }
        self.eval.full_eval()
    }
}

/// Host-side drafting of one move — free function over `&Prepared` only,
/// so a round of drafts fans out across worker threads (the engine's
/// device handles never cross a thread boundary).
fn draft_payload(
    prepared: &Prepared,
    device_quant: bool,
    r: &DraftRequest,
) -> crate::Result<Payload> {
    match &r.mv {
        Move::Transform(t) => Ok(Payload::Ffn(draft_tensors(prepared, device_quant, r.layer, t))),
        Move::BitSwap(s) => {
            anyhow::ensure!(
                !device_quant,
                "allocation moves require the host quantizer (unset INVAREXPLORE_DEVICE_QUANT)"
            );
            let donor = draft_swap_tensor(prepared, &s.donor, s.donor_layer, &s.donor_transform, -1);
            let receiver =
                draft_swap_tensor(prepared, &s.receiver, s.receiver_layer, &s.receiver_transform, 1);
            Ok(Payload::Swap(SwapTensors {
                donor: (s.donor.clone(), s.donor_layer, donor),
                receiver: (s.receiver.clone(), s.receiver_layer, receiver),
            }))
        }
    }
}

impl Objective for XlaObjective {
    fn n_layers(&self) -> usize {
        self.config().n_layers
    }

    fn d_ffn(&self) -> usize {
        self.config().d_ffn
    }

    /// Quantize every linear under the base method (identity transforms),
    /// upload, and run the first full evaluation.
    fn init(&mut self) -> crate::Result<Loss> {
        let cfg = self.config().clone();
        // attention projections: quantized once, touched again only by
        // bit-swap moves
        self.accepted_attn.clear();
        for l in 0..cfg.n_layers {
            for base in ["q.w", "k.w", "v.w", "o.w"] {
                let name = format!("l{l}.{base}");
                if self.device_quant {
                    let t = self.prepared.fp.get(&name).clone();
                    self.eval
                        .engine
                        .update_tensor_device_quant(&name, &t, self.prepared.scheme)?;
                } else {
                    let q = self
                        .prepared
                        .quantize_tensor(&name, self.prepared.fp.get(&name), None);
                    self.eval.engine.update_tensor(&name, &q)?;
                    self.accepted_attn.insert(name, q);
                }
            }
        }
        // FFN tensors via the shared drafting path (identity transform)
        self.accepted.clear();
        self.round.clear();
        for l in 0..cfg.n_layers {
            let t = LayerTransform::identity(cfg.d_ffn);
            let tensors = draft_tensors(&self.prepared, self.device_quant, l, &t);
            self.eval.engine.upload_ffn(
                l,
                &tensors.up_w,
                &tensors.up_b,
                &tensors.down_w,
                self.quant_scheme(),
            )?;
            self.accepted.push(tensors);
        }
        self.eval.full_eval()
    }

    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
        let prepared = &self.prepared;
        let device_quant = self.device_quant;
        let threads = pool::num_threads().min(reqs.len().max(1));
        let payloads = pool::parallel_map(reqs.len(), threads, |i| {
            draft_payload(prepared, device_quant, &reqs[i])
        });
        let mut out = Vec::with_capacity(reqs.len());
        for (p, r) in payloads.into_iter().zip(reqs) {
            out.push(Draft { layer: r.layer, mv: r.mv.clone(), payload: Box::new(p?) });
        }
        Ok(out)
    }

    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
        anyhow::ensure!(
            self.accepted.len() == self.n_layers(),
            "eval_drafts before init"
        );
        self.round.clear();
        let layers: Vec<usize> = drafts.iter().map(|d| d.layer).collect();
        let scheme = self.quant_scheme();
        let accepted = &self.accepted;
        let accepted_attn = &self.accepted_attn;
        let pendings = self.eval.eval_proposals(
            &layers,
            |engine, i| match Self::payload(&drafts[i]) {
                Payload::Ffn(t) => {
                    engine.upload_ffn(drafts[i].layer, &t.up_w, &t.up_b, &t.down_w, scheme)
                }
                Payload::Swap(s) => {
                    engine.update_tensor(&s.donor.0, &s.donor.2)?;
                    engine.update_tensor(&s.receiver.0, &s.receiver.2)
                }
            },
            |engine, i| match Self::payload(&drafts[i]) {
                Payload::Ffn(_) => {
                    let a = &accepted[drafts[i].layer];
                    engine.upload_ffn(drafts[i].layer, &a.up_w, &a.up_b, &a.down_w, scheme)
                }
                Payload::Swap(s) => {
                    for (name, layer, _) in [&s.donor, &s.receiver] {
                        let acc = if name.ends_with("up.w") {
                            &accepted[*layer].up_w
                        } else if name.ends_with("down.w") {
                            &accepted[*layer].down_w
                        } else {
                            accepted_attn
                                .get(name)
                                .unwrap_or_else(|| panic!("no accepted copy of {name:?}"))
                        };
                        engine.update_tensor(name, acc)?;
                    }
                    Ok(())
                }
            },
        )?;
        let mut losses = Vec::with_capacity(pendings.len());
        for (d, p) in drafts.iter().zip(pendings) {
            losses.push(p.loss);
            self.round.insert(d.layer, p);
        }
        Ok(losses)
    }

    // Commit re-uploads the chosen tensors because eval_drafts always
    // restores the accepted state (isolation).  That costs one extra upload
    // per *accepted* proposal vs the old leave-candidate-on-device flow —
    // small next to the suffix evaluation a proposal already pays, and it
    // keeps the protocol stateless between eval and commit.
    fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
        let pending = self.round.remove(&draft.layer).ok_or_else(|| {
            anyhow::anyhow!("commit without a pending eval for layer {}", draft.layer)
        })?;
        // any other pendings of the batch are stale once the model changes
        self.round.clear();
        let payload = *draft
            .payload
            .downcast::<Payload>()
            .map_err(|_| anyhow::anyhow!("XlaObjective drafts carry Payload"))?;
        match payload {
            Payload::Ffn(tensors) => {
                self.eval.engine.upload_ffn(
                    draft.layer,
                    &tensors.up_w,
                    &tensors.up_b,
                    &tensors.down_w,
                    self.quant_scheme(),
                )?;
                self.accepted[draft.layer] = tensors;
            }
            Payload::Swap(s) => {
                anyhow::ensure!(draft.mv.as_swap().is_some(), "swap payload without a swap move");
                for ((name, _, t), delta) in [(&s.donor, -1i64), (&s.receiver, 1)] {
                    self.eval.engine.update_tensor(name, t)?;
                    // fold the new scheme into the accepted allocation
                    let old = self.prepared.alloc.scheme_for(name);
                    let bits = (old.bits as i64 + delta) as usize;
                    self.prepared
                        .alloc
                        .set_scheme(name, crate::quant::QuantScheme::new(bits, old.group));
                }
                // store the accepted copies
                for (name, layer, t) in [s.donor, s.receiver] {
                    if name.ends_with("up.w") {
                        self.accepted[layer].up_w = t;
                    } else if name.ends_with("down.w") {
                        self.accepted[layer].down_w = t;
                    } else {
                        self.accepted_attn.insert(name, t);
                    }
                }
            }
        }
        // a cold-cache pending (round-shared-prefix path) only covers its
        // suffix layers; it cannot splice, so rebuild via a full evaluation
        let loss = if self.eval.can_accept(&pending) {
            let loss = pending.loss;
            self.eval.accept(pending);
            loss
        } else {
            self.eval.full_eval()?
        };
        Ok(loss)
    }
}

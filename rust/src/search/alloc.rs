//! Mixed-precision allocation state for the discrete search, and the
//! budget-preserving **bit-swap** move.
//!
//! The search treats the per-tensor bit widths as one more discrete axis
//! next to the invariance transforms: a proposal either mutates one layer's
//! FFN transform (the original InvarExplore move) or *swaps a bit* — steal
//! one bit from a donor tensor, grant one to a receiver tensor — subject to
//! the global [`AllocState::budget`] in bits/param.  Equal-size tensor
//! pairs (any two attention projections, or `up.w`/`down.w` across layers)
//! swap at exactly constant bits/param; unequal pairs are admitted only
//! when the resulting allocation stays at or under the budget, so the
//! accepted allocation can only ever get *cheaper* than the budget, never
//! more expensive.

use crate::model::OptConfig;
use crate::quant::{BitAllocation, QuantScheme};
use crate::transform::LayerTransform;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// One quantizable tensor tracked by the allocation search.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocEntry {
    pub name: String,
    pub layer: usize,
    pub numel: usize,
    pub scheme: QuantScheme,
}

/// The bit-swap proposal: `donor` loses one bit, `receiver` gains one.
///
/// The accepted FFN transform of each affected layer rides along (filled by
/// the driver at proposal time, `None` for attention tensors), so an
/// objective can re-quantize the affected tensors from the base FP weights
/// without reaching back into the search state.
#[derive(Debug, Clone)]
pub struct BitSwap {
    pub donor: String,
    pub donor_layer: usize,
    pub receiver: String,
    pub receiver_layer: usize,
    pub donor_transform: Option<LayerTransform>,
    pub receiver_transform: Option<LayerTransform>,
}

impl BitSwap {
    /// The round scheduler's resource key: drafts must touch distinct
    /// layers to be independently scorable, and a swap occupies both of its
    /// tensors' layers.
    pub fn min_layer(&self) -> usize {
        self.donor_layer.min(self.receiver_layer)
    }
}

/// Accepted per-tensor allocation + the global budget, owned by
/// [`super::SearchState`] when allocation search is enabled.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocState {
    pub entries: Vec<AllocEntry>,
    /// Bits/param ceiling; set to the starting allocation's bits/param.
    pub budget: f64,
}

fn is_ffn(name: &str) -> bool {
    name.ends_with("up.w") || name.ends_with("down.w")
}

impl AllocState {
    /// Track every quantizable tensor of `cfg`, starting from `alloc`.
    /// The budget is the starting allocation's own bits/param.
    pub fn new(cfg: &OptConfig, alloc: &BitAllocation) -> AllocState {
        let entries = cfg
            .quant_names()
            .iter()
            .map(|name| {
                let (r, c) = cfg.param_shape(name).expect("quant names are known params");
                let layer = crate::model::config::split_layer_prefix(name)
                    .0
                    .expect("quant names carry a layer prefix");
                AllocEntry {
                    name: name.clone(),
                    layer,
                    numel: r * c,
                    scheme: alloc.scheme_for(name),
                }
            })
            .collect();
        let mut st = AllocState { entries, budget: 0.0 };
        st.budget = st.bits_per_param();
        st
    }

    /// Build from an explicit tensor list (synthetic objectives).  Budget
    /// defaults to the starting bits/param when `budget` is `None`.
    pub fn from_entries(entries: Vec<AllocEntry>, budget: Option<f64>) -> AllocState {
        let mut st = AllocState { entries, budget: 0.0 };
        st.budget = budget.unwrap_or_else(|| st.bits_per_param());
        st
    }

    /// Size-weighted mean bits/param of the current allocation.
    pub fn bits_per_param(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for e in &self.entries {
            num += e.numel as f64 * e.scheme.bits_per_param();
            den += e.numel as f64;
        }
        num / den.max(1.0)
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// Current scheme of one tracked tensor.
    pub fn scheme_of(&self, name: &str) -> Option<QuantScheme> {
        self.index_of(name).map(|i| self.entries[i].scheme)
    }

    /// Would swapping a bit from `entries[d]` to `entries[r]` be legal?
    /// Distinct tensors, donor stays >= 1 bit, receiver stays <= 8 bits,
    /// and the resulting allocation does not exceed the budget.
    pub fn swap_is_valid(&self, d: usize, r: usize) -> bool {
        if d == r {
            return false;
        }
        let (donor, recv) = (&self.entries[d], &self.entries[r]);
        if donor.scheme.bits <= 1 || recv.scheme.bits >= 8 {
            return false;
        }
        let mut total = 0.0;
        let mut den = 0.0;
        for (i, e) in self.entries.iter().enumerate() {
            let bits = if i == d {
                e.scheme.bits - 1
            } else if i == r {
                e.scheme.bits + 1
            } else {
                e.scheme.bits
            };
            total += e.numel as f64 * QuantScheme::new(bits, e.scheme.group).bits_per_param();
            den += e.numel as f64;
        }
        total / den.max(1.0) <= self.budget + 1e-9
    }

    /// Draw a budget-preserving swap by rejection sampling (bounded at
    /// `tries` draws so the RNG stream stays deterministic).  `free` — when
    /// given — restricts both affected layers to unclaimed round slots;
    /// `transforms` supplies the accepted FFN transform each affected FFN
    /// tensor must be re-quantized under.
    pub fn propose(
        &self,
        rng: &mut Pcg64,
        transforms: &[LayerTransform],
        free: Option<&[bool]>,
        tries: usize,
    ) -> Option<BitSwap> {
        let n = self.entries.len();
        if n < 2 {
            return None;
        }
        for _ in 0..tries {
            let d = rng.below(n);
            let r = rng.below(n);
            if !self.swap_is_valid(d, r) {
                continue;
            }
            let (donor, recv) = (&self.entries[d], &self.entries[r]);
            if let Some(free) = free {
                if !free[donor.layer] || (donor.layer != recv.layer && !free[recv.layer]) {
                    continue;
                }
            }
            let t_of = |e: &AllocEntry| {
                (is_ffn(&e.name) && e.layer < transforms.len())
                    .then(|| transforms[e.layer].clone())
            };
            return Some(BitSwap {
                donor: donor.name.clone(),
                donor_layer: donor.layer,
                receiver: recv.name.clone(),
                receiver_layer: recv.layer,
                donor_transform: t_of(donor),
                receiver_transform: t_of(recv),
            });
        }
        None
    }

    /// Commit a swap into the accepted allocation.
    pub fn apply(&mut self, swap: &BitSwap) {
        let d = self.index_of(&swap.donor).expect("donor tracked");
        let r = self.index_of(&swap.receiver).expect("receiver tracked");
        assert!(self.swap_is_valid(d, r), "applying an invalid bit swap");
        self.entries[d].scheme.bits -= 1;
        self.entries[r].scheme.bits += 1;
        debug_assert!(self.bits_per_param() <= self.budget + 1e-9);
    }

    /// Export the searched allocation as a [`BitAllocation`] (exact
    /// per-tensor overrides for every tensor that differs from `default`).
    pub fn to_allocation(&self, default: QuantScheme) -> BitAllocation {
        let mut alloc = BitAllocation::uniform(default);
        for e in &self.entries {
            if e.scheme != default {
                alloc.set_scheme(&e.name, e.scheme);
            }
        }
        alloc
    }

    pub fn to_json(&self) -> Json {
        Json::obj().set("budget", self.budget).set(
            "entries",
            Json::Arr(
                self.entries
                    .iter()
                    .map(|e| {
                        Json::obj()
                            .set("name", e.name.as_str())
                            .set("layer", e.layer)
                            .set("numel", e.numel)
                            .set("bits", e.scheme.bits)
                            .set("group", e.scheme.group)
                    })
                    .collect(),
            ),
        )
    }

    pub fn from_json(j: &Json) -> crate::Result<AllocState> {
        let entries = j
            .req("entries")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|e| {
                Ok(AllocEntry {
                    name: e.req("name")?.as_str().unwrap_or("").to_string(),
                    layer: e.req("layer")?.as_usize().unwrap_or(0),
                    numel: e.req("numel")?.as_usize().unwrap_or(0),
                    scheme: QuantScheme::new(
                        e.req("bits")?.as_usize().unwrap_or(2),
                        e.req("group")?.as_usize().unwrap_or(64),
                    ),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        anyhow::ensure!(!entries.is_empty(), "empty allocation state");
        Ok(AllocState {
            entries,
            budget: j.req("budget")?.as_f64().unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 layers x {up.w, down.w}, equal sizes -> every swap is exactly
    /// budget-preserving.
    pub(crate) fn ffn_entries(n_layers: usize, scheme: QuantScheme) -> Vec<AllocEntry> {
        let mut out = Vec::new();
        for l in 0..n_layers {
            for base in ["up.w", "down.w"] {
                out.push(AllocEntry {
                    name: format!("l{l}.{base}"),
                    layer: l,
                    numel: 4096,
                    scheme,
                });
            }
        }
        out
    }

    #[test]
    fn new_tracks_every_quant_tensor() {
        let cfg = OptConfig::test_config();
        let st = AllocState::new(&cfg, &BitAllocation::uniform(QuantScheme::new(2, 32)));
        assert_eq!(st.entries.len(), cfg.quant_names().len());
        assert_eq!(st.scheme_of("l1.down.w"), Some(QuantScheme::new(2, 32)));
        assert_eq!(st.entries[0].layer, 0);
        assert!((st.budget - QuantScheme::new(2, 32).bits_per_param()).abs() < 1e-12);
    }

    #[test]
    fn equal_size_swap_preserves_budget_exactly() {
        let mut st = AllocState::from_entries(ffn_entries(2, QuantScheme::new(2, 64)), None);
        let before = st.bits_per_param();
        let swap = BitSwap {
            donor: "l0.up.w".into(),
            donor_layer: 0,
            receiver: "l1.down.w".into(),
            receiver_layer: 1,
            donor_transform: None,
            receiver_transform: None,
        };
        st.apply(&swap);
        assert_eq!(st.scheme_of("l0.up.w").unwrap().bits, 1);
        assert_eq!(st.scheme_of("l1.down.w").unwrap().bits, 3);
        assert!((st.bits_per_param() - before).abs() < 1e-12);
    }

    #[test]
    fn swap_validity_respects_bit_range_and_budget() {
        let mut entries = ffn_entries(1, QuantScheme::new(2, 64));
        entries[0].scheme = QuantScheme::new(1, 64); // can't donate below 1 bit
        entries[1].scheme = QuantScheme::new(8, 64); // can't receive past 8
        let st = AllocState::from_entries(entries, None);
        assert!(!st.swap_is_valid(0, 1));
        assert!(!st.swap_is_valid(0, 0));
        // 8-bit tensor can donate to the 1-bit tensor
        assert!(st.swap_is_valid(1, 0));

        // unequal sizes: granting to the BIGGER tensor would exceed budget
        let entries = vec![
            AllocEntry { name: "l0.up.w".into(), layer: 0, numel: 64, scheme: QuantScheme::new(2, 64) },
            AllocEntry { name: "l0.down.w".into(), layer: 0, numel: 4096, scheme: QuantScheme::new(2, 64) },
        ];
        let st = AllocState::from_entries(entries, None);
        assert!(!st.swap_is_valid(0, 1), "small donor, big receiver must exceed budget");
        assert!(st.swap_is_valid(1, 0), "big donor, small receiver stays under budget");
    }

    #[test]
    fn propose_is_deterministic_and_valid() {
        let st = AllocState::from_entries(ffn_entries(3, QuantScheme::new(2, 64)), None);
        let transforms = vec![LayerTransform::identity(8); 3];
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        let a = st.propose(&mut r1, &transforms, None, 32).unwrap();
        let b = st.propose(&mut r2, &transforms, None, 32).unwrap();
        assert_eq!((a.donor.clone(), a.receiver.clone()), (b.donor, b.receiver));
        assert_ne!(a.donor, a.receiver);
        // FFN tensors carry their layer's accepted transform
        assert!(a.donor_transform.is_some());
        assert_eq!(a.min_layer(), a.donor_layer.min(a.receiver_layer));
    }

    #[test]
    fn propose_honors_free_mask() {
        let st = AllocState::from_entries(ffn_entries(3, QuantScheme::new(2, 64)), None);
        let transforms = vec![LayerTransform::identity(8); 3];
        let mut rng = Pcg64::new(4);
        // only layer 2 free -> both endpoints must live in layer 2
        let free = [false, false, true];
        for _ in 0..10 {
            if let Some(s) = st.propose(&mut rng, &transforms, Some(&free), 64) {
                assert_eq!(s.donor_layer, 2);
                assert_eq!(s.receiver_layer, 2);
            }
        }
    }

    #[test]
    fn to_allocation_roundtrips_through_schemes() {
        let mut st = AllocState::from_entries(ffn_entries(2, QuantScheme::new(2, 64)), None);
        st.entries[0].scheme = QuantScheme::new(3, 64);
        st.entries[3].scheme = QuantScheme::new(1, 64);
        let alloc = st.to_allocation(QuantScheme::new(2, 64));
        for e in &st.entries {
            assert_eq!(alloc.scheme_for(&e.name), e.scheme, "{}", e.name);
        }
        assert_eq!(alloc.overrides.len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut st = AllocState::from_entries(ffn_entries(2, QuantScheme::new(2, 64)), None);
        st.entries[1].scheme = QuantScheme::new(4, 64);
        let j = st.to_json();
        let back = AllocState::from_json(&crate::util::json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(st, back);
    }
}

//! Synthetic, XLA-free objectives for driver tests and throughput benches.
//!
//! [`SynthObjective`] — transform moves only: loss = Σ per-layer
//! potentials; a layer's potential improves when its scale vector
//! approaches a hidden optimum.  Deterministic, no PJRT.  The `draft_work`
//! knob adds a configurable amount of real host-side re-quantization work
//! per draft (the codec the XLA objective runs per proposal), so
//! `benches/perf_hotpath.rs` can measure how K-wide rounds hide
//! per-candidate drafting latency.
//!
//! [`MixedSynthObjective`] — the mixed-precision landscape: the same
//! transform potentials plus a per-tensor quantization-error term
//! `Σ_t sens_t · numel_t · 4^{-bits_t} / Σ_t numel_t` (b-bit groupwise MSE
//! scales as 2^{-2b}), over one `up.w`/`down.w` pair per layer with
//! deliberately heterogeneous sensitivities.  Budget-preserving bit swaps
//! that move bits toward sensitive tensors strictly lower the loss, so a
//! searched allocation beats the uniform one at the same bits/param —
//! the `benches/mixed_precision.rs` acceptance pin.

use std::collections::HashMap;

use super::alloc::{AllocEntry, AllocState, BitSwap};
use super::hillclimb::{Draft, DraftRequest, Move, Objective};
use crate::quant::{self, QuantScheme};
use crate::runtime::Loss;
use crate::tensor::Tensor;
use crate::util::pool;
use crate::util::rng::Pcg64;

pub struct SynthObjective {
    n_layers: usize,
    d: usize,
    target: Vec<Vec<f32>>,
    current: Vec<Vec<f32>>,
    /// Pending losses of the last `eval_drafts` batch, keyed by layer.
    pending: HashMap<usize, Loss>,
    /// Elements of synthetic groupwise fake-quant run per draft (0 = none).
    pub draft_work: usize,
}

impl SynthObjective {
    pub fn new(n_layers: usize, d: usize) -> SynthObjective {
        let mut rng = Pcg64::new(99);
        let target = (0..n_layers)
            .map(|_| (0..d).map(|_| (rng.uniform() as f32) * 2.0 + 0.5).collect())
            .collect();
        SynthObjective {
            n_layers,
            d,
            target,
            current: vec![vec![1.0; d]; n_layers],
            pending: HashMap::new(),
            draft_work: 0,
        }
    }

    /// Like [`SynthObjective::new`] with `elems` of fake-quant work per
    /// draft (rounded up to whole 64-wide groups).
    pub fn with_draft_work(n_layers: usize, d: usize, elems: usize) -> SynthObjective {
        let mut o = SynthObjective::new(n_layers, d);
        o.draft_work = elems;
        o
    }

    fn layer_loss(&self, l: usize, s: &[f32]) -> f64 {
        s.iter()
            .zip(&self.target[l])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }

    fn total_with(&self, l: usize, s: &[f32]) -> Loss {
        let mut ce = 0.0;
        for i in 0..self.n_layers {
            ce += if i == l {
                self.layer_loss(i, s)
            } else {
                self.layer_loss(i, &self.current[i])
            };
        }
        Loss { ce, act_mse: 0.0 }
    }

    /// Current accepted total loss (test hook).
    pub fn current_total(&self) -> f64 {
        (0..self.n_layers).map(|l| self.layer_loss(l, &self.current[l])).sum()
    }

    /// The configurable host-side drafting cost: a groupwise fake-quant
    /// pass over a tensor seeded from the proposal's scale vector.
    fn burn(&self, req: &DraftRequest) {
        let Some(t) = req.mv.as_transform() else { return };
        if self.draft_work == 0 {
            return;
        }
        let cols = 64;
        let rows = self.draft_work.div_ceil(cols).max(1);
        let scale = &t.scale;
        let data: Vec<f32> = (0..rows * cols)
            .map(|i| scale[i % scale.len()] * ((i % 17) as f32 - 8.0))
            .collect();
        let t = Tensor::from_vec(rows, cols, data);
        std::hint::black_box(quant::fake_quant(&t, QuantScheme::new(2, 64)));
    }
}

impl Objective for SynthObjective {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn d_ffn(&self) -> usize {
        self.d
    }

    fn init(&mut self) -> crate::Result<Loss> {
        Ok(self.total_with(0, &self.current[0].clone()))
    }

    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
        let threads = pool::num_threads().min(reqs.len().max(1));
        Ok(pool::parallel_map(reqs.len(), threads, |i| {
            self.burn(&reqs[i]);
            Draft {
                layer: reqs[i].layer,
                mv: reqs[i].mv.clone(),
                payload: Box::new(()),
            }
        }))
    }

    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
        self.pending.clear();
        let mut out = Vec::with_capacity(drafts.len());
        for d in drafts {
            anyhow::ensure!(d.layer < self.n_layers, "draft layer out of range");
            let t = d.mv.as_transform().ok_or_else(|| {
                anyhow::anyhow!("SynthObjective does not support allocation moves")
            })?;
            let loss = self.total_with(d.layer, &t.scale);
            anyhow::ensure!(
                self.pending.insert(d.layer, loss).is_none(),
                "duplicate draft for layer {}",
                d.layer
            );
            out.push(loss);
        }
        Ok(out)
    }

    fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
        let loss = self
            .pending
            .get(&draft.layer)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("commit without a pending eval for layer {}", draft.layer))?;
        let t = draft
            .mv
            .as_transform()
            .ok_or_else(|| anyhow::anyhow!("SynthObjective does not support allocation moves"))?;
        self.current[draft.layer] = t.scale.clone();
        // committing invalidates every other pending of the batch
        self.pending.clear();
        Ok(loss)
    }
}

/// Synthetic mixed-precision objective (see module docs).
///
/// `ce = transform potential + alloc error`; both terms are deterministic,
/// so search runs are reproducible given a seed.  One `up.w`/`down.w`
/// tensor pair per layer, all of equal `numel`, so every bit swap is
/// *exactly* budget-preserving.
pub struct MixedSynthObjective {
    base: SynthObjective,
    /// Tensor name -> (sensitivity, numel).
    tensors: Vec<(String, f64, usize)>,
    /// Accepted bits per tensor.
    bits: HashMap<String, usize>,
    group: usize,
    /// Bits every tensor starts at (the uniform reference allocation).
    uniform_bits: usize,
    /// Pendings of the last eval batch: layer -> (loss, swap to apply).
    pending: HashMap<usize, (Loss, Option<(String, String)>)>,
}

/// Tensor universe of the synthetic mixed-precision landscape — shared by
/// the objective and [`MixedSynthObjective::alloc_state`] so the driver's
/// proposals always name tensors the objective tracks.
fn synth_tensors(n_layers: usize) -> Vec<(String, f64, usize)> {
    let mut rng = Pcg64::new(4242);
    let mut out = Vec::new();
    for l in 0..n_layers {
        for base in ["up.w", "down.w"] {
            // sensitivities spread over ~4 orders of magnitude: plenty of
            // strictly-improving swaps exist from any uniform start
            let sens = 10f64.powf(rng.uniform() * 4.0 - 2.0);
            out.push((format!("l{l}.{base}"), sens, 4096));
        }
    }
    out
}

impl MixedSynthObjective {
    pub fn new(n_layers: usize, d: usize, scheme: QuantScheme) -> MixedSynthObjective {
        let tensors = synth_tensors(n_layers);
        let bits = tensors.iter().map(|(n, _, _)| (n.clone(), scheme.bits)).collect();
        MixedSynthObjective {
            base: SynthObjective::new(n_layers, d),
            tensors,
            bits,
            group: scheme.group,
            uniform_bits: scheme.bits,
            pending: HashMap::new(),
        }
    }

    /// The matching driver-side allocation state (same tensor universe,
    /// budget = the uniform allocation's bits/param).
    pub fn alloc_state(&self) -> AllocState {
        let entries = self
            .tensors
            .iter()
            .map(|(name, _, numel)| AllocEntry {
                name: name.clone(),
                layer: crate::model::config::split_layer_prefix(name)
                    .0
                    .expect("synth tensors carry a layer prefix"),
                numel: *numel,
                scheme: QuantScheme::new(self.uniform_bits, self.group),
            })
            .collect();
        AllocState::from_entries(entries, None)
    }

    /// Allocation error term for a hypothetical bits map: the size-weighted
    /// sensitivity-scaled 4^{-bits} error.
    fn alloc_term_with(&self, swap: Option<(&str, &str)>) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (name, sens, numel) in &self.tensors {
            let mut b = self.bits[name];
            if let Some((donor, receiver)) = swap {
                if name == donor {
                    b -= 1;
                }
                if name == receiver {
                    b += 1;
                }
            }
            num += sens * *numel as f64 * 4f64.powi(-(b as i32));
            den += *numel as f64;
        }
        num / den
    }

    /// Accepted allocation error (test/bench hook).
    pub fn alloc_term(&self) -> f64 {
        self.alloc_term_with(None)
    }

    /// Allocation error of the uniform reference at the same budget.
    pub fn uniform_alloc_term(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (_, sens, numel) in &self.tensors {
            num += sens * *numel as f64 * 4f64.powi(-(self.uniform_bits as i32));
            den += *numel as f64;
        }
        num / den
    }

    /// Accepted total loss (test/bench hook).
    pub fn current_total(&self) -> f64 {
        self.base.current_total() + self.alloc_term()
    }

    fn swap_of(&self, s: &BitSwap) -> crate::Result<(String, String)> {
        anyhow::ensure!(
            self.bits.contains_key(&s.donor) && self.bits.contains_key(&s.receiver),
            "bit swap names an untracked tensor ({} -> {})",
            s.donor,
            s.receiver
        );
        anyhow::ensure!(self.bits[&s.donor] > 1, "donor {} already at 1 bit", s.donor);
        anyhow::ensure!(self.bits[&s.receiver] < 8, "receiver {} already at 8 bits", s.receiver);
        Ok((s.donor.clone(), s.receiver.clone()))
    }
}

impl Objective for MixedSynthObjective {
    fn n_layers(&self) -> usize {
        self.base.n_layers
    }

    fn d_ffn(&self) -> usize {
        self.base.d
    }

    fn init(&mut self) -> crate::Result<Loss> {
        let base = self.base.init()?;
        Ok(Loss { ce: base.ce + self.alloc_term(), act_mse: base.act_mse })
    }

    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
        Ok(reqs
            .iter()
            .map(|r| Draft {
                layer: r.layer,
                mv: r.mv.clone(),
                payload: Box::new(()),
            })
            .collect())
    }

    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
        self.pending.clear();
        let mut out = Vec::with_capacity(drafts.len());
        for d in drafts {
            anyhow::ensure!(d.layer < self.base.n_layers, "draft layer out of range");
            let (loss, swap) = match &d.mv {
                Move::Transform(t) => {
                    let base = self.base.total_with(d.layer, &t.scale);
                    (Loss { ce: base.ce + self.alloc_term(), act_mse: base.act_mse }, None)
                }
                Move::BitSwap(s) => {
                    let (donor, receiver) = self.swap_of(s)?;
                    let ce = self.base.total_with(0, &self.base.current[0].clone()).ce
                        + self.alloc_term_with(Some((donor.as_str(), receiver.as_str())));
                    (Loss { ce, act_mse: 0.0 }, Some((donor, receiver)))
                }
            };
            anyhow::ensure!(
                self.pending.insert(d.layer, (loss, swap)).is_none(),
                "duplicate draft for layer {}",
                d.layer
            );
            out.push(loss);
        }
        Ok(out)
    }

    fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
        let (loss, swap) = self
            .pending
            .get(&draft.layer)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("commit without a pending eval for layer {}", draft.layer))?;
        match (&draft.mv, swap) {
            (Move::Transform(t), None) => {
                self.base.current[draft.layer] = t.scale.clone();
            }
            (Move::BitSwap(_), Some((donor, receiver))) => {
                *self.bits.get_mut(&donor).unwrap() -= 1;
                *self.bits.get_mut(&receiver).unwrap() += 1;
            }
            _ => anyhow::bail!("pending/move mismatch at commit"),
        }
        self.pending.clear();
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::{LayerTransform, TransformKinds};

    fn proposal(d: usize, seed: u64) -> LayerTransform {
        let mut rng = Pcg64::new(seed);
        LayerTransform::identity(d).propose(
            &mut rng,
            TransformKinds::parse("s").unwrap(),
            0.5,
            0.4,
            0.0,
        )
    }

    #[test]
    fn commit_requires_prior_eval() {
        let mut obj = SynthObjective::new(2, 8);
        obj.init().unwrap();
        let req = DraftRequest::transform(0, proposal(8, 1));
        let one_draft = |obj: &SynthObjective| {
            obj.draft(std::slice::from_ref(&req)).unwrap().pop().unwrap()
        };
        assert!(obj.commit(one_draft(&obj)).is_err(), "commit before eval must fail");
        let mut drafts = obj.draft(std::slice::from_ref(&req)).unwrap();
        let losses = obj.eval_drafts(&drafts).unwrap();
        let committed = obj.commit(drafts.swap_remove(0)).unwrap();
        assert_eq!(losses[0], committed);
        // second commit after the batch was committed: pendings invalidated
        assert!(obj.commit(one_draft(&obj)).is_err());
    }

    #[test]
    fn eval_scores_candidates_independently() {
        let mut obj = SynthObjective::new(3, 8);
        obj.init().unwrap();
        let reqs: Vec<DraftRequest> =
            (0..3).map(|l| DraftRequest::transform(l, proposal(8, 10 + l as u64))).collect();
        let drafts = obj.draft(&reqs).unwrap();
        let batch = obj.eval_drafts(&drafts).unwrap();
        // one-at-a-time scoring must agree: candidates never see each other
        for (i, d) in drafts.iter().enumerate() {
            let single = obj.eval_drafts(std::slice::from_ref(d)).unwrap();
            assert_eq!(single[0], batch[i], "candidate {i} not independent");
        }
    }

    #[test]
    fn draft_work_burns_deterministically() {
        let obj = SynthObjective::with_draft_work(2, 8, 4096);
        let reqs: Vec<DraftRequest> =
            (0..2).map(|l| DraftRequest::transform(l, proposal(8, l as u64))).collect();
        let a = obj.draft(&reqs).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].layer, 0);
        assert_eq!(a[1].layer, 1);
    }

    #[test]
    fn synth_objective_rejects_allocation_moves() {
        let mut obj = SynthObjective::new(2, 8);
        obj.init().unwrap();
        let swap = BitSwap {
            donor: "l0.up.w".into(),
            donor_layer: 0,
            receiver: "l1.down.w".into(),
            receiver_layer: 1,
            donor_transform: None,
            receiver_transform: None,
        };
        let drafts = obj.draft(&[DraftRequest::swap(swap)]).unwrap();
        assert!(obj.eval_drafts(&drafts).is_err());
    }

    // ---- MixedSynthObjective ----------------------------------------------

    fn some_swap(obj: &MixedSynthObjective) -> BitSwap {
        // pick the least-sensitive tensor as donor, most-sensitive as
        // receiver — by construction a strictly improving move
        let mut ts = obj.tensors.clone();
        ts.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let donor = ts.first().unwrap().0.clone();
        let receiver = ts.last().unwrap().0.clone();
        let layer_of = |n: &str| n[1..n.find('.').unwrap()].parse().unwrap();
        BitSwap {
            donor_layer: layer_of(&donor),
            receiver_layer: layer_of(&receiver),
            donor,
            receiver,
            donor_transform: None,
            receiver_transform: None,
        }
    }

    #[test]
    fn sensitivity_ordered_swap_strictly_improves() {
        let mut obj = MixedSynthObjective::new(4, 8, QuantScheme::new(2, 64));
        let init = obj.init().unwrap();
        let swap = some_swap(&obj);
        let drafts = obj.draft(&[DraftRequest::swap(swap)]).unwrap();
        let loss = obj.eval_drafts(&drafts).unwrap()[0];
        assert!(
            loss.ce < init.ce,
            "low->high sensitivity swap must improve: {} vs {}",
            loss.ce,
            init.ce
        );
    }

    #[test]
    fn committed_swap_updates_alloc_term() {
        let mut obj = MixedSynthObjective::new(4, 8, QuantScheme::new(2, 64));
        obj.init().unwrap();
        let uniform = obj.alloc_term();
        assert_eq!(obj.alloc_term(), obj.uniform_alloc_term());
        let swap = some_swap(&obj);
        let donor = swap.donor.clone();
        let mut drafts = obj.draft(&[DraftRequest::swap(swap)]).unwrap();
        let loss = obj.eval_drafts(&drafts).unwrap()[0];
        let committed = obj.commit(drafts.swap_remove(0)).unwrap();
        assert_eq!(loss, committed);
        assert!(obj.alloc_term() < uniform);
        assert_eq!(obj.bits[&donor], 1);
    }

    #[test]
    fn transform_and_swap_moves_compose() {
        let mut obj = MixedSynthObjective::new(3, 8, QuantScheme::new(2, 64));
        obj.init().unwrap();
        // transform eval carries the CURRENT alloc term unchanged
        let t = proposal(8, 3);
        let drafts = obj.draft(&[DraftRequest::transform(1, t.clone())]).unwrap();
        let loss = obj.eval_drafts(&drafts).unwrap()[0];
        let expect = obj.base.total_with(1, &t.scale).ce + obj.alloc_term();
        assert!((loss.ce - expect).abs() < 1e-12, "transform eval must add the accepted alloc term");
        // alloc_state matches the tracked tensor universe
        let st = obj.alloc_state();
        assert_eq!(st.entries.len(), obj.tensors.len());
        for e in &st.entries {
            assert!(obj.bits.contains_key(&e.name), "{}", e.name);
        }
        assert!((st.bits_per_param() - QuantScheme::new(2, 64).bits_per_param()).abs() < 1e-12);
    }
}

//! Synthetic, XLA-free [`Objective`] for driver tests and throughput
//! benches.
//!
//! Loss = Σ per-layer potentials; a layer's potential improves when its
//! scale vector approaches a hidden optimum.  Deterministic, no PJRT.  The
//! `draft_work` knob adds a configurable amount of real host-side
//! re-quantization work per draft (the codec the XLA objective runs per
//! proposal), so `benches/perf_hotpath.rs` can measure how K-wide rounds
//! hide per-candidate drafting latency.

use std::collections::HashMap;

use super::hillclimb::{Draft, DraftRequest, Objective};
use crate::quant::{self, QuantScheme};
use crate::runtime::Loss;
use crate::tensor::Tensor;
use crate::util::pool;
use crate::util::rng::Pcg64;

pub struct SynthObjective {
    n_layers: usize,
    d: usize,
    target: Vec<Vec<f32>>,
    current: Vec<Vec<f32>>,
    /// Pending losses of the last `eval_drafts` batch, keyed by layer.
    pending: HashMap<usize, Loss>,
    /// Elements of synthetic groupwise fake-quant run per draft (0 = none).
    pub draft_work: usize,
}

impl SynthObjective {
    pub fn new(n_layers: usize, d: usize) -> SynthObjective {
        let mut rng = Pcg64::new(99);
        let target = (0..n_layers)
            .map(|_| (0..d).map(|_| (rng.uniform() as f32) * 2.0 + 0.5).collect())
            .collect();
        SynthObjective {
            n_layers,
            d,
            target,
            current: vec![vec![1.0; d]; n_layers],
            pending: HashMap::new(),
            draft_work: 0,
        }
    }

    /// Like [`SynthObjective::new`] with `elems` of fake-quant work per
    /// draft (rounded up to whole 64-wide groups).
    pub fn with_draft_work(n_layers: usize, d: usize, elems: usize) -> SynthObjective {
        let mut o = SynthObjective::new(n_layers, d);
        o.draft_work = elems;
        o
    }

    fn layer_loss(&self, l: usize, s: &[f32]) -> f64 {
        s.iter()
            .zip(&self.target[l])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }

    fn total_with(&self, l: usize, s: &[f32]) -> Loss {
        let mut ce = 0.0;
        for i in 0..self.n_layers {
            ce += if i == l {
                self.layer_loss(i, s)
            } else {
                self.layer_loss(i, &self.current[i])
            };
        }
        Loss { ce, act_mse: 0.0 }
    }

    /// Current accepted total loss (test hook).
    pub fn current_total(&self) -> f64 {
        (0..self.n_layers).map(|l| self.layer_loss(l, &self.current[l])).sum()
    }

    /// The configurable host-side drafting cost: a groupwise fake-quant
    /// pass over a tensor seeded from the proposal's scale vector.
    fn burn(&self, req: &DraftRequest) {
        if self.draft_work == 0 {
            return;
        }
        let cols = 64;
        let rows = self.draft_work.div_ceil(cols).max(1);
        let scale = &req.transform.scale;
        let data: Vec<f32> = (0..rows * cols)
            .map(|i| scale[i % scale.len()] * ((i % 17) as f32 - 8.0))
            .collect();
        let t = Tensor::from_vec(rows, cols, data);
        std::hint::black_box(quant::fake_quant(&t, QuantScheme::new(2, 64)));
    }
}

impl Objective for SynthObjective {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn d_ffn(&self) -> usize {
        self.d
    }

    fn init(&mut self) -> crate::Result<Loss> {
        Ok(self.total_with(0, &self.current[0].clone()))
    }

    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
        let threads = pool::num_threads().min(reqs.len().max(1));
        Ok(pool::parallel_map(reqs.len(), threads, |i| {
            self.burn(&reqs[i]);
            Draft {
                layer: reqs[i].layer,
                transform: reqs[i].transform.clone(),
                payload: Box::new(()),
            }
        }))
    }

    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
        self.pending.clear();
        let mut out = Vec::with_capacity(drafts.len());
        for d in drafts {
            anyhow::ensure!(d.layer < self.n_layers, "draft layer out of range");
            let loss = self.total_with(d.layer, &d.transform.scale);
            anyhow::ensure!(
                self.pending.insert(d.layer, loss).is_none(),
                "duplicate draft for layer {}",
                d.layer
            );
            out.push(loss);
        }
        Ok(out)
    }

    fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
        let loss = self
            .pending
            .get(&draft.layer)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("commit without a pending eval for layer {}", draft.layer))?;
        self.current[draft.layer] = draft.transform.scale;
        // committing invalidates every other pending of the batch
        self.pending.clear();
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::{LayerTransform, TransformKinds};

    fn proposal(d: usize, seed: u64) -> LayerTransform {
        let mut rng = Pcg64::new(seed);
        LayerTransform::identity(d).propose(
            &mut rng,
            TransformKinds::parse("s").unwrap(),
            0.5,
            0.4,
            0.0,
        )
    }

    #[test]
    fn commit_requires_prior_eval() {
        let mut obj = SynthObjective::new(2, 8);
        obj.init().unwrap();
        let req = DraftRequest { layer: 0, transform: proposal(8, 1) };
        let one_draft = |obj: &SynthObjective| {
            obj.draft(std::slice::from_ref(&req)).unwrap().pop().unwrap()
        };
        assert!(obj.commit(one_draft(&obj)).is_err(), "commit before eval must fail");
        let mut drafts = obj.draft(std::slice::from_ref(&req)).unwrap();
        let losses = obj.eval_drafts(&drafts).unwrap();
        let committed = obj.commit(drafts.swap_remove(0)).unwrap();
        assert_eq!(losses[0], committed);
        // second commit after the batch was committed: pendings invalidated
        assert!(obj.commit(one_draft(&obj)).is_err());
    }

    #[test]
    fn eval_scores_candidates_independently() {
        let mut obj = SynthObjective::new(3, 8);
        obj.init().unwrap();
        let reqs: Vec<DraftRequest> = (0..3)
            .map(|l| DraftRequest { layer: l, transform: proposal(8, 10 + l as u64) })
            .collect();
        let drafts = obj.draft(&reqs).unwrap();
        let batch = obj.eval_drafts(&drafts).unwrap();
        // one-at-a-time scoring must agree: candidates never see each other
        for (i, d) in drafts.iter().enumerate() {
            let single = obj.eval_drafts(std::slice::from_ref(d)).unwrap();
            assert_eq!(single[0], batch[i], "candidate {i} not independent");
        }
    }

    #[test]
    fn draft_work_burns_deterministically() {
        let obj = SynthObjective::with_draft_work(2, 8, 4096);
        let reqs: Vec<DraftRequest> =
            (0..2).map(|l| DraftRequest { layer: l, transform: proposal(8, l as u64) }).collect();
        let a = obj.draft(&reqs).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].layer, 0);
        assert_eq!(a[1].layer, 1);
    }
}

//! The paper's contribution: activation-guided discrete search over
//! invariant transformations (Algorithm 1).
//!
//! * [`hillclimb`] — the draft / evaluate / commit [`Objective`] protocol
//!   plus the sequential reference driver, written trait-first so control
//!   flow is unit-testable without XLA;
//! * [`scheduler`] — the round-based batched proposal engine: K proposals
//!   on distinct layers drafted concurrently per round (`--batch K`),
//!   greedy acceptance with exact re-scoring of survivors;
//! * [`objective`] — the real objective: transform → re-quantize → run the
//!   AOT XLA programs through the incremental [`crate::runtime::Evaluator`];
//! * [`synth`] — deterministic XLA-free objective for tests and the
//!   `perf_hotpath` throughput bench;
//! * [`state`] — resumable search state (π, s, φ per layer + RNG +
//!   telemetry) with JSON checkpoints.

pub mod hillclimb;
pub mod objective;
pub mod scheduler;
pub mod state;
pub mod synth;

pub use hillclimb::{probe, run_steps, Draft, DraftRequest, Objective, SearchConfig};
pub use objective::XlaObjective;
pub use scheduler::{run, run_rounds};
pub use state::{SearchState, StepRecord};
pub use synth::SynthObjective;

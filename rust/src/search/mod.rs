//! The paper's contribution: activation-guided discrete search over
//! invariant transformations (Algorithm 1).
//!
//! * [`hillclimb`] — the generic random-walk hill-climbing driver, written
//!   against the [`Objective`] trait so its control flow is unit-testable
//!   without XLA;
//! * [`objective`] — the real objective: transform → re-quantize → run the
//!   AOT XLA programs through the incremental [`crate::runtime::Evaluator`];
//! * [`state`] — resumable search state (π, s, φ per layer + RNG +
//!   telemetry) with JSON checkpoints.

pub mod hillclimb;
pub mod objective;
pub mod state;

pub use hillclimb::{run_steps, Objective, SearchConfig};
pub use objective::XlaObjective;
pub use state::{SearchState, StepRecord};

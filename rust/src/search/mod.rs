//! The paper's contribution: activation-guided discrete search over
//! invariant transformations (Algorithm 1).
//!
//! * [`hillclimb`] — the draft / evaluate / commit [`Objective`] protocol
//!   plus the sequential reference driver, written trait-first so control
//!   flow is unit-testable without XLA;
//! * [`scheduler`] — the round-based batched proposal engine: K proposals
//!   on distinct layers drafted concurrently per round (`--batch K`),
//!   greedy acceptance with exact re-scoring of survivors;
//! * [`objective`] — the real objective: transform → re-quantize → run the
//!   AOT XLA programs through the incremental [`crate::runtime::Evaluator`];
//! * [`alloc`] — the mixed-precision allocation axis: per-tensor bit
//!   widths under a global bits/param budget, mutated by budget-preserving
//!   [`BitSwap`] moves that mix into the same proposal stream
//!   (`cfg.p_alloc`);
//! * [`synth`] — deterministic XLA-free objectives for tests and the
//!   `perf_hotpath` / `mixed_precision` benches;
//! * [`state`] — resumable search state (π, s, φ per layer + RNG +
//!   allocation + telemetry) with JSON checkpoints.

pub mod alloc;
pub mod hillclimb;
pub mod objective;
pub mod scheduler;
pub mod state;
pub mod synth;

pub use alloc::{AllocEntry, AllocState, BitSwap};
pub use hillclimb::{probe, run_steps, Draft, DraftRequest, Move, Objective, SearchConfig};
pub use objective::XlaObjective;
pub use scheduler::{run, run_rounds};
pub use state::{SearchState, StepRecord};
pub use synth::{MixedSynthObjective, SynthObjective};

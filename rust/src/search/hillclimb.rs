//! The random-walk hill-climbing driver (paper Algorithm 1).
//!
//! Generic over [`Objective`] so the accept/reject control flow, telemetry
//! and determinism are tested without a PJRT client; the real objective is
//! [`super::objective::XlaObjective`].

use super::state::{SearchState, StepRecord};
use crate::runtime::Loss;
use crate::transform::{LayerTransform, TransformKinds};

/// Hyper-parameters of the discrete search (paper §4.1 defaults).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Transform families to explore (Table-2 ablations).
    pub kinds: TransformKinds,
    /// Fraction of channels moved per proposal ("10% of the neurons").
    pub frac: f64,
    /// Scaling random-walk std (paper: 1e-2).
    pub sigma_s: f64,
    /// Rotation random-walk std (paper: 1e-5).
    pub sigma_r: f64,
    /// Balancing α of Eqn. 23; `None` = auto-set so CE is 10× the MSE term
    /// at the start (paper §4.1).
    pub alpha: Option<f64>,
    /// Log every n-th step.
    pub log_every: usize,
}

impl Default for SearchConfig {
    /// Paper defaults (§4.1) except σ_r: the paper grid-searched 1e-5 for
    /// 10K-step runs on OPT-13B; our pilot grid search at sandbox scale
    /// (hundreds of steps, 4-layer models) lands on 5e-3 — small enough
    /// that rotation stays within the §3.2 approximate-invariance regime
    /// (FP CE drift < 0.1%, pinned by tests), large enough that the
    /// random walk moves in a few hundred steps.  Env overrides:
    /// `INVAREXPLORE_SIGMA_R`, `INVAREXPLORE_SIGMA_S`, `INVAREXPLORE_FRAC`.
    fn default() -> Self {
        let envf = |name: &str, default: f64| {
            std::env::var(name)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(default)
        };
        SearchConfig {
            kinds: TransformKinds::all(),
            frac: envf("INVAREXPLORE_FRAC", 0.1),
            sigma_s: envf("INVAREXPLORE_SIGMA_S", 1e-2),
            sigma_r: envf("INVAREXPLORE_SIGMA_R", 5e-3),
            alpha: None,
            log_every: 50,
        }
    }
}

/// What the search loop needs from the system under optimization.
pub trait Objective {
    fn n_layers(&self) -> usize;
    fn d_ffn(&self) -> usize;

    /// Quantize the whole (identity-transformed) model and return the
    /// initial loss — Algorithm 1 lines 1–3.
    fn init(&mut self) -> crate::Result<Loss>;

    /// Apply transform `t` to layer `l` (from the base FP weights),
    /// re-quantize the affected tensors, evaluate.  The result is *pending*
    /// until [`Objective::accept`] / [`Objective::reject`].
    fn try_layer(&mut self, l: usize, t: &LayerTransform) -> crate::Result<Loss>;

    /// Commit the pending proposal.
    fn accept(&mut self) -> crate::Result<()>;

    /// Revert the pending proposal (restore layer weights).
    fn reject(&mut self) -> crate::Result<()>;
}

/// Initialize `state` from the objective (idempotent if already done).
pub fn ensure_init(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
) -> crate::Result<()> {
    if state.best.ce.is_finite() {
        return Ok(());
    }
    let loss = obj.init()?;
    state.alpha = match cfg.alpha {
        Some(a) => a,
        None => {
            if loss.act_mse > 0.0 {
                loss.ce / (10.0 * loss.act_mse)
            } else {
                0.0
            }
        }
    };
    state.best = loss;
    crate::info!(
        "search init: ce {:.4} act_mse {:.3e} alpha {:.3e}",
        loss.ce,
        loss.act_mse,
        state.alpha
    );
    Ok(())
}

/// Run `n_steps` proposals (Algorithm 1 lines 10–19), extending `state`.
pub fn run_steps(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_steps: usize,
) -> crate::Result<()> {
    ensure_init(obj, state, cfg)?;
    let n_layers = obj.n_layers();

    for _ in 0..n_steps {
        state.step += 1;
        let l = state.rng.below(n_layers);
        let proposal =
            state.transforms[l].propose(&mut state.rng, cfg.kinds, cfg.frac, cfg.sigma_s, cfg.sigma_r);
        let loss = obj.try_layer(l, &proposal)?;
        let accepted = loss.total(state.alpha) < state.best.total(state.alpha);
        if accepted {
            obj.accept()?;
            state.transforms[l] = proposal;
            state.best = loss;
            state.accepts += 1;
        } else {
            obj.reject()?;
        }
        let rec = StepRecord {
            step: state.step,
            layer: l,
            loss_total: state.best.total(state.alpha),
            ce: state.best.ce,
            act_mse: state.best.act_mse,
            accepted,
            accept_rate: state.accept_rate(),
            elapsed_s: state.started.elapsed().as_secs_f64(),
        };
        if cfg.log_every > 0 && state.step % cfg.log_every == 0 {
            crate::info!(
                "step {:5}  loss {:.4}  ce {:.4}  mse {:.3e}  acc {:.2}",
                rec.step,
                rec.loss_total,
                rec.ce,
                rec.act_mse,
                rec.accept_rate
            );
        }
        state.telemetry.push(rec);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Synthetic objective: loss = Σ per-layer potentials; a transform's
    /// potential improves when its scale vector is closer to a hidden
    /// optimum.  Deterministic, no XLA.
    struct Synth {
        n_layers: usize,
        d: usize,
        target: Vec<Vec<f32>>,
        current: Vec<Vec<f32>>,
        pending: Option<(usize, Vec<f32>)>,
    }

    impl Synth {
        fn new(n_layers: usize, d: usize) -> Synth {
            let mut rng = Pcg64::new(99);
            let target = (0..n_layers)
                .map(|_| (0..d).map(|_| (rng.uniform() as f32) * 2.0 + 0.5).collect())
                .collect();
            Synth {
                n_layers,
                d,
                target,
                current: vec![vec![1.0; d]; n_layers],
                pending: None,
            }
        }

        fn layer_loss(&self, l: usize, s: &[f32]) -> f64 {
            s.iter()
                .zip(&self.target[l])
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        }

        fn total_with(&self, l: usize, s: &[f32]) -> Loss {
            let mut ce = 0.0;
            for i in 0..self.n_layers {
                ce += if i == l {
                    self.layer_loss(i, s)
                } else {
                    self.layer_loss(i, &self.current[i])
                };
            }
            Loss { ce, act_mse: 0.0 }
        }
    }

    impl Objective for Synth {
        fn n_layers(&self) -> usize {
            self.n_layers
        }
        fn d_ffn(&self) -> usize {
            self.d
        }
        fn init(&mut self) -> crate::Result<Loss> {
            Ok(self.total_with(0, &self.current[0].clone()))
        }
        fn try_layer(&mut self, l: usize, t: &LayerTransform) -> crate::Result<Loss> {
            let loss = self.total_with(l, &t.scale);
            self.pending = Some((l, t.scale.clone()));
            Ok(loss)
        }
        fn accept(&mut self) -> crate::Result<()> {
            let (l, s) = self.pending.take().expect("pending");
            self.current[l] = s;
            Ok(())
        }
        fn reject(&mut self) -> crate::Result<()> {
            self.pending.take().expect("pending");
            Ok(())
        }
    }

    fn cfg() -> SearchConfig {
        SearchConfig {
            kinds: TransformKinds::parse("s").unwrap(),
            frac: 0.3,
            sigma_s: 0.3,
            sigma_r: 0.0,
            alpha: Some(0.0),
            log_every: 0,
        }
    }

    #[test]
    fn hillclimbing_reduces_loss_monotonically() {
        let mut obj = Synth::new(3, 8);
        let mut state = SearchState::new(3, 8, 1);
        run_steps(&mut obj, &mut state, &cfg(), 400).unwrap();
        let losses: Vec<f64> = state.telemetry.iter().map(|r| r.loss_total).collect();
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "loss increased: {} -> {}", w[0], w[1]);
        }
        // must make real progress on this easy landscape
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "insufficient progress");
        assert!(state.accepts > 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut obj = Synth::new(2, 8);
            let mut state = SearchState::new(2, 8, seed);
            run_steps(&mut obj, &mut state, &cfg(), 100).unwrap();
            (state.best.ce, state.accepts)
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn rejected_proposals_leave_state_unchanged() {
        struct AlwaysWorse {
            pending: bool,
        }
        impl Objective for AlwaysWorse {
            fn n_layers(&self) -> usize {
                1
            }
            fn d_ffn(&self) -> usize {
                4
            }
            fn init(&mut self) -> crate::Result<Loss> {
                Ok(Loss { ce: 1.0, act_mse: 0.0 })
            }
            fn try_layer(&mut self, _: usize, _: &LayerTransform) -> crate::Result<Loss> {
                self.pending = true;
                Ok(Loss { ce: 2.0, act_mse: 0.0 })
            }
            fn accept(&mut self) -> crate::Result<()> {
                panic!("must never accept");
            }
            fn reject(&mut self) -> crate::Result<()> {
                assert!(self.pending);
                self.pending = false;
                Ok(())
            }
        }
        let mut obj = AlwaysWorse { pending: false };
        let mut state = SearchState::new(1, 4, 0);
        run_steps(&mut obj, &mut state, &cfg(), 50).unwrap();
        assert_eq!(state.accepts, 0);
        assert!(state.transforms[0].is_identity());
        assert!((state.best.ce - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_auto_set_from_init() {
        struct WithMse;
        impl Objective for WithMse {
            fn n_layers(&self) -> usize {
                1
            }
            fn d_ffn(&self) -> usize {
                4
            }
            fn init(&mut self) -> crate::Result<Loss> {
                Ok(Loss { ce: 5.0, act_mse: 0.1 })
            }
            fn try_layer(&mut self, _: usize, _: &LayerTransform) -> crate::Result<Loss> {
                Ok(Loss { ce: 10.0, act_mse: 0.1 })
            }
            fn accept(&mut self) -> crate::Result<()> {
                Ok(())
            }
            fn reject(&mut self) -> crate::Result<()> {
                Ok(())
            }
        }
        let mut state = SearchState::new(1, 4, 0);
        let c = SearchConfig { alpha: None, ..cfg() };
        run_steps(&mut WithMse, &mut state, &c, 1).unwrap();
        // alpha = ce / (10 * mse) = 5 / 1 = 5
        assert!((state.alpha - 5.0).abs() < 1e-9);
    }

    #[test]
    fn telemetry_accept_rate_consistent() {
        let mut obj = Synth::new(2, 8);
        let mut state = SearchState::new(2, 8, 3);
        run_steps(&mut obj, &mut state, &cfg(), 200).unwrap();
        let last = state.telemetry.last().unwrap();
        assert!((last.accept_rate - state.accepts as f64 / 200.0).abs() < 1e-9);
        assert_eq!(state.telemetry.len(), 200);
    }
}

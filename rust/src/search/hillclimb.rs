//! The hill-climbing search protocol (paper Algorithm 1) and its sequential
//! reference driver.
//!
//! The search talks to the system under optimization through the
//! [`Objective`] trait, a three-stage **draft / evaluate / commit** protocol
//! designed so independent proposals can be processed in concurrent K-wide
//! rounds (see [`super::scheduler`]):
//!
//! 1. **draft** (`&self`, parallelizable) — the host-side work of a
//!    proposal: apply the move to the base FP weights and re-quantize under
//!    the baseline's semantics.  Implementations fan the batch out across
//!    [`crate::util::pool::parallel_map`].
//! 2. **evaluate** (`&mut self`, serialized) — score each draft against the
//!    current *accepted* state, restoring that state before returning.
//! 3. **commit** (`&mut self`) — promote one evaluated draft into the
//!    accepted state.
//!
//! Since the mixed-precision PR a proposal is a [`Move`]: either an
//! invariance [`LayerTransform`] of one layer's FFN (the original
//! InvarExplore move family) or a budget-preserving [`BitSwap`] that steals
//! a bit from one tensor and grants it to another (`cfg.p_alloc` controls
//! the mix; 0 keeps the historical transform-only RNG stream bit-for-bit).
//!
//! [`run_steps`] is the one-proposal-at-a-time reference driver; the
//! batched round engine in [`super::scheduler`] reproduces its telemetry
//! bit-for-bit at `batch = 1` (pinned by tests).

use super::alloc::BitSwap;
use super::state::{SearchState, StepRecord};
use crate::obs::search::MoveFamily;
use crate::obs::trace;
use crate::runtime::Loss;
use crate::transform::{LayerTransform, TransformKinds};

/// Hyper-parameters of the discrete search (paper §4.1 defaults).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Transform families to explore (Table-2 ablations).
    pub kinds: TransformKinds,
    /// Fraction of channels moved per proposal ("10% of the neurons").
    pub frac: f64,
    /// Scaling random-walk std (paper: 1e-2).
    pub sigma_s: f64,
    /// Rotation random-walk std (paper: 1e-5).
    pub sigma_r: f64,
    /// Balancing α of Eqn. 23; `None` = auto-set so CE is 10× the MSE term
    /// at the start (paper §4.1).
    pub alpha: Option<f64>,
    /// Log every n-th step.
    pub log_every: usize,
    /// Proposals drafted per round (`--batch`).  1 = exact sequential
    /// semantics; K > 1 drafts K proposals on distinct layers concurrently.
    pub batch: usize,
    /// Probability a proposal is a bit-swap allocation move instead of a
    /// transform move.  Requires [`SearchState::alloc`]; at 0 the move-type
    /// draw is skipped entirely, so transform-only runs keep the historical
    /// RNG stream bit-for-bit.
    pub p_alloc: f64,
}

impl Default for SearchConfig {
    /// Paper defaults (§4.1) except σ_r: the paper grid-searched 1e-5 for
    /// 10K-step runs on OPT-13B; our pilot grid search at sandbox scale
    /// (hundreds of steps, 4-layer models) lands on 5e-3 — small enough
    /// that rotation stays within the §3.2 approximate-invariance regime
    /// (FP CE drift < 0.1%, pinned by tests), large enough that the
    /// random walk moves in a few hundred steps.  Env overrides:
    /// `INVAREXPLORE_SIGMA_R`, `INVAREXPLORE_SIGMA_S`, `INVAREXPLORE_FRAC`,
    /// `INVAREXPLORE_BATCH`, `INVAREXPLORE_P_ALLOC`.
    fn default() -> Self {
        use crate::util::cli::env_override;
        SearchConfig {
            kinds: TransformKinds::all(),
            frac: env_override("INVAREXPLORE_FRAC", 0.1),
            sigma_s: env_override("INVAREXPLORE_SIGMA_S", 1e-2),
            sigma_r: env_override("INVAREXPLORE_SIGMA_R", 5e-3),
            alpha: None,
            log_every: 50,
            batch: env_override("INVAREXPLORE_BATCH", 1usize).max(1),
            p_alloc: env_override("INVAREXPLORE_P_ALLOC", 0.0f64).clamp(0.0, 1.0),
        }
    }
}

/// One proposed mutation of the search state.
#[derive(Debug, Clone)]
pub enum Move {
    /// Invariance transform of one layer's FFN (Eqns. 21–22).
    Transform(LayerTransform),
    /// Budget-preserving bit reallocation between two tensors.
    BitSwap(BitSwap),
}

impl Move {
    pub fn as_transform(&self) -> Option<&LayerTransform> {
        match self {
            Move::Transform(t) => Some(t),
            Move::BitSwap(_) => None,
        }
    }

    pub fn as_swap(&self) -> Option<&BitSwap> {
        match self {
            Move::Transform(_) => None,
            Move::BitSwap(s) => Some(s),
        }
    }

    /// Telemetry family of this move (`obs::search` counters).
    pub fn family(&self) -> MoveFamily {
        match self {
            Move::Transform(_) => MoveFamily::Transform,
            Move::BitSwap(_) => MoveFamily::BitSwap,
        }
    }
}

/// One requested proposal.  `layer` is the round scheduler's resource key
/// and the evaluator's incremental re-entry point: the mutated layer for a
/// transform move, the *lowest* affected layer for a bit swap.
#[derive(Debug, Clone)]
pub struct DraftRequest {
    pub layer: usize,
    pub mv: Move,
}

impl DraftRequest {
    pub fn transform(layer: usize, t: LayerTransform) -> DraftRequest {
        DraftRequest { layer, mv: Move::Transform(t) }
    }

    pub fn swap(s: BitSwap) -> DraftRequest {
        DraftRequest { layer: s.min_layer(), mv: Move::BitSwap(s) }
    }
}

/// A drafted proposal: the host-side work product, ready to evaluate.
///
/// `payload` carries implementation-specific state (e.g. re-quantized
/// tensors for the XLA objective); the driver only reads `layer` and `mv`.
pub struct Draft {
    pub layer: usize,
    pub mv: Move,
    pub payload: Box<dyn std::any::Any + Send>,
}

/// What the search loop needs from the system under optimization.
///
/// Protocol contract:
///
/// * [`Objective::eval_drafts`] scores every draft *independently* against
///   the accepted state and leaves the accepted state in effect when it
///   returns; it retains per-draft pending results so an immediately
///   following `commit` is cheap (no re-evaluation).
/// * [`Objective::commit`] promotes one draft of the **most recent**
///   `eval_drafts` batch and invalidates that batch's other pendings —
///   their losses are stale once the model changed.  Committing more than
///   one draft requires a fresh `eval_drafts` in between (the scheduler's
///   re-scoring pass).
pub trait Objective {
    fn n_layers(&self) -> usize;
    fn d_ffn(&self) -> usize;

    /// Quantize the whole (identity-transformed) model and return the
    /// initial loss — Algorithm 1 lines 1–3.
    fn init(&mut self) -> crate::Result<Loss>;

    /// Stage 1 — host-side draft of a batch of proposals on distinct
    /// layers (move application + re-quantization).
    fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>>;

    /// Stage 2 — score each draft against the accepted state.
    fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>>;

    /// Stage 3 — commit one draft from the most recent `eval_drafts`
    /// batch; returns its exact loss.  Takes the draft by value so
    /// implementations can move its payload (e.g. re-quantized weight
    /// matrices) into the accepted state instead of cloning.
    fn commit(&mut self, draft: Draft) -> crate::Result<Loss>;
}

/// Draft + evaluate a single transform proposal without committing it (the
/// accepted state is untouched).  Probe helper for benches and tests.
pub fn probe(obj: &mut dyn Objective, layer: usize, t: &LayerTransform) -> crate::Result<Loss> {
    let drafts = obj.draft(&[DraftRequest::transform(layer, t.clone())])?;
    let losses = obj.eval_drafts(&drafts)?;
    Ok(losses[0])
}

/// Initialize `state` from the objective (idempotent if already done).
///
/// Initialization is tracked by an explicit [`SearchState::initialized`]
/// flag, *not* by `best.ce.is_finite()`: a legitimately non-finite initial
/// CE (easy to hit at 2-bit) must not silently re-run the full init on
/// every `run_steps` segment.
pub fn ensure_init(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
) -> crate::Result<()> {
    if state.initialized {
        return Ok(());
    }
    let loss = obj.init()?;
    state.alpha = match cfg.alpha {
        Some(a) => a,
        None => {
            if loss.act_mse > 0.0 {
                loss.ce / (10.0 * loss.act_mse)
            } else {
                0.0
            }
        }
    };
    state.best = loss;
    state.initialized = true;
    crate::info!(
        "search init: ce {:.4} act_mse {:.3e} alpha {:.3e}",
        loss.ce,
        loss.act_mse,
        state.alpha
    );
    Ok(())
}

/// Push one telemetry record, logging every `cfg.log_every` steps.
///
/// Also the single funnel for the `obs` search telemetry: per-family
/// propose/accept counters and the per-step CE/loss trace — shared by the
/// sequential and batched drivers, so both emit identical streams for
/// identical step sequences.
pub(super) fn record_step(
    state: &mut SearchState,
    cfg: &SearchConfig,
    layer: usize,
    family: MoveFamily,
    accepted: bool,
) {
    crate::obs::search::record_move(family, accepted);
    let rec = StepRecord {
        step: state.step,
        layer,
        loss_total: state.best.total(state.alpha),
        ce: state.best.ce,
        act_mse: state.best.act_mse,
        accepted,
        accept_rate: state.accept_rate(),
        elapsed_s: state.started.elapsed().as_secs_f64(),
    };
    trace::counter("search", "ce", rec.ce);
    trace::counter("search", "loss_total", rec.loss_total);
    trace::counter("search", "accept_rate", rec.accept_rate);
    if cfg.log_every > 0 && state.step % cfg.log_every == 0 {
        crate::info!(
            "step {:5}  loss {:.4}  ce {:.4}  mse {:.3e}  acc {:.2}",
            rec.step,
            rec.loss_total,
            rec.ce,
            rec.act_mse,
            rec.accept_rate
        );
    }
    state.telemetry.push(rec);
}

/// Should the next proposal be an allocation move?  Consumes one uniform
/// draw **only** when allocation search is active, so transform-only
/// configurations keep the historical RNG stream bit-for-bit.
pub(super) fn draw_alloc_move(state: &mut SearchState, cfg: &SearchConfig) -> bool {
    cfg.p_alloc > 0.0 && state.alloc.is_some() && state.rng.uniform() < cfg.p_alloc
}

/// Draw one proposal: a bit swap with probability `cfg.p_alloc` (when
/// allocation search is enabled and a valid swap exists), otherwise a
/// transform on a random layer.
pub(super) fn propose_one(
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_layers: usize,
) -> DraftRequest {
    if draw_alloc_move(state, cfg) {
        let SearchState { alloc, rng, transforms, .. } = state;
        if let Some(swap) = alloc.as_ref().unwrap().propose(rng, transforms, None, 32) {
            return DraftRequest::swap(swap);
        }
        // no valid swap under the budget — fall through to a transform move
    }
    let l = state.rng.below(n_layers);
    let t = state.transforms[l].propose(&mut state.rng, cfg.kinds, cfg.frac, cfg.sigma_s, cfg.sigma_r);
    DraftRequest::transform(l, t)
}

/// Fold an accepted draft's move into the search state (the objective's
/// own accepted state is updated by [`Objective::commit`]).
pub(super) fn commit_to_state(state: &mut SearchState, draft: &Draft) {
    match &draft.mv {
        Move::Transform(t) => state.transforms[draft.layer] = t.clone(),
        Move::BitSwap(s) => {
            state
                .alloc
                .as_mut()
                .expect("bit-swap accepted without allocation state")
                .apply(s);
            state.alloc_accepts += 1;
        }
    }
}

/// Run `n_steps` proposals strictly one at a time (Algorithm 1 lines
/// 10–19), extending `state`.  This is the reference semantics the batched
/// scheduler must reproduce at `batch = 1`.
pub fn run_steps(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_steps: usize,
) -> crate::Result<()> {
    ensure_init(obj, state, cfg)?;
    let n_layers = obj.n_layers();

    for _ in 0..n_steps {
        state.step += 1;
        let req = propose_one(state, cfg, n_layers);
        let layer = req.layer;
        let family = req.mv.family();
        let mut drafts = obj.draft(std::slice::from_ref(&req))?;
        let loss = obj.eval_drafts(&drafts)?[0];
        let accepted = loss.total(state.alpha) < state.best.total(state.alpha);
        if accepted {
            let draft = drafts.swap_remove(0);
            commit_to_state(state, &draft);
            let exact = obj.commit(draft)?;
            state.best = exact;
            state.accepts += 1;
        }
        record_step(state, cfg, layer, family, accepted);
    }
    Ok(())
}

/// Shared scaling-only driver-test config (α pinned to 0) — used by the
/// hillclimb and scheduler test suites.
#[cfg(test)]
pub(crate) fn test_cfg() -> SearchConfig {
    SearchConfig {
        kinds: TransformKinds::parse("s").unwrap(),
        frac: 0.3,
        sigma_s: 0.3,
        sigma_r: 0.0,
        alpha: Some(0.0),
        log_every: 0,
        batch: 1,
        p_alloc: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::synth::SynthObjective;

    fn cfg() -> SearchConfig {
        test_cfg()
    }

    fn passthrough_drafts(reqs: &[DraftRequest]) -> Vec<Draft> {
        reqs.iter()
            .map(|r| Draft {
                layer: r.layer,
                mv: r.mv.clone(),
                payload: Box::new(()),
            })
            .collect()
    }

    #[test]
    fn hillclimbing_reduces_loss_monotonically() {
        let mut obj = SynthObjective::new(3, 8);
        let mut state = SearchState::new(3, 8, 1);
        run_steps(&mut obj, &mut state, &cfg(), 400).unwrap();
        let losses: Vec<f64> = state.telemetry.iter().map(|r| r.loss_total).collect();
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "loss increased: {} -> {}", w[0], w[1]);
        }
        // must make real progress on this easy landscape
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "insufficient progress");
        assert!(state.accepts > 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut obj = SynthObjective::new(2, 8);
            let mut state = SearchState::new(2, 8, seed);
            run_steps(&mut obj, &mut state, &cfg(), 100).unwrap();
            (state.best.ce, state.accepts)
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn tracing_on_leaves_search_trajectory_bit_identical() {
        // Telemetry recording happens strictly after each accept decision,
        // so the full step-by-step trajectory (losses to the bit, accepted
        // flags, RNG-driven layer choices) is invariant to the recorder.
        let run = || {
            let mut obj = SynthObjective::new(2, 8);
            let mut state = SearchState::new(2, 8, 9);
            run_steps(&mut obj, &mut state, &cfg(), 120).unwrap();
            let traj: Vec<_> = state
                .telemetry
                .iter()
                .map(|r| (r.step, r.layer, r.loss_total.to_bits(), r.ce.to_bits(), r.accepted))
                .collect();
            (traj, state.accepts)
        };
        let reference = run();
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        crate::obs::trace::clear();
        crate::obs::search::reset();
        let traced = run();
        let snap = crate::obs::search::snapshot();
        crate::obs::set_enabled(false);
        let events = crate::obs::trace::take_events();
        crate::obs::search::reset();
        assert_eq!(reference, traced, "tracing perturbed the search trajectory");
        // the family counters saw every proposal (>=: the global is shared
        // with any instrumented test running concurrently)
        assert!(snap.proposed_of(MoveFamily::Transform) >= 120);
        assert!(snap.accepted_of(MoveFamily::Transform) >= traced.1 as u64);
        // and the per-step CE trajectory was sampled into the trace
        let ce_samples =
            events.iter().filter(|e| e.cat == "search" && e.name == "ce").count();
        assert!(ce_samples >= 120, "expected >=120 ce samples, got {ce_samples}");
    }

    /// Objective that counts `init` calls and reports a non-finite initial
    /// CE — the regression case for the old `best.ce.is_finite()` sentinel.
    struct InfInit {
        init_calls: usize,
    }

    impl Objective for InfInit {
        fn n_layers(&self) -> usize {
            1
        }
        fn d_ffn(&self) -> usize {
            4
        }
        fn init(&mut self) -> crate::Result<Loss> {
            self.init_calls += 1;
            Ok(Loss { ce: f64::INFINITY, act_mse: 0.0 })
        }
        fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
            Ok(passthrough_drafts(reqs))
        }
        fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
            Ok(drafts.iter().map(|_| Loss { ce: f64::INFINITY, act_mse: 0.0 }).collect())
        }
        fn commit(&mut self, _draft: Draft) -> crate::Result<Loss> {
            panic!("nothing improves an infinite loss");
        }
    }

    #[test]
    fn non_finite_initial_ce_does_not_reinit() {
        let mut obj = InfInit { init_calls: 0 };
        let mut state = SearchState::new(1, 4, 0);
        // segmented driving, as Figure 1 does between test-PPL evaluations
        run_steps(&mut obj, &mut state, &cfg(), 5).unwrap();
        run_steps(&mut obj, &mut state, &cfg(), 5).unwrap();
        run_steps(&mut obj, &mut state, &cfg(), 5).unwrap();
        assert_eq!(obj.init_calls, 1, "init must run exactly once per search");
        assert!(state.initialized);
        assert_eq!(state.step, 15);
        assert_eq!(state.accepts, 0);
    }

    #[test]
    fn rejected_proposals_leave_state_unchanged() {
        struct AlwaysWorse;
        impl Objective for AlwaysWorse {
            fn n_layers(&self) -> usize {
                1
            }
            fn d_ffn(&self) -> usize {
                4
            }
            fn init(&mut self) -> crate::Result<Loss> {
                Ok(Loss { ce: 1.0, act_mse: 0.0 })
            }
            fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
                Ok(passthrough_drafts(reqs))
            }
            fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
                Ok(drafts.iter().map(|_| Loss { ce: 2.0, act_mse: 0.0 }).collect())
            }
            fn commit(&mut self, _draft: Draft) -> crate::Result<Loss> {
                panic!("must never accept");
            }
        }
        let mut obj = AlwaysWorse;
        let mut state = SearchState::new(1, 4, 0);
        run_steps(&mut obj, &mut state, &cfg(), 50).unwrap();
        assert_eq!(state.accepts, 0);
        assert!(state.transforms[0].is_identity());
        assert!((state.best.ce - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_auto_set_from_init() {
        struct WithMse;
        impl Objective for WithMse {
            fn n_layers(&self) -> usize {
                1
            }
            fn d_ffn(&self) -> usize {
                4
            }
            fn init(&mut self) -> crate::Result<Loss> {
                Ok(Loss { ce: 5.0, act_mse: 0.1 })
            }
            fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
                Ok(passthrough_drafts(reqs))
            }
            fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
                Ok(drafts.iter().map(|_| Loss { ce: 10.0, act_mse: 0.1 }).collect())
            }
            fn commit(&mut self, _draft: Draft) -> crate::Result<Loss> {
                Ok(Loss { ce: 10.0, act_mse: 0.1 })
            }
        }
        let mut state = SearchState::new(1, 4, 0);
        let c = SearchConfig { alpha: None, ..cfg() };
        run_steps(&mut WithMse, &mut state, &c, 1).unwrap();
        // alpha = ce / (10 * mse) = 5 / 1 = 5
        assert!((state.alpha - 5.0).abs() < 1e-9);
    }

    #[test]
    fn telemetry_accept_rate_consistent() {
        let mut obj = SynthObjective::new(2, 8);
        let mut state = SearchState::new(2, 8, 3);
        run_steps(&mut obj, &mut state, &cfg(), 200).unwrap();
        let last = state.telemetry.last().unwrap();
        assert!((last.accept_rate - state.accepts as f64 / 200.0).abs() < 1e-9);
        assert_eq!(state.telemetry.len(), 200);
    }

    #[test]
    fn probe_leaves_accepted_state_untouched() {
        let mut obj = SynthObjective::new(2, 8);
        let mut state = SearchState::new(2, 8, 9);
        run_steps(&mut obj, &mut state, &cfg(), 20).unwrap();
        let before = obj.current_total();
        let t = state.transforms[0].propose(
            &mut state.rng,
            TransformKinds::parse("s").unwrap(),
            0.3,
            0.3,
            0.0,
        );
        let _ = probe(&mut obj, 0, &t).unwrap();
        assert_eq!(obj.current_total(), before, "probe mutated accepted state");
    }

    /// p_alloc = 0 must not consume any extra RNG draws: a config with the
    /// flag off produces the exact same run as one predating the flag
    /// (covered transitively by the scheduler's K=1 bit-identity test, and
    /// directly here against a hand-rolled legacy proposal loop).
    #[test]
    fn p_alloc_zero_keeps_legacy_rng_stream() {
        let mut obj = SynthObjective::new(3, 8);
        let mut state = SearchState::new(3, 8, 42);
        run_steps(&mut obj, &mut state, &cfg(), 60).unwrap();

        // legacy loop: draw layer, draw proposal — nothing else
        let mut rng = crate::util::rng::Pcg64::new(42);
        let mut transforms: Vec<LayerTransform> = vec![LayerTransform::identity(8); 3];
        let mut legacy_layers = Vec::new();
        let c = cfg();
        let mut obj2 = SynthObjective::new(3, 8);
        let mut best = obj2.init().unwrap();
        for _ in 0..60 {
            let l = rng.below(3);
            legacy_layers.push(l);
            let t = transforms[l].propose(&mut rng, c.kinds, c.frac, c.sigma_s, c.sigma_r);
            let mut drafts = obj2.draft(&[DraftRequest::transform(l, t.clone())]).unwrap();
            let loss = obj2.eval_drafts(&drafts).unwrap()[0];
            if loss.total(0.0) < best.total(0.0) {
                transforms[l] = t;
                best = obj2.commit(drafts.swap_remove(0)).unwrap();
            }
        }
        let layers: Vec<usize> = state.telemetry.iter().map(|r| r.layer).collect();
        assert_eq!(layers, legacy_layers, "layer draw stream diverged");
        assert_eq!(state.best.ce.to_bits(), best.ce.to_bits());
    }
}

//! Resumable search state + telemetry.

use std::path::Path;

use super::alloc::AllocState;
use crate::runtime::Loss;
use crate::transform::LayerTransform;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// One telemetry record per search step (drives Figure 1).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub layer: usize,
    pub loss_total: f64,
    pub ce: f64,
    pub act_mse: f64,
    pub accepted: bool,
    /// Cumulative acceptance ratio up to this step.
    pub accept_rate: f64,
    pub elapsed_s: f64,
}

/// Full search state: current transforms, objective scalars, RNG, telemetry.
pub struct SearchState {
    pub transforms: Vec<LayerTransform>,
    pub rng: Pcg64,
    pub best: Loss,
    pub alpha: f64,
    /// Has `Objective::init` run for this state?  Explicit flag — `best.ce`
    /// finiteness is NOT a reliable sentinel (a 2-bit model can legitimately
    /// start at a non-finite CE, which must not re-trigger init on every
    /// `run_steps` segment).
    pub initialized: bool,
    pub step: usize,
    pub accepts: usize,
    /// Accepted bit-swap moves (subset of `accepts`).
    pub alloc_accepts: usize,
    /// Mixed-precision allocation search state; `None` = transform-only
    /// search (the historical behavior).
    pub alloc: Option<AllocState>,
    pub telemetry: Vec<StepRecord>,
    pub started: std::time::Instant,
}

impl SearchState {
    pub fn new(n_layers: usize, d_ffn: usize, seed: u64) -> SearchState {
        SearchState {
            transforms: vec![LayerTransform::identity(d_ffn); n_layers],
            rng: Pcg64::new(seed),
            best: Loss { ce: f64::INFINITY, act_mse: 0.0 },
            alpha: 0.0,
            initialized: false,
            step: 0,
            accepts: 0,
            alloc_accepts: 0,
            alloc: None,
            telemetry: Vec::new(),
            started: std::time::Instant::now(),
        }
    }

    /// Enable mixed-precision allocation search (bit-swap proposals draw
    /// their donors/receivers from — and commit into — this state).
    pub fn with_alloc(mut self, alloc: AllocState) -> SearchState {
        self.alloc = Some(alloc);
        self
    }

    pub fn accept_rate(&self) -> f64 {
        if self.step == 0 {
            0.0
        } else {
            self.accepts as f64 / self.step as f64
        }
    }

    /// Serialize transforms + scalars (telemetry is exported separately as
    /// CSV; the RNG restarts from a derived seed on resume).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("step", self.step)
            .set("accepts", self.accepts)
            .set("alloc_accepts", self.alloc_accepts)
            .set("alpha", self.alpha)
            .set("initialized", self.initialized)
            .set("best_ce", self.best.ce)
            .set("best_act_mse", self.best.act_mse)
            .set(
                "transforms",
                Json::Arr(self.transforms.iter().map(|t| t.to_json()).collect()),
            );
        if let Some(alloc) = &self.alloc {
            j = j.set("alloc", alloc.to_json());
        }
        j
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // temp + fsync + rename: a crash mid-save leaves the previous
        // checkpoint intact instead of a torn file that kills the resume
        crate::util::atomic_write(path, self.to_json().to_string().as_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path, seed: u64) -> crate::Result<SearchState> {
        let j = crate::util::json::parse_file(path).map_err(|e| {
            anyhow::anyhow!(
                "checkpoint {} is unreadable or torn (crash mid-save from a version \
                 without atomic writes?): {e}; delete it or pass a fresh --state path \
                 to restart the search from step 0",
                path.display()
            )
        })?;
        let transforms: Vec<LayerTransform> = j
            .req("transforms")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(LayerTransform::from_json)
            .collect::<crate::Result<_>>()?;
        anyhow::ensure!(!transforms.is_empty(), "empty transform state");
        let d_ffn = transforms[0].d_ffn();
        let step = j.req("step")?.as_usize().unwrap_or(0);
        let mut st = SearchState::new(transforms.len(), d_ffn, seed ^ (step as u64).wrapping_mul(0x9e37));
        st.transforms = transforms;
        st.step = step;
        st.accepts = j.req("accepts")?.as_usize().unwrap_or(0);
        st.alpha = j.req("alpha")?.as_f64().unwrap_or(0.0);
        st.best = Loss {
            ce: j.req("best_ce")?.as_f64().unwrap_or(f64::INFINITY),
            act_mse: j.req("best_act_mse")?.as_f64().unwrap_or(0.0),
        };
        // pre-flag checkpoints fall back to the old (finite-CE) heuristic
        st.initialized = j
            .get("initialized")
            .and_then(Json::as_bool)
            .unwrap_or(st.best.ce.is_finite());
        // optional fields added by the mixed-precision PR; absent in older
        // checkpoints (transform-only searches)
        st.alloc_accepts = j.get("alloc_accepts").and_then(Json::as_usize).unwrap_or(0);
        st.alloc = j.get("alloc").map(AllocState::from_json).transpose()?;
        Ok(st)
    }

    /// Export telemetry as CSV (Figure 1 series).
    pub fn telemetry_csv(&self, path: &Path) -> crate::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &["step", "layer", "loss", "ce", "act_mse", "accepted", "accept_rate", "elapsed_s"],
        )?;
        for r in &self.telemetry {
            w.row(&[
                r.step.to_string(),
                r.layer.to_string(),
                format!("{:.6}", r.loss_total),
                format!("{:.6}", r.ce),
                format!("{:.6e}", r.act_mse),
                (r.accepted as u8).to_string(),
                format!("{:.4}", r.accept_rate),
                format!("{:.2}", r.elapsed_s),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip() {
        let mut st = SearchState::new(3, 16, 7);
        st.step = 42;
        st.accepts = 17;
        st.alpha = 1.5;
        st.best = Loss { ce: 2.0, act_mse: 0.25 };
        let t = st.transforms[1].propose(
            &mut st.rng,
            crate::transform::TransformKinds::all(),
            0.2,
            0.05,
            1e-4,
        );
        st.transforms[1] = t;

        let dir = std::env::temp_dir().join("invarexplore_state_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.json");
        st.save(&p).unwrap();
        let back = SearchState::load(&p, 7).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.accepts, 17);
        assert_eq!(back.transforms[1].perm, st.transforms[1].perm);
        assert!((back.best.ce - 2.0).abs() < 1e-9);
    }

    #[test]
    fn initialized_flag_roundtrips_even_with_non_finite_ce() {
        let mut st = SearchState::new(1, 4, 0);
        st.initialized = true;
        st.best = Loss { ce: f64::INFINITY, act_mse: 0.0 }; // legit at 2-bit
        let dir = std::env::temp_dir().join("invarexplore_state_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("inf.json");
        st.save(&p).unwrap();
        let back = SearchState::load(&p, 0).unwrap();
        assert!(back.initialized, "flag lost on a non-finite-CE checkpoint");
    }

    #[test]
    fn alloc_state_roundtrips_and_is_optional() {
        use crate::quant::{BitAllocation, QuantScheme};

        // without alloc: key absent, loads back as None
        let st = SearchState::new(1, 4, 0);
        assert!(st.to_json().get("alloc").is_none());

        let cfg = crate::model::OptConfig::test_config();
        let alloc = AllocState::new(&cfg, &BitAllocation::uniform(QuantScheme::new(2, 32)));
        let mut st = SearchState::new(cfg.n_layers, cfg.d_ffn, 0).with_alloc(alloc);
        st.alloc_accepts = 3;
        let dir = std::env::temp_dir().join("invarexplore_state_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("alloc.json");
        st.save(&p).unwrap();
        let back = SearchState::load(&p, 0).unwrap();
        assert_eq!(back.alloc_accepts, 3);
        assert_eq!(back.alloc, st.alloc);
    }

    #[test]
    fn torn_checkpoint_load_errors_descriptively_instead_of_panicking() {
        let mut st = SearchState::new(2, 4, 0);
        st.step = 9;
        let dir = std::env::temp_dir().join("invarexplore_state_torn");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("torn.json");
        st.save(&p).unwrap();
        // simulate a crash mid-write from a non-atomic writer: truncate the
        // checkpoint halfway through
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        let err = SearchState::load(&p, 0).err().expect("torn checkpoint must not load");
        let msg = format!("{err:#}");
        assert!(msg.contains("torn"), "{msg}");
        assert!(msg.contains("--state"), "resume hint missing: {msg}");
        // a fresh save over the torn file repairs it (rename is atomic)
        st.save(&p).unwrap();
        assert_eq!(SearchState::load(&p, 0).unwrap().step, 9);
    }

    #[test]
    fn accept_rate() {
        let mut st = SearchState::new(1, 4, 0);
        assert_eq!(st.accept_rate(), 0.0);
        st.step = 10;
        st.accepts = 8;
        assert!((st.accept_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn telemetry_csv_written() {
        let mut st = SearchState::new(1, 4, 0);
        st.telemetry.push(StepRecord {
            step: 1,
            layer: 0,
            loss_total: 3.0,
            ce: 2.9,
            act_mse: 0.1,
            accepted: true,
            accept_rate: 1.0,
            elapsed_s: 0.5,
        });
        let dir = std::env::temp_dir().join("invarexplore_state_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        st.telemetry_csv(&p).unwrap();
        let (hdr, rows) = crate::util::csv::read_csv(&p).unwrap();
        assert_eq!(hdr[0], "step");
        assert_eq!(rows.len(), 1);
    }
}

//! The round-based batched proposal engine.
//!
//! Each round drafts `K` proposals on **distinct** layers, fans the
//! host-side transform application + re-quantization out across the thread
//! pool (inside [`Objective::draft`]), scores all candidates against the
//! round-start accepted state with one batched evaluation, then greedily
//! accepts the best improving candidate and **re-scores the survivors** so
//! every accepted loss is exact — candidates were scored independently, so
//! once one lands the others' losses are stale.
//!
//! `K = 1` reproduces the sequential driver [`super::hillclimb::run_steps`]
//! bit-for-bit: the same RNG stream (one layer draw + one proposal per
//! step), the same loss arithmetic, the same telemetry (pinned by tests).
//!
//! Worst-case device cost of a round is `K + (K-1) + …` suffix evaluations
//! when every candidate keeps improving; in practice accept rates are low,
//! so a round costs `K` evaluations while drafting cost is divided by the
//! worker count.

use super::hillclimb::{
    commit_to_state, draw_alloc_move, ensure_init, record_step, Draft, DraftRequest, Objective,
    SearchConfig,
};
use super::state::SearchState;

/// Drive the search for `n_steps` proposals, honoring `cfg.batch`.
///
/// The single entry point used by the pipeline: dispatches to the exact
/// sequential driver when `batch <= 1`, otherwise runs K-wide rounds.
pub fn run(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_steps: usize,
) -> crate::Result<()> {
    if cfg.batch <= 1 {
        super::hillclimb::run_steps(obj, state, cfg, n_steps)
    } else {
        run_rounds(obj, state, cfg, n_steps, cfg.batch)
    }
}

/// Run `n_steps` proposals in rounds of (up to) `k` concurrent candidates.
pub fn run_rounds(
    obj: &mut dyn Objective,
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_steps: usize,
    k: usize,
) -> crate::Result<()> {
    anyhow::ensure!(k >= 1, "batch size must be >= 1");
    ensure_init(obj, state, cfg)?;
    let n_layers = obj.n_layers();

    let mut remaining = n_steps;
    while remaining > 0 {
        // a round cannot exceed the layer count: candidates must mutate
        // distinct layers to be independently scorable (a bit swap occupies
        // both of its tensors' layers, so a round may come back smaller
        // than k_eff — `remaining` is decremented by what was drawn)
        let k_eff = k.min(remaining).min(n_layers);
        let reqs = draw_round(state, cfg, n_layers, k_eff);
        remaining -= reqs.len();

        let drafts = obj.draft(&reqs)?;
        let mut losses = obj.eval_drafts(&drafts)?;

        // greedy accept: best improving candidate first, survivors
        // re-scored against the new accepted state before the next pick
        let mut pool: Vec<Draft> = drafts;
        let mut order: Vec<usize> = (0..pool.len()).collect();
        loop {
            let Some(i) = best_improving(&losses, state) else { break };
            let draft = pool.swap_remove(i);
            order.swap_remove(i);
            losses.swap_remove(i);
            let layer = draft.layer;
            let family = draft.mv.family();
            commit_to_state(state, &draft);
            let exact = obj.commit(draft)?;
            state.best = exact;
            state.accepts += 1;
            state.step += 1;
            record_step(state, cfg, layer, family, true);
            if pool.is_empty() {
                break;
            }
            losses = obj.eval_drafts(&pool)?;
        }

        // rejected candidates, recorded in draft order
        let mut rejects: Vec<(usize, usize, _)> =
            order.iter().zip(&pool).map(|(&o, d)| (o, d.layer, d.mv.family())).collect();
        rejects.sort_by_key(|&(o, _, _)| o);
        for (_, layer, family) in rejects {
            state.step += 1;
            record_step(state, cfg, layer, family, false);
        }
    }
    Ok(())
}

/// Sample up to `k` proposals on distinct layers.  Layers are drawn by
/// rejection so a single-candidate round consumes exactly one `below()`
/// call — the sequential driver's stream.  With allocation search active,
/// at most one candidate per round is a bit swap (it occupies *both* of its
/// tensors' layers, keeping every candidate's resource set disjoint so the
/// round's drafts stay independently scorable and survivors stay valid
/// after any commit).
fn draw_round(
    state: &mut SearchState,
    cfg: &SearchConfig,
    n_layers: usize,
    k: usize,
) -> Vec<DraftRequest> {
    let mut free = vec![true; n_layers];
    let mut reqs = Vec::with_capacity(k);
    let mut alloc_drawn = false;
    while reqs.len() < k {
        if !alloc_drawn && draw_alloc_move(state, cfg) {
            alloc_drawn = true; // at most one allocation move per round
            let SearchState { alloc, rng, transforms, .. } = state;
            if let Some(swap) =
                alloc.as_ref().unwrap().propose(rng, transforms, Some(&free), 32)
            {
                free[swap.donor_layer] = false;
                free[swap.receiver_layer] = false;
                reqs.push(DraftRequest::swap(swap));
                continue;
            }
            // no valid swap on the free layers — fall through to a transform
        }
        if free.iter().all(|&f| !f) {
            break; // layer capacity exhausted mid-round (a swap took two)
        }
        let l = state.rng.below(n_layers);
        if !free[l] {
            continue;
        }
        free[l] = false;
        let transform = state.transforms[l].propose(
            &mut state.rng,
            cfg.kinds,
            cfg.frac,
            cfg.sigma_s,
            cfg.sigma_r,
        );
        reqs.push(DraftRequest::transform(l, transform));
    }
    reqs
}

/// Index of the lowest-loss candidate that improves on the accepted state.
fn best_improving(losses: &[crate::runtime::Loss], state: &SearchState) -> Option<usize> {
    let bar = state.best.total(state.alpha);
    let mut best: Option<(usize, f64)> = None;
    for (i, loss) in losses.iter().enumerate() {
        let t = loss.total(state.alpha);
        let beats_leader = match best {
            None => true,
            Some((_, bt)) => t < bt,
        };
        if t < bar && beats_leader {
            best = Some((i, t));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Loss;
    use crate::search::hillclimb::{run_steps, test_cfg as cfg};
    use crate::search::synth::SynthObjective;
    use std::cell::RefCell;

    /// Candidate-count K=1 must reproduce the sequential driver bit-for-bit
    /// (the `--batch 1` acceptance criterion): identical `StepRecord`
    /// streams up to wall-clock, identical final state.
    #[test]
    fn k1_round_engine_is_bit_identical_to_sequential() {
        let seq = {
            let mut obj = SynthObjective::new(3, 8);
            let mut state = SearchState::new(3, 8, 7);
            run_steps(&mut obj, &mut state, &cfg(), 150).unwrap();
            state
        };
        let batched = {
            let mut obj = SynthObjective::new(3, 8);
            let mut state = SearchState::new(3, 8, 7);
            run_rounds(&mut obj, &mut state, &cfg(), 150, 1).unwrap();
            state
        };
        assert_eq!(seq.telemetry.len(), batched.telemetry.len());
        for (a, b) in seq.telemetry.iter().zip(&batched.telemetry) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.loss_total.to_bits(), b.loss_total.to_bits(), "step {}", a.step);
            assert_eq!(a.ce.to_bits(), b.ce.to_bits());
            assert_eq!(a.act_mse.to_bits(), b.act_mse.to_bits());
            assert_eq!(a.accept_rate.to_bits(), b.accept_rate.to_bits());
        }
        assert_eq!(seq.accepts, batched.accepts);
        assert_eq!(seq.best.ce.to_bits(), batched.best.ce.to_bits());
        assert_eq!(seq.transforms.len(), batched.transforms.len());
        for (a, b) in seq.transforms.iter().zip(&batched.transforms) {
            assert_eq!(a, b);
        }
    }

    /// With K > 1 the accepted loss must stay monotone non-increasing: the
    /// survivors' re-scoring pass keeps every committed loss exact.
    #[test]
    fn batched_rounds_keep_loss_monotone() {
        let mut obj = SynthObjective::new(6, 8);
        let mut state = SearchState::new(6, 8, 11);
        run_rounds(&mut obj, &mut state, &cfg(), 240, 4).unwrap();
        assert_eq!(state.telemetry.len(), 240);
        let losses: Vec<f64> = state.telemetry.iter().map(|r| r.loss_total).collect();
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "loss increased: {} -> {}", w[0], w[1]);
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "insufficient progress");
        assert!(state.accepts > 10);
        // best must equal the objective's actual committed state
        assert!((state.best.ce - obj.current_total()).abs() < 1e-9);
    }

    #[test]
    fn batched_rounds_deterministic_given_seed() {
        let run = |seed| {
            let mut obj = SynthObjective::new(5, 8);
            let mut state = SearchState::new(5, 8, seed);
            run_rounds(&mut obj, &mut state, &cfg(), 120, 4).unwrap();
            (state.best.ce, state.accepts)
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    /// Delegating wrapper that records the layer set of every draft batch.
    struct Recording {
        inner: SynthObjective,
        batches: RefCell<Vec<Vec<usize>>>,
    }

    impl Objective for Recording {
        fn n_layers(&self) -> usize {
            self.inner.n_layers()
        }
        fn d_ffn(&self) -> usize {
            self.inner.d_ffn()
        }
        fn init(&mut self) -> crate::Result<Loss> {
            self.inner.init()
        }
        fn draft(&self, reqs: &[DraftRequest]) -> crate::Result<Vec<Draft>> {
            self.batches.borrow_mut().push(reqs.iter().map(|r| r.layer).collect());
            self.inner.draft(reqs)
        }
        fn eval_drafts(&mut self, drafts: &[Draft]) -> crate::Result<Vec<Loss>> {
            self.inner.eval_drafts(drafts)
        }
        fn commit(&mut self, draft: Draft) -> crate::Result<Loss> {
            self.inner.commit(draft)
        }
    }

    #[test]
    fn rounds_draft_distinct_layers_and_clamp_to_layer_count() {
        let mut obj = Recording {
            inner: SynthObjective::new(3, 8),
            batches: RefCell::new(Vec::new()),
        };
        let mut state = SearchState::new(3, 8, 2);
        // k = 8 > n_layers = 3: rounds must clamp to 3 distinct layers
        run_rounds(&mut obj, &mut state, &cfg(), 31, 8).unwrap();
        assert_eq!(state.telemetry.len(), 31);
        let batches = obj.batches.borrow();
        assert!(!batches.is_empty());
        let mut proposals = 0;
        for b in batches.iter() {
            assert!(b.len() <= 3, "round exceeded layer count: {b:?}");
            let mut sorted = b.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), b.len(), "layers not distinct: {b:?}");
            proposals += b.len();
        }
        assert!(proposals >= 31, "drafted fewer proposals than steps");
    }

    /// Mixed transform + bit-swap rounds: loss stays monotone, every
    /// accepted state is exact, swaps are actually accepted, and the
    /// allocation never exceeds its budget.
    #[test]
    fn mixed_precision_rounds_stay_monotone_and_under_budget() {
        use crate::quant::QuantScheme;
        use crate::search::synth::MixedSynthObjective;

        let scheme = QuantScheme::new(2, 64);
        let mut obj = MixedSynthObjective::new(6, 8, scheme);
        let alloc = obj.alloc_state();
        let budget = alloc.budget;
        let mut state = SearchState::new(6, 8, 13).with_alloc(alloc);
        let cfg = SearchConfig { p_alloc: 0.5, ..cfg() };
        run_rounds(&mut obj, &mut state, &cfg, 240, 4).unwrap();

        assert_eq!(state.telemetry.len(), 240);
        let losses: Vec<f64> = state.telemetry.iter().map(|r| r.loss_total).collect();
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "loss increased: {} -> {}", w[0], w[1]);
        }
        assert!(state.accepts > 10);
        assert!(state.alloc_accepts >= 1, "no bit swap was ever accepted");
        assert!(state.alloc_accepts <= state.accepts);
        let alloc = state.alloc.as_ref().unwrap();
        assert!(
            alloc.bits_per_param() <= budget + 1e-9,
            "allocation exceeded budget: {} > {budget}",
            alloc.bits_per_param()
        );
        // heterogeneity actually emerged and pays off vs the uniform start
        assert!(alloc.entries.iter().any(|e| e.scheme.bits != scheme.bits));
        assert!(obj.alloc_term() < obj.uniform_alloc_term());
        // accepted loss is exact
        assert!((state.best.ce - obj.current_total()).abs() < 1e-9);
    }

    /// Sequential driver handles the same mixed-move stream (batch = 1).
    #[test]
    fn mixed_precision_sequential_driver() {
        use crate::quant::QuantScheme;
        use crate::search::synth::MixedSynthObjective;

        let mut obj = MixedSynthObjective::new(4, 8, QuantScheme::new(2, 64));
        let alloc = obj.alloc_state();
        let mut state = SearchState::new(4, 8, 21).with_alloc(alloc);
        let cfg = SearchConfig { p_alloc: 0.5, ..cfg() };
        run_steps(&mut obj, &mut state, &cfg, 200).unwrap();
        assert!(state.alloc_accepts >= 1);
        assert!((state.best.ce - obj.current_total()).abs() < 1e-9);
        let run_seeded = |seed| {
            let mut obj = MixedSynthObjective::new(4, 8, QuantScheme::new(2, 64));
            let alloc = obj.alloc_state();
            let mut state = SearchState::new(4, 8, seed).with_alloc(alloc);
            run_steps(&mut obj, &mut state, &cfg, 100).unwrap();
            (state.best.ce, state.accepts, state.alloc_accepts)
        };
        assert_eq!(run_seeded(3), run_seeded(3), "mixed search must be deterministic");
    }

    #[test]
    fn run_dispatches_on_batch_config() {
        let steps = 60;
        let via_dispatch = {
            let mut obj = SynthObjective::new(3, 8);
            let mut state = SearchState::new(3, 8, 4);
            run(&mut obj, &mut state, &cfg(), steps).unwrap(); // batch = 1
            (state.best.ce, state.accepts)
        };
        let via_sequential = {
            let mut obj = SynthObjective::new(3, 8);
            let mut state = SearchState::new(3, 8, 4);
            run_steps(&mut obj, &mut state, &cfg(), steps).unwrap();
            (state.best.ce, state.accepts)
        };
        assert_eq!(via_dispatch, via_sequential);

        let batched_cfg = SearchConfig { batch: 3, ..cfg() };
        let mut obj = SynthObjective::new(3, 8);
        let mut state = SearchState::new(3, 8, 4);
        run(&mut obj, &mut state, &batched_cfg, steps).unwrap();
        assert_eq!(state.telemetry.len(), steps);
    }
}

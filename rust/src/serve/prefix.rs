//! Radix-trie prefix cache over token sequences, backed by the refcounted
//! KV pages of [`KvCache`].
//!
//! Each trie node stores the KV state of one cached prompt prefix; edges
//! are token runs (path-compressed).  A lookup walks the trie along the
//! incoming prompt and returns a [`KvCache::fork_at`] of the longest cached
//! prefix — O(pages) and sharing every page with the stored entry — so the
//! caller only prefills the unshared suffix.  Inserting a served prompt
//! costs one fork; interior nodes created by edge splits share pages with
//! their children, so the trie's unique footprint stays close to one copy
//! of the distinct token content.
//!
//! Eviction is LRU over leaves against a **unique-byte** budget (shared
//! pages counted once, see [`PrefixCache::bytes`]): evicting a leaf drops
//! only the pages no surviving node references.
//!
//! Determinism: a hit changes *where* prefill computation happens, not its
//! result — cached K/V rows are bit-identical to recomputation (row-wise
//! independent kernels), pinned by `hit_continues_bit_identically` here and
//! `prop_prefix_cache_is_transparent` in `serve::scheduler`.

// DETERMINISM: HashSet deduplicates page pointers when accounting unique
// bytes; only its membership and the commutative byte sum are used, so
// iteration order cannot affect eviction decisions or metrics.
use std::collections::HashSet;

use crate::model::native::KvCache;

/// Trie-internal counters (the scheduler's `ServeMetrics` tracks reuse —
/// including same-round chaining the trie can't see — itself; `evictions`
/// is mirrored from here).
#[derive(Debug, Clone, Default)]
pub struct PrefixStats {
    /// Prefix lookups performed on admission.
    pub lookups: u64,
    /// Lookups that matched a non-empty cached prefix.
    pub hits: u64,
    /// Prompt tokens served from cache instead of prefill.
    pub hit_tokens: u64,
    /// Prompts inserted (or extended) into the trie after serving.
    pub insertions: u64,
    /// Leaves evicted to stay under the unique-byte budget.
    pub evictions: u64,
}

struct Node {
    /// Tokens along the edge from the parent to this node.
    edge: Vec<i32>,
    /// KV state covering the whole prefix ending at this node
    /// (`cache.len()` equals the prefix length).
    cache: KvCache,
    children: Vec<Node>,
    /// LRU stamp (monotone clock, bumped on lookup/insert touches).
    used: u64,
}

/// Radix-trie prefix cache with refcounted pages and LRU eviction.
pub struct PrefixCache {
    roots: Vec<Node>,
    max_bytes: usize,
    clock: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    /// `max_bytes` bounds the unique page footprint; least-recently-used
    /// leaves are evicted past it.
    pub fn new(max_bytes: usize) -> PrefixCache {
        PrefixCache { roots: Vec::new(), max_bytes, clock: 0, stats: PrefixStats::default() }
    }

    /// Trie-internal counters (see [`PrefixStats`]).
    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// Number of cached prefixes (trie nodes).
    pub fn len(&self) -> usize {
        fn count(nodes: &[Node]) -> usize {
            nodes.iter().map(|n| 1 + count(&n.children)).sum()
        }
        count(&self.roots)
    }

    /// True when no prefix is cached.
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// Longest cached proper prefix of `tokens`: `(matched_len, fork)`.
    ///
    /// Never matches all of `tokens` — the caller must re-feed at least the
    /// last prompt token to obtain last-position logits — and only counts a
    /// hit when at least one token is reused.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<(usize, KvCache)> {
        self.stats.lookups += 1;
        self.clock += 1;
        let clock = self.clock;
        let limit = tokens.len().saturating_sub(1);
        if limit == 0 {
            return None;
        }
        let mut nodes = &mut self.roots;
        let mut depth = 0usize;
        let mut best: Option<(usize, KvCache)> = None;
        loop {
            let idx = match nodes.iter().position(|n| n.edge.first() == tokens.get(depth)) {
                Some(i) => i,
                None => break,
            };
            let cur = nodes;
            let node = &mut cur[idx];
            let mut m = 0;
            while m < node.edge.len() && depth + m < limit && node.edge[m] == tokens[depth + m] {
                m += 1;
            }
            // the position() match guarantees edge[0] == tokens[depth] and
            // every path into the loop has depth < limit, so m >= 1
            debug_assert!(m > 0);
            node.used = clock;
            best = Some((depth + m, node.cache.fork_at(depth + m)));
            if m == node.edge.len() && depth + m < limit {
                depth += m;
                nodes = &mut node.children;
                continue;
            }
            break;
        }
        if let Some((n, _)) = &best {
            self.stats.hits += 1;
            self.stats.hit_tokens += *n as u64;
        }
        best
    }

    /// Cache the KV state of a served prompt.  `cache.len()` must equal
    /// `tokens.len()`; the trie stores a fork (pages shared with the
    /// caller, copy-on-write from here on).
    pub fn insert(&mut self, tokens: &[i32], cache: &KvCache) {
        assert_eq!(cache.len(), tokens.len(), "prefix insert: cache/token length mismatch");
        if tokens.is_empty() {
            return;
        }
        self.clock += 1;
        self.stats.insertions += 1;
        let clock = self.clock;
        let mut nodes = &mut self.roots;
        let mut depth = 0usize;
        loop {
            let idx = match nodes.iter().position(|n| n.edge.first() == tokens.get(depth)) {
                Some(i) => i,
                None => {
                    nodes.push(Node {
                        edge: tokens[depth..].to_vec(),
                        cache: cache.fork_at(tokens.len()),
                        children: Vec::new(),
                        used: clock,
                    });
                    return;
                }
            };
            let cur = nodes;
            let node = &mut cur[idx];
            let mut m = 0;
            while m < node.edge.len()
                && depth + m < tokens.len()
                && node.edge[m] == tokens[depth + m]
            {
                m += 1;
            }
            if m < node.edge.len() {
                // diverged (or the new prefix ends) mid-edge: split at m.
                // The node keeps the first m tokens and becomes an interior
                // node whose cache is a fork of the inserted state (shares
                // pages with both sides); the old tail moves to a child.
                let tail = node.edge.split_off(m);
                let child = Node {
                    edge: tail,
                    cache: std::mem::replace(&mut node.cache, cache.fork_at(depth + m)),
                    children: std::mem::take(&mut node.children),
                    used: node.used,
                };
                node.children = vec![child];
                node.used = clock;
                if depth + m < tokens.len() {
                    node.children.push(Node {
                        edge: tokens[depth + m..].to_vec(),
                        cache: cache.fork_at(tokens.len()),
                        children: Vec::new(),
                        used: clock,
                    });
                }
                return;
            }
            // full edge match
            node.used = clock;
            if depth + m == tokens.len() {
                return; // already cached (same tokens ⇒ same KV, bit for bit)
            }
            depth += m;
            nodes = &mut node.children;
        }
    }

    /// Unique live bytes across all cached pages (a page shared by several
    /// nodes — or by a node and its parent via edge splits — counts once).
    pub fn bytes(&self) -> usize {
        let mut seen = HashSet::new();
        self.add_unique_bytes(&mut seen)
    }

    /// [`PrefixCache::bytes`] against an external `seen` set, so callers
    /// can account trie pages and active-sequence pages without double
    /// counting (the scheduler's live-KV gauge).
    pub fn add_unique_bytes(&self, seen: &mut HashSet<usize>) -> usize {
        let mut total = 0;
        let mut stack: Vec<&Node> = self.roots.iter().collect();
        while let Some(n) = stack.pop() {
            for (ptr, b) in n.cache.page_refs() {
                if seen.insert(ptr) {
                    total += b;
                }
            }
            stack.extend(n.children.iter());
        }
        total
    }

    /// Evict LRU leaves until the unique-byte footprint fits `max_bytes`.
    ///
    /// Page refcounts are built once per call and updated incrementally as
    /// leaves are popped, so an eviction storm costs one accounting pass
    /// plus O(nodes) per evicted leaf — not a full unique-byte recount per
    /// eviction.
    pub fn enforce_budget(&mut self) {
        // DETERMINISM: refcount map keyed by page pointer; eviction order
        // comes from LRU `used` stamps and the byte total is a commutative
        // sum, so map iteration order never changes which leaf is evicted.
        use std::collections::HashMap;
        // ptr -> (bytes, refs across all nodes)
        fn collect(nodes: &[Node], counts: &mut HashMap<usize, (usize, usize)>) {
            for n in nodes {
                for (ptr, b) in n.cache.page_refs() {
                    counts.entry(ptr).or_insert((b, 0)).1 += 1;
                }
                collect(&n.children, counts);
            }
        }
        let mut counts: HashMap<usize, (usize, usize)> = HashMap::new();
        collect(&self.roots, &mut counts);
        let mut total: usize = counts.values().map(|(b, _)| *b).sum();
        while total > self.max_bytes {
            let Some(removed) = self.pop_lru_leaf() else { break };
            for (ptr, b) in removed.cache.page_refs() {
                if let Some(e) = counts.get_mut(&ptr) {
                    e.1 -= 1;
                    if e.1 == 0 {
                        total -= b;
                    }
                }
            }
            self.stats.evictions += 1;
        }
    }

    fn pop_lru_leaf(&mut self) -> Option<Node> {
        fn min_leaf(nodes: &[Node]) -> Option<u64> {
            let mut best: Option<u64> = None;
            for n in nodes {
                let cand = if n.children.is_empty() { Some(n.used) } else { min_leaf(&n.children) };
                if let Some(c) = cand {
                    best = Some(best.map_or(c, |b| b.min(c)));
                }
            }
            best
        }
        fn take(nodes: &mut Vec<Node>, stamp: u64) -> Option<Node> {
            if let Some(i) = nodes.iter().position(|n| n.children.is_empty() && n.used == stamp) {
                return Some(nodes.remove(i));
            }
            nodes.iter_mut().find_map(|n| take(&mut n.children, stamp))
        }
        let stamp = min_leaf(&self.roots)?;
        take(&mut self.roots, stamp)
    }

    /// Drop everything (tests / model reload).
    pub fn clear(&mut self) {
        self.roots.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::{forward_cached, prefill};
    use crate::model::{OptConfig, Weights};

    fn setup() -> (Weights, OptConfig) {
        let cfg = OptConfig::test_config();
        (Weights::random(cfg.clone(), 4), cfg)
    }

    fn filled(w: &Weights, cfg: &OptConfig, tokens: &[i32]) -> KvCache {
        let mut c = KvCache::new(cfg);
        prefill(w, &mut c, tokens);
        c
    }

    #[test]
    fn lookup_miss_then_hit() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let prompt = vec![1i32, 2, 3, 4, 5, 6];
        assert!(pc.lookup(&prompt).is_none());
        let cache = filled(&w, &cfg, &prompt);
        pc.insert(&prompt, &cache);

        // identical prompt: matches all but the last token
        let (n, fork) = pc.lookup(&prompt).expect("hit");
        assert_eq!(n, prompt.len() - 1);
        assert_eq!(fork.len(), n);

        // longer prompt sharing the full prefix
        let longer: Vec<i32> = prompt.iter().copied().chain([9, 9]).collect();
        let (n, _) = pc.lookup(&longer).expect("hit");
        assert_eq!(n, prompt.len());

        // diverging after 3 tokens
        let other = vec![1i32, 2, 3, 7, 7, 7];
        let (n, _) = pc.lookup(&other).expect("partial hit");
        assert_eq!(n, 3);

        // different first token: miss
        assert!(pc.lookup(&[9, 1, 2]).is_none());
        let s = pc.stats();
        assert_eq!(s.lookups, 5, "including the pre-insert miss");
        assert_eq!(s.hits, 3);
    }

    #[test]
    fn hit_continues_bit_identically() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let first = vec![5i32, 8, 13, 21, 3, 9, 11, 2];
        pc.insert(&first, &filled(&w, &cfg, &first));

        // second prompt shares 5 tokens then diverges
        let second: Vec<i32> = first[..5].iter().copied().chain([40, 41, 42]).collect();
        let (n, mut fork) = pc.lookup(&second).expect("hit");
        assert_eq!(n, 5);
        let via_cache = forward_cached(&w, &mut fork, &second[n..]);
        let mut fresh = KvCache::new(&cfg);
        let via_fresh = prefill(&w, &mut fresh, &second);
        assert_eq!(via_cache, via_fresh, "prefix-cache prefill must be bit-identical");
    }

    #[test]
    fn edge_split_keeps_both_entries() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let a = vec![1i32, 2, 3, 4, 5, 6];
        let b = vec![1i32, 2, 3, 9, 9, 9];
        pc.insert(&a, &filled(&w, &cfg, &a));
        pc.insert(&b, &filled(&w, &cfg, &b)); // splits a's edge at 3
        assert_eq!(pc.len(), 3, "interior + two leaves");
        let (na, _) = pc.lookup(&a).expect("a survives the split");
        assert_eq!(na, a.len() - 1);
        let (nb, _) = pc.lookup(&b).expect("b cached");
        assert_eq!(nb, b.len() - 1);
        // the interior node itself serves the common prefix
        let c = vec![1i32, 2, 3, 7];
        let (nc, fork) = pc.lookup(&c).expect("common prefix");
        assert_eq!(nc, 3);
        assert_eq!(fork.len(), 3);
    }

    #[test]
    fn single_token_prompt_never_hits() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let prompt = vec![7i32, 8];
        pc.insert(&prompt, &filled(&w, &cfg, &prompt));
        // a 1-token prompt has no proper prefix to reuse
        assert!(pc.lookup(&[7]).is_none());
        assert!(pc.lookup(&[]).is_none());
    }

    #[test]
    fn shared_pages_counted_once() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let a = vec![1i32, 2, 3, 4, 5, 6];
        let cache = filled(&w, &cfg, &a);
        pc.insert(&a, &cache);
        let solo = pc.bytes();
        assert!(solo > 0);
        assert_eq!(solo, cache.allocated_bytes(), "trie shares the caller's pages");
        // inserting a prompt diverging mid-page shares the common pages
        let b = vec![1i32, 2, 3, 9, 9, 9];
        pc.insert(&b, &filled(&w, &cfg, &b));
        assert!(pc.bytes() <= 2 * solo, "unique accounting must dedup shared pages");
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let (w, cfg) = setup();
        let one_entry = filled(&w, &cfg, &[1, 2, 3, 4]).allocated_bytes();
        // budget for about two disjoint entries
        let mut pc = PrefixCache::new(2 * one_entry);
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| (0..4).map(|t| (10 * i + t) as i32).collect())
            .collect();
        for p in &prompts {
            pc.insert(p, &filled(&w, &cfg, p));
            pc.enforce_budget();
        }
        assert!(pc.bytes() <= 2 * one_entry, "budget enforced");
        assert!(pc.stats().evictions >= 2, "oldest entries evicted");
        // the most recent entry survives
        assert!(pc.lookup(&prompts[3]).is_some());
        // the oldest was evicted
        assert!(pc.lookup(&prompts[0]).is_none());
    }

    #[test]
    fn insert_is_idempotent() {
        let (w, cfg) = setup();
        let mut pc = PrefixCache::new(usize::MAX);
        let p = vec![3i32, 1, 4, 1, 5];
        let cache = filled(&w, &cfg, &p);
        pc.insert(&p, &cache);
        let n1 = pc.len();
        let b1 = pc.bytes();
        pc.insert(&p, &cache);
        assert_eq!(pc.len(), n1);
        assert_eq!(pc.bytes(), b1);
    }
}

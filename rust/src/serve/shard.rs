//! Tensor-parallel sharded serving: every worker owns a **row slice** of
//! every layer's weights and computes the matching slice of each linear's
//! output.
//!
//! The row-tiled fused packed kernels (`quant::packed`) partition cleanly
//! by output rows, so sharding a linear means slicing its
//! [`PackedTensor`] into per-shard row ranges ([`PackedTensor::slice_rows`])
//! and letting each shard run the *same* fused unpack→dequant→GEMM over its
//! slice.  The per-layer "reduction" is pure concatenation — each shard's
//! partial output is a disjoint column range of the full output, no
//! floating-point summation ever crosses shards — which is what makes the
//! sharded forward **bit-identical** to the single-shard path for every
//! shard count (pinned for shards ∈ {1, 2, 4} by
//! `sharded_forward_bit_identical_across_shard_counts`).
//!
//! Shard boundaries land on whole 64-row kernel tiles ([`shard_ranges`]),
//! so each shard's tile decomposition and 4-wide/`dot`-tail column split
//! are exactly the sub-ranges the whole-matrix kernel would compute —
//! the bit-identity is structural, not incidental.
//!
//! Non-linear parameters (embeddings, positions, LayerNorms, biases) are
//! small next to the packed linears and are replicated on every shard, as
//! in standard Megatron-style tensor parallelism.

// DETERMINISM: HashMap holds the per-shard weight slices for keyed lookup
// by parameter name only; the forward pass asks for specific names, so
// iteration order never influences compute or output.
use std::collections::HashMap;

use crate::model::native::DecoderParams;
use crate::model::{OptConfig, Weights};
use crate::quant::PackedTensor;
use crate::serve::PackedModel;
use crate::tensor::{ops, Tensor};
use crate::util::pool;

/// Kernel output-row tile — shard boundaries must land on multiples of
/// this so each shard's tile decomposition matches the whole-matrix
/// kernel's (see `quant::packed`'s `ROW_TILE`, same value by contract).
const SHARD_TILE: usize = 64;

/// Partition `rows` output rows into `n_shards` contiguous ranges
/// `(r0, len)`, balanced to within one 64-row kernel tile.
///
/// Every boundary is tile-aligned, so a sharded linear over these ranges
/// is bit-identical to the whole-matrix kernel (see
/// [`PackedTensor::slice_rows`]).  Ranges cover `0..rows` exactly, in
/// order, without overlap; when there are fewer tiles than shards the
/// trailing ranges are empty.
pub fn shard_ranges(rows: usize, n_shards: usize) -> Vec<(usize, usize)> {
    assert!(n_shards >= 1, "shard_ranges: need at least one shard");
    let tiles = rows.div_ceil(SHARD_TILE);
    let mut out = Vec::with_capacity(n_shards);
    let mut t0 = 0usize;
    for s in 0..n_shards {
        let t1 = tiles * (s + 1) / n_shards;
        let r0 = (t0 * SHARD_TILE).min(rows);
        let r1 = (t1 * SHARD_TILE).min(rows);
        out.push((r0, r1 - r0));
        t0 = t1;
    }
    out
}

/// One linear's weights, split into per-shard row slices.
enum ShardedLinear {
    /// Packed slices, one per non-empty shard range: `(r0, slice)`.
    Packed(Vec<(usize, PackedTensor)>),
    /// Dense fallback slices for linears the model serves unquantized.
    Dense(Vec<(usize, Tensor)>),
}

/// A [`PackedModel`] split row-wise across `n_shards` tensor-parallel
/// workers.
///
/// Implements [`DecoderParams`], so the continuous-batching scheduler and
/// the router serve it exactly like a single-shard model; every linear
/// fans out across the shard slices (in parallel on the thread pool) and
/// concatenates the disjoint partial outputs.  Completions are
/// bit-identical to serving the unsharded [`PackedModel`] — sharding is a
/// pure scale-out knob.
pub struct ShardedModel {
    fp: Weights,
    n_shards: usize,
    linears: HashMap<String, ShardedLinear>,
}

impl ShardedModel {
    /// Split `pm` into `n_shards` row-parallel workers.  Packed linears are
    /// sliced with [`PackedTensor::slice_rows`]; dense-fallback linears are
    /// sliced row-wise on the FP weights; everything else is replicated.
    pub fn new(pm: &PackedModel, n_shards: usize) -> ShardedModel {
        assert!(n_shards >= 1, "ShardedModel: need at least one shard");
        let fp = pm.weights().clone();
        let mut linears = HashMap::new();
        for name in fp.quant_names() {
            let lin = match pm.packed_of(&name) {
                Some(p) => ShardedLinear::Packed(
                    shard_ranges(p.rows, n_shards)
                        .into_iter()
                        .filter(|&(_, n)| n > 0)
                        .map(|(r0, n)| (r0, p.slice_rows(r0, n)))
                        .collect(),
                ),
                None => {
                    let w = fp.get(&name);
                    ShardedLinear::Dense(
                        shard_ranges(w.rows, n_shards)
                            .into_iter()
                            .filter(|&(_, n)| n > 0)
                            .map(|(r0, n)| {
                                let data = w.data[r0 * w.cols..(r0 + n) * w.cols].to_vec();
                                (r0, Tensor::from_vec(n, w.cols, data))
                            })
                            .collect(),
                    )
                }
            };
            linears.insert(name, lin);
        }
        ShardedModel { fp, n_shards, linears }
    }

    /// Number of tensor-parallel workers this model is split across.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Bytes of packed weight slices owned by each shard — the per-worker
    /// residency a deployment would place on each device.  Index `s` is
    /// shard `s`; dense-fallback and replicated FP weights are excluded.
    pub fn packed_bytes_per_shard(&self) -> Vec<usize> {
        let mut bytes = vec![0usize; self.n_shards];
        // per-shard totals: recover each slice's shard index from its row
        // offset (ranges are in shard order and slices store r0)
        for lin in self.linears.values() {
            if let ShardedLinear::Packed(slices) = lin {
                let rows: usize = slices.iter().map(|(_, p)| p.rows).sum();
                let ranges = shard_ranges(rows, self.n_shards);
                for (r0, p) in slices {
                    if let Some(s) = ranges.iter().position(|&(q0, n)| q0 == *r0 && n > 0) {
                        bytes[s] += p.nbytes();
                    }
                }
            }
        }
        bytes
    }

    /// Fan one linear out across the shard slices and concatenate the
    /// disjoint column ranges of the output.  Shards compute in parallel
    /// on the thread pool (ordered results), each through the exact kernel
    /// the unsharded path runs over its row range — bit-identity per slice
    /// is pinned by `slice_rows_linear_matches_whole` in `quant::packed`.
    fn sharded_linear(&self, wname: &str, bias: &[f32], x: &Tensor) -> Tensor {
        // PANIC-OK: construction covers every quantizable linear name, and
        // DecoderParams::linear is only called with those — a miss is a
        // programming error caught by every forward in the test suite.
        let lin = self.linears.get(wname).expect("sharded linear exists");
        let rows_total = bias.len();
        let mut out = Tensor::zeros(x.rows, rows_total);
        let partials: Vec<(usize, Tensor)> = match lin {
            ShardedLinear::Packed(slices) => {
                let threads = pool::num_threads().min(slices.len());
                pool::parallel_map(slices.len(), threads, |s| {
                    let (r0, p) = &slices[s];
                    (*r0, p.linear(x, &bias[*r0..*r0 + p.rows]))
                })
            }
            ShardedLinear::Dense(slices) => {
                let threads = pool::num_threads().min(slices.len());
                pool::parallel_map(slices.len(), threads, |s| {
                    let (r0, w) = &slices[s];
                    (*r0, ops::linear(x, w, &bias[*r0..*r0 + w.rows]))
                })
            }
        };
        for (r0, part) in &partials {
            let n = part.cols;
            for i in 0..x.rows {
                out.data[i * rows_total + r0..i * rows_total + r0 + n]
                    .copy_from_slice(&part.data[i * n..(i + 1) * n]);
            }
        }
        out
    }
}

impl DecoderParams for ShardedModel {
    fn config(&self) -> &OptConfig {
        &self.fp.config
    }

    fn dense(&self, name: &str) -> &Tensor {
        self.fp.get(name)
    }

    fn linear(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        let bias = &self.fp.layer(l, &format!("{base}.b")).data;
        self.sharded_linear(&format!("l{l}.{base}.w"), bias, x)
    }

    fn linear_batch(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        // the packed slice kernel is already the cache-blocked multi-row
        // GEMM (`PackedTensor::linear_batch` == `linear`), so batching
        // routes through the same sharded fan-out
        self.linear(l, base, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::{self, KvCache};
    use crate::quant::{self, BitAllocation, QuantScheme};
    use crate::serve::{Request, Scheduler, ServeOpts};
    use crate::util::propcheck;
    use crate::util::rng::Pcg64;
    use crate::util::sampling::Sampler;

    fn packed_model(seed: u64) -> PackedModel {
        let w = Weights::random(OptConfig::test_config(), seed);
        let alloc = BitAllocation::uniform(QuantScheme::new(2, 32));
        PackedModel::from_allocation(w, &alloc).unwrap()
    }

    #[test]
    fn shard_ranges_cover_rows_exactly_and_tile_aligned() {
        propcheck::check("shard_ranges partition", 64, |rng| {
            let rows = rng.below(600) + 1;
            let n_shards = *rng.choice(&[1usize, 2, 3, 4, 8]);
            let ranges = shard_ranges(rows, n_shards);
            if ranges.len() != n_shards {
                return Err(format!("{} ranges for {n_shards} shards", ranges.len()));
            }
            let mut next = 0usize;
            for &(r0, n) in &ranges {
                if r0 != next {
                    return Err(format!("gap/overlap at {r0}, expected {next}"));
                }
                if r0 % SHARD_TILE != 0 {
                    return Err(format!("unaligned shard start {r0}"));
                }
                if n % SHARD_TILE != 0 && r0 + n != rows {
                    return Err(format!("interior shard ({r0},{n}) not tile-aligned"));
                }
                next = r0 + n;
            }
            propcheck::ensure(next == rows, format!("covered {next} of {rows} rows"))
        });
    }

    #[test]
    fn sharded_forward_bit_identical_across_shard_counts() {
        // the tentpole pin: prefill AND decode logits from the sharded
        // model equal the unsharded PackedModel bit-for-bit, for every
        // pinned shard count
        let pm = packed_model(9);
        let mut rng = Pcg64::new(1);
        let toks: Vec<i32> = (0..12).map(|_| rng.below(pm.config().vocab) as i32).collect();
        let mut c0 = KvCache::new(pm.config());
        let base_prefill = native::prefill(&pm, &mut c0, &toks);
        for shards in [1usize, 2, 4] {
            let sm = ShardedModel::new(&pm, shards);
            assert_eq!(sm.n_shards(), shards);
            let mut c1 = KvCache::new(sm.config());
            let l1 = native::prefill(&sm, &mut c1, &toks);
            assert_eq!(base_prefill, l1, "prefill diverged at {shards} shards");
            let mut c0d = c0.clone();
            for t in [3i32, 7, 11, 40] {
                let d0 = native::decode_step(&pm, &mut c0d, t);
                let d1 = native::decode_step(&sm, &mut c1, t);
                assert_eq!(d0, d1, "decode diverged at {shards} shards (token {t})");
            }
        }
    }

    #[test]
    fn sharded_mixed_precision_forward_matches_unsharded() {
        // heterogeneous schemes slice per-tensor (each slice carries its
        // own bits/group header), and a deliberately unpacked linear
        // exercises the dense row-slice fallback
        let w = Weights::random(OptConfig::test_config(), 17);
        let scheme = QuantScheme::new(2, 32);
        let packed: Vec<(String, PackedTensor)> = w
            .quant_names()
            .iter()
            .filter(|n| n.as_str() != "l0.up.w") // dense fallback
            .map(|n| {
                let s = if n.contains("down") { QuantScheme::new(4, 32) } else { scheme };
                (n.clone(), PackedTensor::pack(&quant::quantize(w.get(n), s)))
            })
            .collect();
        let pm = PackedModel::new(w, packed);
        let mut rng = Pcg64::new(5);
        let toks: Vec<i32> = (0..10).map(|_| rng.below(pm.config().vocab) as i32).collect();
        let mut c0 = KvCache::new(pm.config());
        let l0 = native::prefill(&pm, &mut c0, &toks);
        for shards in [2usize, 4] {
            let sm = ShardedModel::new(&pm, shards);
            let mut c1 = KvCache::new(sm.config());
            assert_eq!(l0, native::prefill(&sm, &mut c1, &toks), "{shards} shards");
        }
    }

    #[test]
    fn sharded_scheduler_completions_bit_identical() {
        // end to end: the continuous-batching scheduler over the sharded
        // model reproduces the single-shard completions exactly
        let pm = packed_model(9);
        let vocab = pm.config().vocab;
        let run = |params: &dyn DecoderParams| {
            let mut s = Scheduler::new(
                params,
                ServeOpts { max_batch: 2, seed: 3, ..Default::default() },
            );
            let mut rng = Pcg64::new(8);
            for i in 0..4 {
                s.submit(Request::new(
                    i,
                    (0..5 + i % 2).map(|_| rng.below(vocab) as i32).collect(),
                    4,
                    Sampler::TopK { k: 4, temperature: 0.7 },
                ));
            }
            s.run().0
        };
        let reference = run(&pm);
        for shards in [1usize, 2, 4] {
            let sm = ShardedModel::new(&pm, shards);
            assert_eq!(reference, run(&sm), "completions diverged at {shards} shards");
        }
    }

    #[test]
    fn more_shards_than_tiles_still_exact() {
        // test_config linears are 32-64 rows — one tile — so at 4 shards
        // three ranges are empty; the fan-out must skip them gracefully
        let pm = packed_model(11);
        let sm = ShardedModel::new(&pm, 4);
        let mut rng = Pcg64::new(2);
        let toks: Vec<i32> = (0..6).map(|_| rng.below(pm.config().vocab) as i32).collect();
        let mut c0 = KvCache::new(pm.config());
        let mut c1 = KvCache::new(sm.config());
        assert_eq!(
            native::prefill(&pm, &mut c0, &toks),
            native::prefill(&sm, &mut c1, &toks)
        );
    }

    #[test]
    fn per_shard_bytes_account_the_packed_slices() {
        let pm = packed_model(9);
        let sm = ShardedModel::new(&pm, 2);
        let per = sm.packed_bytes_per_shard();
        assert_eq!(per.len(), 2);
        assert!(per[0] > 0, "shard 0 must own packed rows");
        // slicing re-packs zeros per slice, so the sum can exceed the
        // unsharded total only by per-slice padding slack
        let total: usize = per.iter().sum();
        assert!(
            total >= pm.packed_bytes() / 2,
            "per-shard accounting lost weight bytes: {total} vs {}",
            pm.packed_bytes()
        );
    }
}

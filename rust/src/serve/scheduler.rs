//! The continuous-batching engine: admission policies, mid-flight slot
//! refill, per-request rejection and cancellation, prefix-cache reuse,
//! streaming sinks and telemetry.
//!
//! One scheduling round = (1) sample queue depth, (2) admit requests into
//! free decode slots in policy order — validating each one and emitting a
//! [`FinishReason::Rejected`] completion instead of panicking on malformed
//! input, (3) prefill the admitted prompts (reusing the longest cached
//! prefix when the prefix cache is on), (4) retire finished / stopped /
//! cancelled sequences (freeing their slots for the next round's
//! admission), (5) advance every active sequence one token.  The loop runs
//! until queue and slots are both empty, so slots freed mid-flight are
//! refilled while other sequences keep decoding — no drain barrier.
//!
//! Determinism: each request samples from its own RNG stream
//! (`seed` ⊕ id) and every kernel computes sequence positions
//! independently, so completions are bit-identical across `max_batch`,
//! admission policy, thread count, and prefix cache on/off (pinned below).
//! Prefill is data-parallel across admitted prompts; with the prefix cache
//! on, prompts are grouped by sorted order (lexicographic neighbors
//! maximize shared prefixes) — groups prefill in parallel while slots
//! within a group chain off their predecessor's cache, so same-round
//! sharing is captured without serializing unrelated prompts.

// DETERMINISM: HashSet here backs the cancellation registry and admitted-id
// tracking — membership tests and keyed removal only; no iteration order
// ever reaches scheduling decisions or completions.
use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::native::{self, DecoderParams, KvCache};
use crate::serve::fault::FaultInjector;
use crate::serve::metrics::ServeMetrics;
use crate::serve::prefix::PrefixCache;
use crate::serve::spec::{self, SpecRound};
use crate::serve::stream::{FinishReason, StopCondition};
use crate::obs::trace;
use crate::serve::{Completion, Request, RequestTiming, ServeOpts, ServeStats};
use crate::util::pool;
use crate::util::rng::Pcg64;

/// Order in which queued requests claim freed decode slots.  All policies
/// respect `Request::priority` first (lower admits first); completions do
/// not depend on the policy — only latency does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// First come, first served (arrival order).
    #[default]
    Fcfs,
    /// Shortest prompt first (ties by arrival): minimizes mean TTFT under
    /// mixed prompt lengths.
    ShortestPrompt,
    /// Earliest deadline first; requests without a deadline go last, by
    /// arrival.
    Deadline,
}

impl AdmissionPolicy {
    /// Parse a CLI/serve-config spec: `fcfs`, `spf` (or `shortest`),
    /// `edf` (or `deadline`).
    pub fn parse(s: &str) -> crate::Result<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Ok(AdmissionPolicy::Fcfs),
            "spf" | "shortest" | "shortest-prompt" => Ok(AdmissionPolicy::ShortestPrompt),
            "edf" | "deadline" => Ok(AdmissionPolicy::Deadline),
            _ => anyhow::bail!("unknown admission policy {s:?} (fcfs|spf|edf)"),
        }
    }

    /// Index of the queued request to admit next.
    fn select(&self, queue: &[Queued], epoch: Instant) -> usize {
        let key = |q: &Queued| -> (i64, u64, u64) {
            match self {
                AdmissionPolicy::Fcfs => (q.req.priority as i64, 0, q.arrival),
                AdmissionPolicy::ShortestPrompt => {
                    (q.req.priority as i64, q.req.prompt.len() as u64, q.arrival)
                }
                AdmissionPolicy::Deadline => {
                    let d = q
                        .deadline_at
                        .map(|d| d.saturating_duration_since(epoch).as_millis() as u64)
                        .unwrap_or(u64::MAX);
                    (q.req.priority as i64, d, q.arrival)
                }
            }
        };
        queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| key(q))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Shared cancellation registry.  Clone it out of the scheduler
/// ([`Scheduler::cancel_handle`]) and call [`CancelHandle::cancel`] from
/// any thread — including a streaming sink running inside a decode round.
/// A cancelled request finishes with [`FinishReason::Cancelled`] at the
/// next round boundary (queued requests are cancelled at admission).
///
/// Cancellations apply to requests queued or in flight when consumed; a
/// cancellation is dropped once its request finishes (for any reason), and
/// unmatched ids are dropped when a run drains, so stale cancels never
/// leak into later requests reusing an id.
#[derive(Debug, Clone, Default)]
pub struct CancelHandle {
    ids: Arc<Mutex<HashSet<usize>>>,
}

impl CancelHandle {
    /// Lock the id set, recovering from poison: the registry holds a plain
    /// `HashSet`, so a panic on another thread cannot leave it in a
    /// torn state — worst case a cancellation is retained, never invented.
    fn ids(&self) -> std::sync::MutexGuard<'_, HashSet<usize>> {
        self.ids.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Cancel request `id`: queued requests retire without starting,
    /// in-flight ones stop at the next round boundary.
    pub fn cancel(&self, id: usize) {
        self.ids().insert(id);
    }

    /// True when `id` has been cancelled and not yet retired.
    pub fn is_cancelled(&self, id: usize) -> bool {
        self.ids().contains(&id)
    }

    fn snapshot(&self) -> HashSet<usize> {
        self.ids().clone()
    }

    /// Drop a consumed id so the set cannot grow unboundedly and a later
    /// request reusing the id is not spuriously cancelled.
    fn clear_id(&self, id: usize) {
        self.ids().remove(&id);
    }

    /// Drop everything — called when a run drains, at which point any
    /// remaining id matches no queued or in-flight request.
    fn clear_all(&self) {
        self.ids().clear();
    }
}

/// A queued request plus its admission bookkeeping.
struct Queued {
    req: Request,
    arrival: u64,
    submitted_at: Instant,
    deadline_at: Option<Instant>,
}

/// An admitted in-flight sequence.
struct Slot {
    req: Request,
    cache: KvCache,
    stop: StopCondition,
    generated: Vec<i32>,
    /// Most recently sampled token, not yet fed back through the model.
    last: i32,
    rng: Pcg64,
    /// Prompt tokens reused from the prefix cache (trie hit) or from a
    /// same-round neighbor's cache (intra-round chaining) — not prefilled.
    reused: usize,
    /// Draft-model KV cache (speculative decoding only); caught up lazily
    /// on the slot's first speculative round, rolled back with the target
    /// cache after each verify.
    draft_cache: Option<KvCache>,
    /// This round's speculation outcome, drained into stats/metrics at the
    /// round boundary (`None` on plain decode rounds).
    spec_round: Option<SpecRound>,
    /// Set when a stop condition fired; retired at the round boundary.
    finish: Option<FinishReason>,
    submitted_at: Instant,
    /// When this slot entered the running batch (queue-wait boundary; also
    /// the start of the request's prefill span).
    admitted_at: Instant,
    last_token_at: Instant,
    /// Stamped at the first sampled token — the TTFT boundary, the end of
    /// the prefill span and the start of the decode span.
    first_token_at: Option<Instant>,
    /// Decode rounds this slot participated in (plain or speculative).
    decode_rounds: u32,
    /// Wall-clock of this slot's most recent decode step, measured inside
    /// the parallel closure and compared against
    /// [`ServeOpts::round_budget_ms`] at the round boundary.
    round_elapsed: Duration,
    /// Measured inside the (parallel) sampling closure, drained into the
    /// metrics histograms on the scheduler thread.
    ttft: Option<Duration>,
    itl_pending: Option<Duration>,
}

impl Slot {
    /// Commit the token sampled from `logits` (prefill or decode step).
    fn push_token(&mut self, logits: &[f32]) {
        let tok = self.req.sampler.sample(logits, &mut self.rng) as i32;
        let idx = self.generated.len();
        self.generated.push(tok);
        self.last = tok;
        let now = Instant::now();
        if idx == 0 {
            self.ttft = Some(now.duration_since(self.submitted_at));
            self.first_token_at = Some(now);
        } else {
            self.itl_pending = Some(now.duration_since(self.last_token_at));
        }
        self.last_token_at = now;
        if let Some(sink) = self.req.sink.as_mut() {
            sink.on_token(tok, idx);
        }
        if self.stop.hit(&self.generated) {
            self.finish = Some(FinishReason::Stop);
        }
    }

    /// Close out this slot's lifecycle accounting: record the queue/prefill/
    /// decode histograms, emit the request's spans (when tracing is on —
    /// spans reuse the same boundary instants, so the Chrome trace and the
    /// [`RequestTiming`] agree up to 1 µs truncation), and return the
    /// per-request breakdown for its [`Completion`].
    fn retire(&self, metrics: &mut ServeMetrics) -> RequestTiming {
        let queue_wait = self.admitted_at.duration_since(self.submitted_at);
        metrics.queue_wait.record(queue_wait);
        let mut timing = RequestTiming {
            queue_us: us(queue_wait),
            decode_rounds: self.decode_rounds,
            ..RequestTiming::default()
        };
        if let Some(ft) = self.first_token_at {
            let prefill = ft.duration_since(self.admitted_at);
            let decode = self.last_token_at.duration_since(ft);
            metrics.prefill.record(prefill);
            metrics.decode.record(decode);
            timing.prefill_us = us(prefill);
            timing.decode_us = us(decode);
            timing.ttft_us = us(ft.duration_since(self.submitted_at));
        }
        if crate::obs::enabled() {
            let id = self.req.id as u64;
            trace::complete("serve", "queue", id, self.submitted_at, self.admitted_at);
            if let Some(ft) = self.first_token_at {
                trace::complete("serve", "prefill", id, self.admitted_at, ft);
                trace::complete("serve", "decode", id, ft, self.last_token_at);
            }
            trace::mark("serve", "finish", id);
        }
        timing
    }
}

/// Whole microseconds of a duration, saturating at `u64::MAX`.
fn us(d: Duration) -> u64 {
    d.as_micros().min(u64::MAX as u128) as u64
}

/// Continuous-batching scheduler over any [`DecoderParams`] source.
pub struct Scheduler<'a, P: DecoderParams + ?Sized> {
    params: &'a P,
    opts: ServeOpts,
    queue: Vec<Queued>,
    arrivals: u64,
    epoch: Instant,
    cancel: CancelHandle,
    prefix: Option<PrefixCache>,
    metrics: ServeMetrics,
    /// Draft model for self-speculative decoding ([`Scheduler::with_draft`];
    /// active when `opts.spec > 0`).
    draft: Option<&'a dyn DecoderParams>,
    /// Injection hooks for deterministic chaos runs
    /// ([`Scheduler::set_fault`]; `None` — the default — costs one
    /// `Option` check per round).
    fault: Option<FaultInjector>,
}

impl<'a, P: DecoderParams + ?Sized> Scheduler<'a, P> {
    /// Scheduler over `params` (dense weights, a
    /// [`crate::serve::PackedModel`], or a [`crate::serve::ShardedModel`] —
    /// anything implementing [`DecoderParams`]).
    pub fn new(params: &'a P, opts: ServeOpts) -> Scheduler<'a, P> {
        assert!(opts.max_batch >= 1, "max_batch must be >= 1");
        let mut metrics = ServeMetrics::new();
        metrics.kv_dtype = opts.kv_dtype;
        Scheduler {
            params,
            opts,
            queue: Vec::new(),
            arrivals: 0,
            epoch: Instant::now(),
            cancel: CancelHandle::default(),
            prefix: opts.prefix_cache.then(|| PrefixCache::new(opts.prefix_cache_bytes)),
            metrics,
            draft: None,
            fault: None,
        }
    }

    /// Attach a draft model for self-speculative decoding (typically the
    /// same base weights packed at an aggressive low-bit allocation —
    /// [`crate::serve::PackedModel::draft`]).  Speculation runs once
    /// `ServeOpts::spec > 0` *and* a draft is attached; completions stay
    /// bit-identical to plain decoding either way, so this is purely a
    /// throughput knob.  The draft must share the target's vocabulary and
    /// context length (its depth/width may differ).
    pub fn with_draft(mut self, draft: &'a dyn DecoderParams) -> Scheduler<'a, P> {
        let (t, d) = (self.params.config(), draft.config());
        assert_eq!(t.vocab, d.vocab, "draft/target vocab mismatch");
        assert_eq!(t.max_seq, d.max_seq, "draft/target context-length mismatch");
        self.draft = Some(draft);
        self
    }

    /// Attach deterministic fault-injection hooks
    /// ([`crate::serve::FaultPlan::injector_for`]) — the scheduler will
    /// honor the plan's replica kills and decode stalls during `run`.
    /// Chaos-testing only; without this call the fault path is a single
    /// `Option` check per round.
    pub fn set_fault(&mut self, injector: FaultInjector) {
        self.fault = Some(injector);
    }

    /// Drain the not-yet-admitted queue in arrival order, sinks intact.
    /// Router supervision uses this to recover the queued (never-started)
    /// requests of a replica whose run thread died; in-flight requests are
    /// lost with the thread and rebuilt from retained specs instead.
    pub(crate) fn take_queue(&mut self) -> Vec<Request> {
        let mut q = std::mem::take(&mut self.queue);
        q.sort_by_key(|x| x.arrival);
        q.into_iter().map(|x| x.req).collect()
    }

    /// Enqueue a request; it is admitted by the [`AdmissionPolicy`] when a
    /// decode slot frees up during [`Scheduler::run`].
    pub fn submit(&mut self, req: Request) {
        let arrival = self.arrivals;
        self.arrivals += 1;
        let submitted_at = Instant::now();
        let deadline_at = req.deadline_ms.map(|ms| submitted_at + Duration::from_millis(ms));
        self.queue.push(Queued { req, arrival, submitted_at, deadline_at });
    }

    /// Requests submitted but not yet run.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Handle for cancelling requests from other threads (or sinks).
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }

    /// Cancel a request by id (queued or in-flight).
    pub fn cancel(&self, id: usize) {
        self.cancel.cancel(id);
    }

    /// Telemetry accumulated over all completed `run` calls.
    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Unique bytes currently held by the prefix cache (0 when disabled).
    pub fn prefix_cache_bytes(&self) -> usize {
        self.prefix.as_ref().map_or(0, |pc| pc.bytes())
    }

    /// Run the continuous-batching loop until queue and slots are empty.
    /// Every submitted request yields exactly one [`Completion`] (sorted by
    /// id), including rejected and cancelled ones.
    pub fn run(&mut self) -> (Vec<Completion>, ServeStats) {
        let params = self.params;
        let cfg = params.config();
        let max_seq = cfg.max_seq;
        let mut prefix = self.prefix.take();
        let mut stats = ServeStats::default();
        let mut done: Vec<Completion> = Vec::new();
        let mut active: Vec<Slot> = Vec::new();
        let mut round: u64 = 0;

        while !self.queue.is_empty() || !active.is_empty() {
            round += 1;
            if let Some(fi) = &self.fault {
                // may panic by design: an injected replica kill — the
                // router's supervision layer catches and redispatches
                fi.tick_round(round);
            }
            self.metrics.record_queue_depth(self.queue.len());
            let cancelled = self.cancel.snapshot();

            // -- admission: policy picks requests for the free slots ---------
            let mut admitted: Vec<Slot> = Vec::new();
            while active.len() + admitted.len() < self.opts.max_batch && !self.queue.is_empty() {
                let idx = self.opts.policy.select(&self.queue, self.epoch);
                // selection orders by explicit (priority, key, arrival)
                // tuples, so container order is irrelevant: O(1) extraction
                let q = self.queue.swap_remove(idx);
                let mut req = q.req;
                stats.requests += 1;
                req.max_new = req.max_new.min(max_seq.saturating_sub(req.prompt.len()));
                let verdict = if cancelled.contains(&req.id) {
                    Some(FinishReason::Cancelled)
                } else if q.deadline_at.is_some_and(|d| Instant::now() >= d) {
                    // the deadline expired while the request sat in the
                    // queue: finish it here, before the slot construction
                    // below allocates any KV pages — decoding tokens nobody
                    // is waiting for would only starve live requests
                    Some(FinishReason::TimedOut)
                } else if req.prompt.is_empty() {
                    Some(FinishReason::Rejected(format!("request {}: empty prompt", req.id)))
                } else if req.prompt.len() >= max_seq {
                    Some(FinishReason::Rejected(format!(
                        "request {}: prompt length {} must leave room to generate \
                         within max_seq {}",
                        req.id,
                        req.prompt.len(),
                        max_seq
                    )))
                } else if let Some(&bad) =
                    req.prompt.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab)
                {
                    // A wild token id would otherwise reach the embedding
                    // row lookup inside the parallel prefill and abort the
                    // whole batch (negative ids wrap to huge row indices
                    // through `as usize`).
                    Some(FinishReason::Rejected(format!(
                        "request {}: prompt token {} outside vocab 0..{}",
                        req.id, bad, cfg.vocab
                    )))
                } else if req.max_new == 0 {
                    Some(FinishReason::Length)
                } else {
                    None
                };
                if let Some(reason) = verdict {
                    let id = req.id;
                    finish_unstarted(&mut done, &mut self.metrics, &mut stats, req, reason);
                    self.cancel.clear_id(id);
                    continue;
                }
                let stop = StopCondition {
                    tokens: std::mem::take(&mut req.stop),
                    sequences: std::mem::take(&mut req.stop_seqs),
                };
                let rng = Pcg64::with_stream(self.opts.seed, req.id as u64);
                trace::mark("serve", "admit", req.id as u64);
                let now = Instant::now();
                admitted.push(Slot {
                    req,
                    cache: KvCache::with_dtype(cfg, self.opts.kv_dtype),
                    stop,
                    generated: Vec::new(),
                    last: 0,
                    rng,
                    reused: 0,
                    draft_cache: match self.draft {
                        Some(d) if self.opts.spec > 0 => {
                            Some(KvCache::with_dtype(d.config(), self.opts.kv_dtype))
                        }
                        _ => None,
                    },
                    spec_round: None,
                    finish: None,
                    submitted_at: q.submitted_at,
                    admitted_at: now,
                    last_token_at: now,
                    first_token_at: None,
                    decode_rounds: 0,
                    round_elapsed: Duration::ZERO,
                    ttft: None,
                    itl_pending: None,
                });
            }

            // -- prefill the admitted prompts (once each) --------------------
            let admitted_any = !admitted.is_empty();
            if admitted_any {
                let t0 = Instant::now();
                let _prefill_span = trace::span("serve", "prefill_batch", round);
                if let Some(pc) = prefix.as_mut() {
                    // 1. look up each prompt against the trie (sequential,
                    //    cheap — forks share pages, no forward pass)
                    for s in admitted.iter_mut() {
                        if let Some((hit, fork)) = pc.lookup(&s.req.prompt) {
                            s.cache = fork;
                            s.reused = hit;
                        }
                    }
                    // 2. prefill data-parallel ACROSS groups of prompts
                    //    sorted lexicographically: within a group each slot
                    //    chains off its sorted predecessor's cache (sorted
                    //    neighbors maximize common prefixes), which captures
                    //    same-round sharing without serializing unrelated
                    //    prompts behind one another.  Forks are bit-identical
                    //    to recomputation, so outputs don't depend on the
                    //    grouping.
                    admitted.sort_by(|a, b| {
                        (&a.req.prompt, a.req.id).cmp(&(&b.req.prompt, b.req.id))
                    });
                    let mut groups: Vec<Vec<Slot>> = Vec::new();
                    for s in admitted.drain(..) {
                        match groups.last_mut() {
                            Some(g) if g[0].req.prompt[0] == s.req.prompt[0] => g.push(s),
                            _ => groups.push(vec![s]),
                        }
                    }
                    let threads = pool::num_threads().min(groups.len());
                    pool::parallel_chunks_mut(&mut groups, 1, threads, |_i, chunk| {
                        let group = &mut chunk[0];
                        for j in 0..group.len() {
                            if j > 0 {
                                let (prev, cur) = group.split_at_mut(j);
                                let p = &prev[j - 1];
                                let s = &mut cur[0];
                                let lcp = common_prefix(&p.req.prompt, &s.req.prompt)
                                    .min(s.req.prompt.len() - 1);
                                if lcp > s.cache.len() {
                                    s.cache = p.cache.fork_at(lcp);
                                    s.reused = lcp;
                                }
                            }
                            let s = &mut group[j];
                            let start = s.cache.len();
                            let logits = native::forward_cached(
                                params,
                                &mut s.cache,
                                &s.req.prompt[start..],
                            );
                            s.push_token(&logits);
                        }
                    });
                    for g in &mut groups {
                        admitted.append(g);
                    }
                    // 3. account reuse and publish the prefilled prompts
                    for s in admitted.iter() {
                        stats.prefill_tokens += s.req.prompt.len() - s.reused;
                        stats.prefix_hit_tokens += s.reused;
                        self.metrics.prefix_lookups += 1;
                        if s.reused > 0 {
                            self.metrics.prefix_hits += 1;
                            self.metrics.prefix_hit_tokens += s.reused as u64;
                        }
                        pc.insert(&s.req.prompt, &s.cache);
                    }
                    pc.enforce_budget();
                } else {
                    stats.prefill_tokens +=
                        admitted.iter().map(|s| s.req.prompt.len()).sum::<usize>();
                    let threads = pool::num_threads().min(admitted.len());
                    pool::parallel_chunks_mut(&mut admitted, 1, threads, |_i, slot| {
                        let s = &mut slot[0];
                        let logits = native::forward_cached(params, &mut s.cache, &s.req.prompt);
                        s.push_token(&logits);
                    });
                }
                stats.prefill_time += t0.elapsed();
                stats.generated_tokens += admitted.len();
                for s in &mut admitted {
                    if let Some(d) = s.ttft.take() {
                        self.metrics.ttft.record(d);
                    }
                }
                active.append(&mut admitted);
            }

            // -- live-KV gauge: unique pages over slots + prefix trie --------
            // Sampled on admission rounds (where peaks form) plus every 16th
            // decode round, so the unique-page walk doesn't tax every token
            // round and skew the latency histograms it sits next to.
            if admitted_any || round % 16 == 0 {
                let mut seen: HashSet<usize> = HashSet::new();
                let mut live = 0usize;
                for s in &active {
                    // draft KV pages are full-width like the target's, at
                    // the same kv_dtype (only the draft's *weights* are
                    // cheap), so they count toward residency equally
                    let draft_pages = s.draft_cache.iter().flat_map(|dc| dc.page_refs());
                    for (ptr, b) in s.cache.page_refs().chain(draft_pages) {
                        if seen.insert(ptr) {
                            live += b;
                        }
                    }
                }
                if let Some(pc) = prefix.as_ref() {
                    live += pc.add_unique_bytes(&mut seen);
                }
                let draft_eager = match self.draft {
                    Some(d) if self.opts.spec > 0 => KvCache::eager_bytes(d.config()),
                    _ => 0,
                };
                let eager_per_slot = KvCache::eager_bytes(cfg) + draft_eager;
                self.metrics.record_kv_bytes(live, active.len() * eager_per_slot);
            }

            // -- retire finished sequences (frees admission slots) -----------
            let mut i = 0;
            while i < active.len() {
                let reason = if let Some(r) = active[i].finish.clone() {
                    Some(r)
                } else if active[i].generated.len() >= active[i].req.max_new {
                    Some(FinishReason::Length)
                } else if cancelled.contains(&active[i].req.id) {
                    Some(FinishReason::Cancelled)
                } else {
                    None
                };
                let Some(reason) = reason else {
                    i += 1;
                    continue;
                };
                let mut s = active.swap_remove(i);
                match &reason {
                    FinishReason::Length => self.metrics.finished_length += 1,
                    FinishReason::Stop => self.metrics.finished_stop += 1,
                    FinishReason::Cancelled => {
                        self.metrics.cancelled += 1;
                        stats.cancelled += 1;
                    }
                    FinishReason::TimedOut => {
                        self.metrics.timed_out += 1;
                        stats.timed_out += 1;
                    }
                    FinishReason::Failed(_) => {
                        self.metrics.failed += 1;
                        stats.failed += 1;
                    }
                    FinishReason::Rejected(_) => {}
                }
                if let Some(sink) = s.req.sink.as_mut() {
                    sink.on_finish(&reason);
                }
                // a finished request's pending cancellation (if any) is
                // consumed with it — the set never grows unboundedly and a
                // later request reusing the id is unaffected
                self.cancel.clear_id(s.req.id);
                let timing = s.retire(&mut self.metrics);
                done.push(Completion {
                    id: s.req.id,
                    prompt: std::mem::take(&mut s.req.prompt),
                    generated: std::mem::take(&mut s.generated),
                    finish: reason,
                    timing,
                });
            }
            if active.is_empty() {
                continue; // admit more, or fall out when the queue is dry
            }

            // -- one decode round: every active sequence advances — one token
            //    plain, up to spec+1 tokens speculative (draft + chunked
            //    verify; bit-identical completions either way) ---------------
            let t0 = Instant::now();
            let threads = pool::num_threads().min(active.len());
            let (spec_k, draft) = (self.opts.spec, self.draft);
            let fault = self.fault.clone();
            {
                let _round_span = trace::span("serve", "decode_round", round);
                pool::parallel_chunks_mut(&mut active, 1, threads, |_i, slot| {
                    let s = &mut slot[0];
                    let t_slot = Instant::now();
                    if let Some(fi) = &fault {
                        // an injected stall lands inside the measured
                        // window, exactly like a genuinely wedged kernel
                        fi.maybe_stall(s.req.id, round);
                    }
                    s.decode_rounds += 1;
                    match draft {
                        Some(d) if spec_k > 0 => advance_speculative(params, d, s, spec_k),
                        _ => {
                            let logits = native::decode_step(params, &mut s.cache, s.last);
                            s.push_token(&logits);
                        }
                    }
                    s.round_elapsed = t_slot.elapsed();
                });
            }
            stats.decode_time += t0.elapsed();
            stats.decode_steps += 1;
            if let Some(budget_ms) = self.opts.round_budget_ms {
                // a slot that blew the wall-clock budget retires Failed at
                // the next round boundary instead of wedging the batch;
                // a stop-condition finish from this same round wins — the
                // request's output is already complete
                let budget = Duration::from_millis(budget_ms);
                for s in &mut active {
                    if s.finish.is_none() && s.round_elapsed > budget {
                        s.finish = Some(FinishReason::Failed(format!(
                            "request {}: decode round {round} took {} ms, over the \
                             {budget_ms} ms round budget",
                            s.req.id,
                            s.round_elapsed.as_millis()
                        )));
                    }
                }
            }
            let mut round_tokens = 0usize;
            for s in &mut active {
                match s.spec_round.take() {
                    Some(r) => {
                        // every round commits its matched drafts plus one
                        // correction/bonus sample — ServeStats' and
                        // ServeMetrics' tokens/verify derivations both
                        // lean on this coupling
                        debug_assert_eq!(r.committed, r.matched + 1);
                        round_tokens += r.committed;
                        stats.draft_tokens += r.drafted;
                        stats.spec_matched += r.matched;
                        if r.drafted > 0 {
                            stats.verify_chunks += 1;
                            self.metrics.record_spec_round(&r);
                        }
                    }
                    None => round_tokens += 1,
                }
                if let Some(d) = s.itl_pending.take() {
                    self.metrics.inter_token.record(d);
                }
            }
            stats.decoded_tokens += round_tokens;
            stats.generated_tokens += round_tokens;
        }

        // lookups/hits/hit_tokens accumulate in the prefill phase (they
        // include same-round chaining the trie's own stats can't see);
        // evictions only happen inside the trie
        if let Some(pc) = &prefix {
            self.metrics.prefix_evictions = pc.stats().evictions;
        }
        self.prefix = prefix;
        // the queue is drained, so any cancellation left in the registry
        // matches nothing — drop them so a cancel racing a request's
        // completion can never leak into a later request reusing the id
        self.cancel.clear_all();
        done.sort_by_key(|c| c.id);
        (done, stats)
    }
}

/// Length of the shared leading run of two token sequences.
fn common_prefix(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// One speculative decode round for one slot: the draft proposes up to `k`
/// tokens, the target verifies the pending token plus every draft in a
/// single chunked forward ([`native::forward_chunk`] — one weight pass for
/// the whole chunk), and the verify logits are re-sampled **sequentially**
/// with the request's own sampler and RNG stream.  Tokens commit while they
/// agree with the draft; the first disagreement's sample is itself the
/// token plain decoding would have emitted, so completions — greedy or
/// stochastic — are bit-identical to speculation off (row `i` of the chunk
/// logits is bit-identical to the i-th sequential `decode_step`, and the
/// RNG is consumed once per committed token in both worlds).  The rejected
/// suffix rolls back through [`KvCache::truncate`] on both caches.
fn advance_speculative<P: DecoderParams + ?Sized>(
    params: &P,
    draft: &dyn DecoderParams,
    s: &mut Slot,
    k: usize,
) {
    let n0 = s.cache.len();
    let remaining_new = s.req.max_new - s.generated.len();
    let k = spec::clamp_k(k, remaining_new, s.cache.remaining());
    if k == 0 {
        // no draft budget (last token of the request, or context exhausted
        // past the pending token): plain decode step
        let logits = native::decode_step(params, &mut s.cache, s.last);
        s.push_token(&logits);
        s.spec_round = Some(SpecRound { drafted: 0, matched: 0, committed: 1 });
        return;
    }

    // 1. the draft greedily proposes k tokens continuing prompt + generated,
    //    catching its cache up first.  Only the gap past the draft cache is
    //    materialized: the whole prompt on the slot's first speculative
    //    round, 1-2 tokens on steady-state rounds — never the full stream.
    let dc_len = s.draft_cache.as_ref().map_or(0, KvCache::len);
    let prompt = &s.req.prompt;
    let gap: Vec<i32> = if dc_len < prompt.len() {
        prompt[dc_len..].iter().chain(s.generated.iter()).copied().collect()
    } else {
        s.generated[dc_len - prompt.len()..].to_vec()
    };
    // PANIC-OK: draft_cache is Some for every slot that reaches this
    // function — advance_speculative is only called when a draft model is
    // attached, and admission creates the draft cache alongside the slot.
    let dc = s.draft_cache.as_mut().expect("speculative slot has a draft cache");
    let drafts = spec::propose(draft, dc, &gap, k);

    // 2. the target verifies pending token + drafts in one chunked forward
    let _verify_span = trace::span("serve", "verify", s.req.id as u64);
    let mut chunk = vec![s.last];
    chunk.extend(&drafts);
    let logits = native::forward_chunk(params, &mut s.cache, &chunk);

    // 3. sequential acceptance through the slot's sampler/RNG
    let prev_token_at = s.last_token_at;
    let mut committed_n = 0;
    let mut matched = 0;
    for i in 0..=k {
        s.push_token(logits.row(i));
        committed_n += 1;
        if s.finish.is_some() || s.generated.len() >= s.req.max_new {
            break;
        }
        if i < k {
            if s.last != drafts[i] {
                break;
            }
            matched += 1;
        }
    }
    // telemetry: the intra-chunk gaps push_token measured are meaningless
    // (every committed token materialized in the one verify forward) —
    // report the round's wall-clock gap amortized per committed token
    s.itl_pending = Some(s.last_token_at.duration_since(prev_token_at) / committed_n as u32);

    // 4. roll back the rejected suffix: the target keeps exactly the fed
    //    prefix backing the committed tokens, the draft whatever prefix of
    //    it the drafting pass already holds
    s.cache.truncate(n0 + committed_n);
    // PANIC-OK: same invariant as the propose step above — draft_cache is
    // Some for the lifetime of a speculative slot.
    let dc = s.draft_cache.as_mut().expect("speculative slot has a draft cache");
    let keep = dc.len().min(n0 + committed_n);
    dc.truncate(keep);
    s.spec_round = Some(SpecRound { drafted: k, matched, committed: committed_n });
}

/// Finish a request that never reached a decode slot (rejection,
/// cancellation while queued, or `max_new == 0`).
fn finish_unstarted(
    done: &mut Vec<Completion>,
    metrics: &mut ServeMetrics,
    stats: &mut ServeStats,
    mut req: Request,
    reason: FinishReason,
) {
    match &reason {
        FinishReason::Cancelled => {
            metrics.cancelled += 1;
            stats.cancelled += 1;
        }
        FinishReason::Rejected(_) => {
            metrics.rejected += 1;
            stats.rejected += 1;
        }
        FinishReason::TimedOut => {
            metrics.timed_out += 1;
            stats.timed_out += 1;
            crate::obs::fault::record_fault(crate::obs::fault::FaultEvent::RequestTimedOut);
        }
        FinishReason::Failed(_) => {
            metrics.failed += 1;
            stats.failed += 1;
        }
        FinishReason::Length => metrics.finished_length += 1,
        FinishReason::Stop => metrics.finished_stop += 1,
    }
    if let Some(sink) = req.sink.as_mut() {
        sink.on_finish(&reason);
    }
    done.push(Completion {
        timing: RequestTiming::default(),
        id: req.id,
        prompt: std::mem::take(&mut req.prompt),
        generated: Vec::new(),
        finish: reason,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OptConfig, Weights};
    use crate::serve::stream::{ChannelSink, FnSink, StreamEvent};
    use crate::util::propcheck;
    use crate::util::sampling::Sampler;

    fn test_weights() -> Weights {
        Weights::random(OptConfig::test_config(), 3)
    }

    /// One layer, 96-position context: room for 64-token shared prefixes.
    fn wide_config() -> OptConfig {
        OptConfig {
            name: "serve-test".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ffn: 32,
            max_seq: 96,
        }
    }

    fn requests(n: usize, vocab: usize) -> Vec<Request> {
        let mut rng = Pcg64::new(5);
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    (0..4 + i % 3).map(|_| rng.below(vocab) as i32).collect(),
                    3 + i % 4,
                    if i % 2 == 0 {
                        Sampler::Greedy
                    } else {
                        Sampler::TopK { k: 4, temperature: 0.9 }
                    },
                )
            })
            .collect()
    }

    // -- legacy Server behavior (PR 2), now on the scheduler ----------------

    #[test]
    fn serves_all_requests_to_completion() {
        let w = test_weights();
        let mut server = Scheduler::new(&w, ServeOpts { max_batch: 2, ..Default::default() });
        for r in requests(5, w.config.vocab) {
            server.submit(r);
        }
        assert_eq!(server.pending(), 5);
        let (done, stats) = server.run();
        assert_eq!(done.len(), 5);
        assert_eq!(stats.requests, 5);
        let total: usize = done.iter().map(|c| c.generated.len()).sum();
        assert_eq!(stats.generated_tokens, total);
        // every request samples exactly one token at prefill time
        assert_eq!(stats.decoded_tokens, total - 5);
        for c in &done {
            assert_eq!(c.generated.len(), 3 + c.id % 4);
            assert_eq!(c.finish, FinishReason::Length);
            assert!(c.generated.iter().all(|&t| (t as usize) < w.config.vocab));
        }
    }

    #[test]
    fn max_new_clamped_to_context() {
        let w = test_weights();
        let max_seq = w.config.max_seq;
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(Request::new(0, vec![1; max_seq - 2], 100, Sampler::Greedy));
        let (done, _) = s.run();
        assert_eq!(done[0].generated.len(), 2);
        assert_eq!(done[0].finish, FinishReason::Length);
    }

    #[test]
    fn zero_max_new_completes_without_decoding() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(Request::new(7, vec![1, 2, 3], 0, Sampler::Greedy));
        let (done, stats) = s.run();
        assert_eq!(done.len(), 1);
        assert!(done[0].generated.is_empty());
        assert_eq!(stats.decode_steps, 0);
        assert_eq!(stats.decoded_tokens, 0);
        assert_eq!(stats.generated_tokens, 0);
    }

    // -- satellite: per-request rejection instead of batch abort ------------

    #[test]
    fn bad_requests_reject_without_aborting_the_batch() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 2, ..Default::default() });
        s.submit(Request::new(0, vec![], 3, Sampler::Greedy)); // empty prompt
        s.submit(Request::new(1, vec![1, 2, 3], 3, Sampler::Greedy)); // fine
        s.submit(Request::new(2, vec![0; w.config.max_seq], 3, Sampler::Greedy)); // too long
        s.submit(Request::new(3, vec![4, 5], 2, Sampler::Greedy)); // fine
        let (done, stats) = s.run();
        assert_eq!(done.len(), 4, "every request yields a completion");
        assert_eq!(stats.rejected, 2);
        match &done[0].finish {
            FinishReason::Rejected(msg) => assert!(msg.contains("empty prompt"), "{msg}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        match &done[2].finish {
            FinishReason::Rejected(msg) => assert!(msg.contains("max_seq"), "{msg}"),
            other => panic!("expected rejection, got {other:?}"),
        }
        // the good requests ran to completion despite the bad ones
        assert_eq!(done[1].finish, FinishReason::Length);
        assert_eq!(done[1].generated.len(), 3);
        assert_eq!(done[3].generated.len(), 2);
    }

    #[test]
    fn out_of_vocab_prompt_rejects_without_aborting_the_batch() {
        // Regression (found by the xtask panic-path triage): a prompt token
        // outside the vocab used to reach the embedding row lookup inside
        // the parallel prefill and panic the whole batch — negative ids
        // wrap to huge row indices through `as usize`.
        let w = test_weights();
        let vocab = w.config.vocab as i32;
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 2, ..Default::default() });
        s.submit(Request::new(0, vec![1, vocab, 2], 3, Sampler::Greedy)); // id == vocab
        s.submit(Request::new(1, vec![1, 2, 3], 3, Sampler::Greedy)); // fine
        s.submit(Request::new(2, vec![1, -4, 2], 3, Sampler::Greedy)); // negative id
        let (done, stats) = s.run();
        assert_eq!(done.len(), 3, "every request yields a completion");
        assert_eq!(stats.rejected, 2);
        for bad in [0, 2] {
            match &done[bad].finish {
                FinishReason::Rejected(msg) => {
                    assert!(msg.contains("outside vocab"), "{msg}")
                }
                other => panic!("expected rejection, got {other:?}"),
            }
        }
        assert_eq!(done[1].finish, FinishReason::Length);
        assert_eq!(done[1].generated.len(), 3);
    }

    #[test]
    fn cancel_handle_survives_a_poisoned_lock() {
        // Regression companion to the CancelHandle poison-recovery change:
        // a panic on a thread holding the registry lock must not cascade
        // into every later cancel/is_cancelled call.
        let h = CancelHandle::default();
        h.cancel(1);
        let h2 = h.clone();
        let _ = std::thread::spawn(move || {
            let _guard = h2.ids.lock().unwrap();
            panic!("poison the registry lock");
        })
        .join();
        h.cancel(2); // must not panic
        assert!(h.is_cancelled(1));
        assert!(h.is_cancelled(2));
        h.clear_all();
        assert!(!h.is_cancelled(2));
    }

    // -- satellite: stop tokens / stop sequences ----------------------------

    #[test]
    fn stop_token_terminates_decode() {
        let w = test_weights();
        let free = {
            let mut s = Scheduler::new(&w, ServeOpts::default());
            s.submit(Request::new(0, vec![1, 2, 3, 4], 8, Sampler::Greedy));
            s.run().0.remove(0).generated
        };
        assert_eq!(free.len(), 8, "unconstrained greedy runs to max_new");
        let stop_tok = free[2];
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(Request::new(0, vec![1, 2, 3, 4], 8, Sampler::Greedy).with_stop(vec![stop_tok]));
        let (done, _) = s.run();
        // greedy replays the same stream, so it stops at the first
        // occurrence of the stop token (which is included in the output)
        let expected = free.iter().position(|&t| t == stop_tok).unwrap() + 1;
        assert_eq!(done[0].generated, free[..expected].to_vec());
        assert_eq!(done[0].finish, FinishReason::Stop);
    }

    #[test]
    fn stop_token_sampled_at_prefill_time() {
        let w = test_weights();
        let first = {
            let mut s = Scheduler::new(&w, ServeOpts::default());
            s.submit(Request::new(0, vec![3, 1, 4], 6, Sampler::Greedy));
            s.run().0.remove(0).generated[0]
        };
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(Request::new(0, vec![3, 1, 4], 6, Sampler::Greedy).with_stop(vec![first]));
        let (done, stats) = s.run();
        assert_eq!(done[0].generated, vec![first]);
        assert_eq!(done[0].finish, FinishReason::Stop);
        assert_eq!(stats.decode_steps, 0, "stop hit at prefill: no decode rounds run");
        assert_eq!(stats.decoded_tokens, 0);
    }

    #[test]
    fn stop_sequence_terminates_decode() {
        let w = test_weights();
        let free = {
            let mut s = Scheduler::new(&w, ServeOpts::default());
            s.submit(Request::new(0, vec![2, 7, 1], 8, Sampler::Greedy));
            s.run().0.remove(0).generated
        };
        let stop_seq = free[1..3].to_vec();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(
            Request::new(0, vec![2, 7, 1], 8, Sampler::Greedy)
                .with_stop_seqs(vec![stop_seq.clone()]),
        );
        let (done, _) = s.run();
        let pos = free.windows(2).position(|win| win == &stop_seq[..]).unwrap();
        assert_eq!(done[0].generated.len(), pos + 2);
        assert_eq!(done[0].finish, FinishReason::Stop);
    }

    // -- cancellation -------------------------------------------------------

    #[test]
    fn queued_request_cancelled_before_admission() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 1, ..Default::default() });
        s.submit(Request::new(0, vec![1, 2], 2, Sampler::Greedy));
        s.submit(Request::new(1, vec![3, 4], 2, Sampler::Greedy));
        s.cancel(1);
        let (done, stats) = s.run();
        assert_eq!(done[0].finish, FinishReason::Length);
        assert_eq!(done[1].finish, FinishReason::Cancelled);
        assert!(done[1].generated.is_empty());
        assert_eq!(stats.cancelled, 1);
    }

    #[test]
    fn cancel_mid_flight_from_streaming_sink() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        let handle = s.cancel_handle();
        let sink = FnSink(move |_tok: i32, idx: usize| {
            if idx == 2 {
                handle.cancel(0);
            }
        });
        s.submit(Request::new(0, vec![1, 2, 3], 20, Sampler::Greedy).with_sink(Box::new(sink)));
        let (done, stats) = s.run();
        assert_eq!(done[0].finish, FinishReason::Cancelled);
        assert!(
            done[0].generated.len() >= 3 && done[0].generated.len() < 20,
            "cancelled mid-flight after {} tokens",
            done[0].generated.len()
        );
        assert_eq!(stats.cancelled, 1);
    }

    // -- streaming ----------------------------------------------------------

    #[test]
    fn streaming_sink_receives_tokens_then_finish() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        let (sink, rx) = ChannelSink::new();
        s.submit(Request::new(0, vec![5, 6, 7], 4, Sampler::Greedy).with_sink(Box::new(sink)));
        let (done, _) = s.run();
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 5, "4 tokens + finish");
        let streamed: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                StreamEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(streamed, done[0].generated);
        assert_eq!(events.last(), Some(&StreamEvent::Finish(FinishReason::Length)));
        for (i, e) in events[..4].iter().enumerate() {
            assert!(matches!(e, StreamEvent::Token { index, .. } if *index == i));
        }
    }

    #[test]
    fn rejected_request_still_notifies_its_sink() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        let (sink, rx) = ChannelSink::new();
        s.submit(Request::new(0, vec![], 4, Sampler::Greedy).with_sink(Box::new(sink)));
        let (done, _) = s.run();
        assert!(matches!(done[0].finish, FinishReason::Rejected(_)));
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1, "no tokens, just the finish event");
        assert!(matches!(events[0], StreamEvent::Finish(FinishReason::Rejected(_))));
    }

    // -- admission policies -------------------------------------------------

    #[test]
    fn admission_policies_order_the_queue() {
        let w = test_weights();
        let order: Arc<Mutex<Vec<usize>>> = Arc::default();
        let mk = |id: usize, plen: usize, order: &Arc<Mutex<Vec<usize>>>| {
            let o = order.clone();
            Request::new(id, vec![1; plen], 1, Sampler::Greedy).with_sink(Box::new(FnSink(
                move |_t: i32, idx: usize| {
                    if idx == 0 {
                        o.lock().unwrap().push(id);
                    }
                },
            )))
        };

        // shortest-prompt-first admits by prompt length, not arrival
        let spf = AdmissionPolicy::ShortestPrompt;
        let mut s =
            Scheduler::new(&w, ServeOpts { max_batch: 1, policy: spf, ..Default::default() });
        s.submit(mk(0, 8, &order));
        s.submit(mk(1, 2, &order));
        s.submit(mk(2, 5, &order));
        s.run();
        assert_eq!(*order.lock().unwrap(), vec![1, 2, 0]);

        // earliest deadline first; no deadline goes last
        order.lock().unwrap().clear();
        let mut s = Scheduler::new(
            &w,
            ServeOpts { max_batch: 1, policy: AdmissionPolicy::Deadline, ..Default::default() },
        );
        s.submit(mk(0, 3, &order));
        s.submit(mk(1, 3, &order).with_deadline_ms(5000));
        s.submit(mk(2, 3, &order).with_deadline_ms(10));
        s.run();
        assert_eq!(*order.lock().unwrap(), vec![2, 1, 0]);

        // priority beats arrival under every policy
        order.lock().unwrap().clear();
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 1, ..Default::default() });
        s.submit(mk(0, 3, &order));
        s.submit(mk(1, 3, &order).with_priority(-1));
        s.run();
        assert_eq!(*order.lock().unwrap(), vec![1, 0]);
    }

    #[test]
    fn policy_parse_forms() {
        assert_eq!(AdmissionPolicy::parse("fcfs").unwrap(), AdmissionPolicy::Fcfs);
        assert_eq!(AdmissionPolicy::parse("SPF").unwrap(), AdmissionPolicy::ShortestPrompt);
        assert_eq!(AdmissionPolicy::parse("deadline").unwrap(), AdmissionPolicy::Deadline);
        assert!(AdmissionPolicy::parse("lifo").is_err());
    }

    // -- fault tolerance: deadline expiry and round budgets -----------------

    #[test]
    fn expired_deadline_times_out_before_any_kv_allocation() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        let (sink, rx) = ChannelSink::new();
        // deadline 0 ms: expired by the time admission first looks at it
        s.submit(
            Request::new(0, vec![1, 2, 3], 4, Sampler::Greedy)
                .with_deadline_ms(0)
                .with_sink(Box::new(sink)),
        );
        let (done, stats) = s.run();
        assert_eq!(done[0].finish, FinishReason::TimedOut);
        assert!(done[0].generated.is_empty());
        assert_eq!(stats.timed_out, 1);
        assert_eq!(stats.prefill_tokens, 0, "timed out before prefill ever ran");
        assert_eq!(stats.decode_steps, 0);
        let m = s.metrics();
        assert_eq!(m.timed_out, 1);
        assert_eq!(m.kv_live_bytes_peak, 0, "no KV pages were allocated for it");
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(events, vec![StreamEvent::Finish(FinishReason::TimedOut)]);

        // a live request sharing the queue is untouched by the expiry
        let reference = {
            let mut solo = Scheduler::new(&w, ServeOpts::default());
            solo.submit(Request::new(1, vec![4, 5], 3, Sampler::Greedy));
            solo.run().0.remove(0)
        };
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.submit(Request::new(0, vec![1, 2, 3], 4, Sampler::Greedy).with_deadline_ms(0));
        s.submit(Request::new(1, vec![4, 5], 3, Sampler::Greedy));
        let (done, stats) = s.run();
        assert_eq!(stats.timed_out, 1);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1], reference, "the live neighbor must decode unperturbed");
    }

    #[test]
    fn round_budget_converts_a_stalled_slot_to_failed() {
        let w = test_weights();
        let reference = {
            let mut s = Scheduler::new(&w, ServeOpts::default());
            s.submit(Request::new(1, vec![2, 3, 4], 3, Sampler::Greedy));
            s.run().0.remove(0)
        };
        // request 0's decode sleeps 120 ms at round 1; a 30 ms budget
        // converts the blown round into a Failed finish at the boundary
        // (margins are wide on both sides so a noisy CI box can't flip
        // either slot's verdict)
        let plan = crate::serve::fault::FaultPlan::parse("stall=0@1x120").unwrap();
        let mut s = Scheduler::new(
            &w,
            ServeOpts { round_budget_ms: Some(30), ..Default::default() },
        );
        s.set_fault(plan.injector_for(0));
        s.submit(Request::new(0, vec![1, 2, 3], 4, Sampler::Greedy));
        s.submit(Request::new(1, vec![2, 3, 4], 3, Sampler::Greedy));
        let (done, stats) = s.run();
        assert_eq!(done.len(), 2);
        match &done[0].finish {
            FinishReason::Failed(msg) => {
                assert!(msg.contains("round budget"), "{msg}");
            }
            other => panic!("expected Failed for the stalled slot, got {other:?}"),
        }
        assert_eq!(stats.failed, 1);
        assert_eq!(s.metrics().failed, 1);
        assert_eq!(done[1], reference, "the unstalled neighbor must decode unperturbed");

        // without a budget the same stall only slows the round down
        let plan = crate::serve::fault::FaultPlan::parse("stall=0@1x1").unwrap();
        let mut s = Scheduler::new(&w, ServeOpts::default());
        s.set_fault(plan.injector_for(0));
        s.submit(Request::new(0, vec![1, 2, 3], 4, Sampler::Greedy));
        let (done, stats) = s.run();
        assert_eq!(done[0].finish, FinishReason::Length);
        assert_eq!(done[0].generated.len(), 4);
        assert_eq!(stats.failed, 0);
    }

    // -- determinism pins (acceptance) --------------------------------------

    fn mixed_specs(vocab: usize) -> Vec<(usize, Vec<i32>, usize)> {
        let mut rng = Pcg64::new(5);
        let shared: Vec<i32> = (0..6).map(|_| rng.below(vocab) as i32).collect();
        (0..8)
            .map(|i| {
                let mut prompt = if i % 2 == 0 { shared.clone() } else { Vec::new() };
                prompt.extend((0..3 + i % 4).map(|_| rng.below(vocab) as i32));
                (i, prompt, 3 + i % 5)
            })
            .collect()
    }

    #[test]
    fn completions_invariant_to_batch_policy_and_prefix() {
        let w = test_weights();
        let run = |max_batch: usize, policy: AdmissionPolicy, prefix_cache: bool| {
            let mut s = Scheduler::new(
                &w,
                ServeOpts { max_batch, policy, prefix_cache, seed: 42, ..Default::default() },
            );
            for (id, prompt, max_new) in mixed_specs(w.config.vocab) {
                let sampler = if id % 2 == 0 {
                    Sampler::Greedy
                } else {
                    Sampler::TopK { k: 4, temperature: 0.9 }
                };
                let mut r = Request::new(id, prompt, max_new, sampler);
                if id == 3 {
                    r = r.with_stop(vec![11]);
                }
                if id == 5 {
                    r = r.with_deadline_ms(1000).with_priority(1);
                }
                s.submit(r);
            }
            let (done, _) = s.run();
            done.into_iter().map(|c| (c.id, c.generated, c.finish)).collect::<Vec<_>>()
        };
        let reference = run(1, AdmissionPolicy::Fcfs, false);
        for (mb, pol, pc) in [
            (4, AdmissionPolicy::Fcfs, false),
            (1, AdmissionPolicy::ShortestPrompt, false),
            (4, AdmissionPolicy::ShortestPrompt, false),
            (4, AdmissionPolicy::Fcfs, true),
            (4, AdmissionPolicy::ShortestPrompt, true),
            (4, AdmissionPolicy::Deadline, true),
        ] {
            assert_eq!(reference, run(mb, pol, pc), "max_batch {mb}, {pol:?}, prefix {pc}");
        }
    }

    // -- speculative decoding (tentpole) ------------------------------------

    /// Run `mixed_specs` traffic through a scheduler, optionally with a
    /// draft model attached and speculation on.
    fn run_mixed(
        w: &Weights,
        draft: Option<&dyn DecoderParams>,
        spec: usize,
        max_batch: usize,
        policy: AdmissionPolicy,
        prefix_cache: bool,
    ) -> (Vec<(usize, Vec<i32>, FinishReason)>, crate::serve::ServeStats) {
        let mut s = Scheduler::new(
            w,
            ServeOpts { max_batch, policy, prefix_cache, seed: 42, spec, ..Default::default() },
        );
        if let Some(d) = draft {
            s = s.with_draft(d);
        }
        for (id, prompt, max_new) in mixed_specs(w.config.vocab) {
            let sampler = if id % 2 == 0 {
                Sampler::Greedy
            } else {
                Sampler::TopK { k: 4, temperature: 0.9 }
            };
            let mut r = Request::new(id, prompt, max_new, sampler);
            if id == 3 {
                r = r.with_stop(vec![11]);
            }
            if id == 5 {
                r = r.with_deadline_ms(1000).with_priority(1);
            }
            s.submit(r);
        }
        let (done, stats) = s.run();
        (done.into_iter().map(|c| (c.id, c.generated, c.finish)).collect(), stats)
    }

    #[test]
    fn speculative_completions_bit_identical_across_matrix() {
        // THE tentpole invariant: speculation is a pure perf optimization —
        // completions (greedy AND stochastic, with stop tokens, deadlines,
        // priorities in the mix) are bit-identical to speculation off across
        // batch size x admission policy x prefix cache, even under an
        // adversarial draft trained on nothing the target agrees with.
        let w = test_weights();
        let bad_draft = Weights::random(OptConfig::test_config(), 77);
        let good_draft = test_weights(); // same seed: agrees under greedy
        let reference = run_mixed(&w, None, 0, 1, AdmissionPolicy::Fcfs, false).0;
        for draft in [&bad_draft, &good_draft] {
            for spec in [1usize, 3] {
                for (mb, pol, pc) in [
                    (1, AdmissionPolicy::Fcfs, false),
                    (4, AdmissionPolicy::Fcfs, true),
                    (4, AdmissionPolicy::ShortestPrompt, false),
                    (4, AdmissionPolicy::Deadline, true),
                ] {
                    let (done, stats) = run_mixed(&w, Some(draft), spec, mb, pol, pc);
                    assert_eq!(
                        reference, done,
                        "spec {spec}, max_batch {mb}, {pol:?}, prefix {pc} diverged"
                    );
                    assert!(stats.verify_chunks > 0, "speculation must actually run");
                }
            }
        }
    }

    #[test]
    fn perfect_draft_reaches_full_acceptance() {
        // self-speculation's best case: the draft IS the target, so under
        // greedy sampling every proposal matches and each verify commits
        // k+1 tokens — rounds collapse accordingly
        let w = test_weights();
        let draft = test_weights();
        let submit = |s: &mut Scheduler<'_, Weights>| {
            for i in 0..3 {
                s.submit(Request::new(i, vec![1, 2 + i as i32, 3], 9, Sampler::Greedy));
            }
        };
        let mut plain = Scheduler::new(&w, ServeOpts { max_batch: 3, ..Default::default() });
        submit(&mut plain);
        let (plain_done, plain_stats) = plain.run();

        let opts = ServeOpts { max_batch: 3, spec: 3, ..Default::default() };
        let mut spec = Scheduler::new(&w, opts).with_draft(&draft);
        submit(&mut spec);
        let (spec_done, spec_stats) = spec.run();

        assert_eq!(plain_done, spec_done);
        assert_eq!(
            spec_stats.spec_matched, spec_stats.draft_tokens,
            "a perfect draft must never be rejected"
        );
        assert!((spec_stats.spec_accept_rate() - 1.0).abs() < 1e-12);
        assert!(
            spec_stats.decode_steps < plain_stats.decode_steps,
            "full acceptance must collapse decode rounds ({} vs {})",
            spec_stats.decode_steps,
            plain_stats.decode_steps
        );
        assert_eq!(plain_stats.generated_tokens, spec_stats.generated_tokens);
        assert_eq!(plain_stats.decoded_tokens, spec_stats.decoded_tokens);
    }

    #[test]
    fn spec_opt_without_draft_decodes_plainly() {
        // spec > 0 with no draft attached (or a draft with spec == 0) is
        // plain decoding, not an error
        let w = test_weights();
        let draft = test_weights();
        let run = |spec: usize, attach: bool| {
            let mut s = Scheduler::new(&w, ServeOpts { spec, ..Default::default() });
            if attach {
                s = s.with_draft(&draft);
            }
            s.submit(Request::new(0, vec![4, 5, 6], 5, Sampler::Greedy));
            s.run()
        };
        let (no_draft, stats) = run(4, false);
        assert_eq!(stats.verify_chunks, 0);
        let (with_draft_spec0, stats0) = run(0, true);
        assert_eq!(stats0.verify_chunks, 0);
        assert_eq!(no_draft[0].generated, with_draft_spec0[0].generated);
    }

    #[test]
    fn spec_metrics_track_acceptance() {
        let w = test_weights();
        let draft = Weights::random(OptConfig::test_config(), 31);
        let opts = ServeOpts { max_batch: 2, spec: 2, ..Default::default() };
        let mut s = Scheduler::new(&w, opts).with_draft(&draft);
        for i in 0..3 {
            s.submit(Request::new(i, vec![7, 8, 9, i as i32], 6, Sampler::Greedy));
        }
        let (done, stats) = s.run();
        assert_eq!(done.len(), 3);
        let m = s.metrics();
        assert_eq!(m.spec_accept_len.count() as usize, stats.verify_chunks);
        assert!(m.spec_tokens_per_verify() >= 1.0, "every verify commits at least one token");
        assert_eq!(m.spec_draft_tokens as usize, stats.draft_tokens);
        let j = m.to_json();
        let spec = j.get("speculative").unwrap();
        assert_eq!(spec.get("verify_steps").unwrap().as_usize(), Some(stats.verify_chunks));
        // committed tokens across verifies + plain fallback steps == decoded
        assert!(m.spec_committed_tokens as usize <= stats.decoded_tokens);
    }

    // -- satellite: prefix-cache property test ------------------------------

    #[test]
    fn prop_prefix_cache_is_transparent() {
        let w = test_weights();
        propcheck::check("prefix_cache_transparent", 12, |rng| {
            let vocab = w.config.vocab;
            let n = 2 + rng.below(5);
            let shared_len = 2 + rng.below(8);
            let shared: Vec<i32> = (0..shared_len).map(|_| rng.below(vocab) as i32).collect();
            let specs: Vec<(usize, Vec<i32>, usize)> = (0..n)
                .map(|i| {
                    let mut p = shared[..1 + rng.below(shared_len)].to_vec();
                    p.extend((0..rng.below(6)).map(|_| rng.below(vocab) as i32));
                    (i, p, 1 + rng.below(5))
                })
                .collect();
            let run = |prefix_cache: bool| {
                let mut s = Scheduler::new(
                    &w,
                    ServeOpts { max_batch: 3, seed: 9, prefix_cache, ..Default::default() },
                );
                for (id, p, m) in &specs {
                    s.submit(Request::new(
                        *id,
                        p.clone(),
                        *m,
                        Sampler::TopK { k: 6, temperature: 0.8 },
                    ));
                }
                let (done, _) = s.run();
                done.into_iter().map(|c| c.generated).collect::<Vec<_>>()
            };
            propcheck::ensure(run(true) == run(false), "prefix cache changed completions")
        });
    }

    // -- acceptance: shared prefixes skip prefill, chunked KV beats eager ---

    #[test]
    fn shared_prefix_prefills_fewer_tokens() {
        let w = Weights::random(wide_config(), 2);
        let shared: Vec<i32> = (0..64).map(|i| (i % 64) as i32).collect();
        let mk = |id: usize, tail: i32| {
            let mut p = shared.clone();
            p.extend([tail, tail + 1]);
            Request::new(id, p, 4, Sampler::Greedy)
        };
        let opts = ServeOpts { max_batch: 4, prefix_cache: true, ..Default::default() };
        let mut s = Scheduler::new(&w, opts);
        s.submit(mk(0, 1));
        s.submit(mk(1, 7));
        let (done, stats) = s.run();
        assert_eq!(done.len(), 2);
        let prompt_len = 66;
        assert!(
            stats.prefill_tokens < 2 * prompt_len,
            "sharing a 64-token prefix must prefill fewer than 2x prompt tokens \
             (prefilled {})",
            stats.prefill_tokens
        );
        assert_eq!(stats.prefill_tokens + stats.prefix_hit_tokens, 2 * prompt_len);
        assert_eq!(stats.prefix_hit_tokens, 64, "the whole shared prefix is reused");
        let m = s.metrics();
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefix_lookups, 2);
        assert_eq!(m.prefix_hit_tokens, 64);
        assert!(
            m.kv_live_bytes_peak < m.kv_eager_bytes_peak,
            "chunked KV ({} B) must stay under eager full-context KV ({} B)",
            m.kv_live_bytes_peak,
            m.kv_eager_bytes_peak
        );
        assert!(s.prefix_cache_bytes() > 0, "trie retains the shared pages");
    }

    #[test]
    fn metrics_populated_after_run() {
        let w = test_weights();
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 2, ..Default::default() });
        for i in 0..4 {
            s.submit(Request::new(i, vec![1, 2, 3, i as i32], 3, Sampler::Greedy));
        }
        let (done, _) = s.run();
        assert_eq!(done.len(), 4);
        let m = s.metrics();
        assert_eq!(m.ttft.count(), 4);
        assert!(m.inter_token.count() > 0);
        assert_eq!(m.finished_length, 4);
        assert!(m.kv_live_bytes_peak > 0);
        assert!(
            m.kv_live_bytes_peak < m.kv_eager_bytes_peak,
            "short sequences resident in chunked pages beat eager allocation"
        );
        assert!(m.queue_depth_max() >= 2, "queue observed before slots drained");
        // the telemetry dump is valid JSON
        assert!(crate::util::json::parse(&m.to_json().to_string()).is_ok());
    }

    // -- tentpole: quantized KV cache on the serving path -------------------

    #[test]
    fn quantized_kv_serving_cuts_live_kv_residency() {
        use crate::model::native::KvDtype;
        let w = test_weights();
        let run = |dtype: KvDtype| {
            let mut s = Scheduler::new(
                &w,
                ServeOpts { max_batch: 2, kv_dtype: dtype, ..Default::default() },
            );
            for i in 0..4 {
                s.submit(Request::new(i, vec![1, 2, 3, i as i32], 5, Sampler::Greedy));
            }
            let (done, _) = s.run();
            assert_eq!(done.len(), 4);
            for c in &done {
                assert_eq!(c.finish, FinishReason::Length);
                assert_eq!(c.generated.len(), 5, "quantized KV must still serve to length");
            }
            s.metrics().clone()
        };
        let base = run(KvDtype::F32);
        let int8 = run(KvDtype::Int8);
        assert_eq!(int8.kv_dtype, KvDtype::Int8);
        let j = int8.to_json();
        assert_eq!(j.get("kv").unwrap().get("dtype").unwrap().as_str(), Some("int8"));
        // Identical traffic with length-capped finishes means both runs touch
        // the same page positions at the same rounds, so the sampled peaks
        // compare page sizes directly: 576 B int8 vs 2048 B f32 at d_model=32.
        assert!(base.kv_live_bytes_peak > 0 && int8.kv_live_bytes_peak > 0);
        assert!(
            base.kv_live_bytes_peak as f64 >= 3.5 * int8.kv_live_bytes_peak as f64,
            "int8 live-KV peak {} B is not >=3.5x under the f32 peak {} B",
            int8.kv_live_bytes_peak,
            base.kv_live_bytes_peak
        );
        // the eager baseline stays an f32 full-context figure for every dtype
        assert_eq!(base.kv_eager_bytes_peak, int8.kv_eager_bytes_peak);
    }

    // -- tentpole: request-lifecycle tracing --------------------------------

    #[test]
    fn tracing_on_is_bit_identical() {
        // The span recorder must be a pure observer: with tracing forced on,
        // completions (greedy and stochastic, plain and speculative, with
        // the prefix cache in the mix) stay bit-identical to the
        // tracing-off reference the batch/policy pin already established.
        let w = test_weights();
        let draft = Weights::random(OptConfig::test_config(), 77);
        let reference = run_mixed(&w, None, 0, 1, AdmissionPolicy::Fcfs, false).0;
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        crate::obs::trace::clear();
        let traced = run_mixed(&w, None, 0, 4, AdmissionPolicy::Deadline, true).0;
        let traced_spec =
            run_mixed(&w, Some(&draft), 2, 4, AdmissionPolicy::ShortestPrompt, true).0;
        crate::obs::set_enabled(false);
        crate::obs::trace::clear();
        assert_eq!(reference, traced, "tracing perturbed plain completions");
        assert_eq!(reference, traced_spec, "tracing perturbed speculative completions");
    }

    #[test]
    fn chrome_trace_covers_request_lifecycle_and_matches_ttft() {
        use crate::obs::trace::Phase;
        use crate::serve::Histogram;
        let w = test_weights();
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        crate::obs::trace::clear();
        // pin the trace epoch before any request is submitted, so even
        // submit-time stamps convert exactly (no pre-epoch saturation)
        crate::obs::trace::mark("test", "epoch_pin", 0);
        // ids far from every other test's, so events recorded by tests
        // running concurrently while the recorder is on can't alias ours
        let base = 9_100usize;
        let mut s = Scheduler::new(&w, ServeOpts { max_batch: 2, ..Default::default() });
        for i in 0..4 {
            s.submit(Request::new(base + i, vec![1, 2, 3, i as i32], 3, Sampler::Greedy));
        }
        let (done, _) = s.run();
        crate::obs::set_enabled(false);
        let events = crate::obs::trace::take_events();
        assert_eq!(done.len(), 4);

        // the dumped Chrome trace parses and holds at least our events
        let dir = std::env::temp_dir().join("invarexplore_scheduler_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        crate::obs::chrome::write(&path, &events).unwrap();
        let doc = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(doc.req("traceEvents").unwrap().as_arr().unwrap().len(), events.len());

        let ours = |name: &str, id: usize| {
            events
                .iter()
                .find(|e| e.cat == "serve" && e.name == name && e.id == id as u64)
                .copied()
                .unwrap_or_else(|| panic!("missing {name} event for request {id}"))
        };
        for c in &done {
            assert_eq!(c.finish, FinishReason::Length);
            let admit = ours("admit", c.id);
            let queue = ours("queue", c.id);
            let prefill = ours("prefill", c.id);
            let decode = ours("decode", c.id);
            let finish = ours("finish", c.id);
            assert_eq!(admit.ph, Phase::Mark);
            assert_eq!(queue.ph, Phase::Complete);
            assert_eq!(finish.ph, Phase::Mark);
            // span tree: queue ends where prefill starts, prefill ends where
            // decode starts, decode ends before the finish mark (each
            // boundary shared up to 1 µs truncation)
            let queue_end = queue.ts_us + queue.dur_us;
            assert!(queue_end.abs_diff(prefill.ts_us) <= 1, "queue/prefill boundary");
            let prefill_end = prefill.ts_us + prefill.dur_us;
            assert!(prefill_end.abs_diff(decode.ts_us) <= 1, "prefill/decode boundary");
            assert!(decode.ts_us + decode.dur_us <= finish.ts_us + 1, "decode before finish");
            assert!(admit.ts_us <= queue_end + 1, "admit mark sits at the queue boundary");
            // TTFT derived from the spans matches the per-request timing
            // (which is the exact duration the metrics histogram recorded)
            let span_ttft = prefill_end - queue.ts_us;
            assert!(
                span_ttft.abs_diff(c.timing.ttft_us) <= 2,
                "span TTFT {span_ttft} vs timing {}",
                c.timing.ttft_us
            );
            assert!(
                c.timing.ttft_us.abs_diff(c.timing.queue_us + c.timing.prefill_us) <= 1,
                "ttft != queue + prefill"
            );
            assert!(c.timing.decode_rounds >= 1);
            assert!(c.timing.decode_us >= 1 || c.timing.ttft_us > 0);
        }
        // the TTFT histogram saw exactly these four requests, in exactly the
        // buckets the per-request timings fall into
        let m = s.metrics();
        assert_eq!(m.ttft.count(), 4);
        assert_eq!(m.queue_wait.count(), 4);
        assert_eq!(m.prefill.count(), 4);
        assert_eq!(m.decode.count(), 4);
        let mut expect = vec![0u64; Histogram::N_BUCKETS];
        for c in &done {
            expect[Histogram::bucket_index(c.timing.ttft_us)] += 1;
        }
        for (i, &n) in expect.iter().enumerate() {
            assert_eq!(m.ttft.bucket(i), n, "ttft bucket {i}");
        }
    }
}

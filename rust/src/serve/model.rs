//! The packed deployment model: bit-packed quantized linears + the FP
//! weights for everything else (embeddings, positions, LayerNorms, biases).
//!
//! Implements [`DecoderParams`], so the incremental serving path runs the
//! forward pass *directly on the packed codes* through the fused
//! unpack→dequant→GEMM kernels of [`PackedTensor`] — the quantized linears
//! are never materialized as dense f32.  The parity pin: serving from the
//! packed form is bit-identical to serving from
//! [`PackedModel::unpacked_weights`] (see
//! `packed_forward_bit_identical_to_unpacked_dense`).

// DETERMINISM: HashMap holds the packed linears for keyed lookup by
// parameter name only; the forward pass asks for specific names, so
// iteration order never influences compute or output.
use std::collections::HashMap;

use crate::model::native::DecoderParams;
use crate::model::{OptConfig, Weights};
use crate::quant::{self, BitAllocation, PackedTensor, QuantScheme};
use crate::tensor::{ops, Tensor};

/// A model held in deployment form: FP non-linear parameters plus one
/// [`PackedTensor`] per quantized linear.
///
/// Every packed tensor carries its own [`QuantScheme`], so heterogeneous
/// (mixed-precision) allocations serve through the exact same hot path as
/// uniform ones — the fused kernels read each tensor's bits/group from its
/// own header, never from a global.
pub struct PackedModel {
    fp: Weights,
    packed: HashMap<String, PackedTensor>,
}

impl PackedModel {
    /// Build from preprocessed FP weights plus packed linears (as produced
    /// by `baselines::Prepared::pack_model`).  Each packed tensor must
    /// match its parameter's shape; any quantizable linear *not* listed
    /// falls back to the dense FP weight.
    pub fn new(fp: Weights, packed: Vec<(String, PackedTensor)>) -> PackedModel {
        let mut map = HashMap::new();
        for (name, p) in packed {
            // PANIC-OK: construction-time contract with the packer, not a
            // request path — pack_model/from_allocation only emit names
            // drawn from `fp.config`'s parameter table, and a caller
            // handing us an unknown name is a programming error we want
            // loud at startup, before any request is accepted.
            let expect = fp.config.param_shape(&name).expect("known parameter");
            assert_eq!((p.rows, p.cols), expect, "packed {name:?}: shape mismatch");
            map.insert(name, p);
        }
        PackedModel { fp, packed: map }
    }

    /// Pack `fp`'s quantizable linears under a (possibly heterogeneous)
    /// [`BitAllocation`], keeping everything else dense — the one-call
    /// route from weights + allocation string to a servable model.
    pub fn from_allocation(fp: Weights, alloc: &BitAllocation) -> crate::Result<PackedModel> {
        alloc.validate(&fp.config)?;
        let packed = fp
            .quant_names()
            .iter()
            .map(|n| {
                let q = quant::quantize(fp.get(n), alloc.scheme_for(n));
                (n.clone(), PackedTensor::pack(&q))
            })
            .collect();
        Ok(PackedModel::new(fp, packed))
    }

    /// Model architecture (shapes, vocab, context length).
    pub fn config(&self) -> &OptConfig {
        &self.fp.config
    }

    /// The FP (non-quantized) weight set backing this model: embeddings,
    /// positions, LayerNorms, biases — plus the dense fallback of any
    /// linear that was not packed.
    pub fn weights(&self) -> &Weights {
        &self.fp
    }

    /// The packed form of one linear (`None` when it serves dense).
    pub fn packed_of(&self, name: &str) -> Option<&PackedTensor> {
        self.packed.get(name)
    }

    /// Materialize a **draft model** for self-speculative decoding: the
    /// same base FP weights re-quantized under a (typically much more
    /// aggressive) allocation.  Ultra-low-bit packing makes the draft
    /// nearly free next to the target — a 1-bit draft of a 2.x-bit target
    /// adds under half the target's packed bytes — and because it shares
    /// the base weights its proposals track the target closely, which is
    /// what speculative acceptance rates live on.  Attach it with
    /// [`crate::serve::Scheduler::with_draft`].
    pub fn draft(&self, alloc: &BitAllocation) -> crate::Result<PackedModel> {
        PackedModel::from_allocation(self.fp.clone(), alloc)
    }

    /// Number of linears held in packed form.
    pub fn n_packed(&self) -> usize {
        self.packed.len()
    }

    /// Scheme of one packed linear (`None` when it serves dense).
    pub fn scheme_of(&self, name: &str) -> Option<QuantScheme> {
        self.packed.get(name).map(|p| p.scheme)
    }

    /// `"min..max bits"` summary of the packed schemes — log-line fodder
    /// for heterogeneous models.
    pub fn bits_summary(&self) -> String {
        let bits: Vec<usize> = self.packed.values().map(|p| p.scheme.bits).collect();
        match (bits.iter().min(), bits.iter().max()) {
            (Some(lo), Some(hi)) if lo == hi => format!("{lo}-bit uniform"),
            (Some(lo), Some(hi)) => format!("{lo}..{hi}-bit mixed"),
            _ => "dense".into(),
        }
    }

    /// Total bytes of the packed linears (codes + f16 scales + zeros).
    pub fn packed_bytes(&self) -> usize {
        self.packed.values().map(|p| p.nbytes()).sum()
    }

    /// Measured bits/param over the packed linears (the Table-3 number the
    /// serving path actually holds in RAM).
    pub fn bits_per_param(&self) -> f64 {
        let params: usize = self.packed.values().map(|p| p.rows * p.cols).sum();
        self.packed_bytes() as f64 * 8.0 / params.max(1) as f64
    }

    /// Dense weight set with every packed linear replaced by its
    /// deployment-faithful dequantization — the reference the parity tests
    /// (and the unpack-to-dense baseline in `benches/serve_decode.rs`) pin
    /// the packed-direct forward against.
    pub fn unpacked_weights(&self) -> Weights {
        let mut w = self.fp.clone();
        for (name, p) in &self.packed {
            w.set(name, p.unpack());
        }
        w
    }
}

impl DecoderParams for PackedModel {
    fn config(&self) -> &OptConfig {
        &self.fp.config
    }

    fn dense(&self, name: &str) -> &Tensor {
        self.fp.get(name)
    }

    fn linear(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        let bias = &self.fp.layer(l, &format!("{base}.b")).data;
        let wname = format!("l{l}.{base}.w");
        match self.packed.get(&wname) {
            Some(p) => p.linear(x, bias),
            None => ops::linear(x, self.fp.get(&wname), bias),
        }
    }

    fn linear_batch(&self, l: usize, base: &str, x: &Tensor) -> Tensor {
        // routes multi-row chunks to the cache-blocked packed GEMM, which
        // dequantizes each ROW_TILE of weight rows once for all activation
        // rows (bit-identical to `linear` — pinned by
        // `linear_batch_bit_identical_to_row_calls` in quant::packed)
        let bias = &self.fp.layer(l, &format!("{base}.b")).data;
        let wname = format!("l{l}.{base}.w");
        match self.packed.get(&wname) {
            Some(p) => p.linear_batch(x, bias),
            None => ops::linear(x, self.fp.get(&wname), bias),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::{self, KvCache};
    use crate::quant::{self, QuantScheme};
    use crate::serve::{Request, ServeOpts, Server};
    use crate::util::rng::Pcg64;
    use crate::util::sampling::Sampler;

    fn packed_pair() -> (PackedModel, Weights) {
        let w = Weights::random(OptConfig::test_config(), 9);
        let scheme = QuantScheme::new(2, 32);
        let packed: Vec<(String, PackedTensor)> = w
            .quant_names()
            .iter()
            .map(|n| (n.clone(), PackedTensor::pack(&quant::quantize(w.get(n), scheme))))
            .collect();
        let pm = PackedModel::new(w.clone(), packed);
        let dense = pm.unpacked_weights();
        (pm, dense)
    }

    #[test]
    fn packed_forward_bit_identical_to_unpacked_dense() {
        // the tentpole acceptance pin: packed-direct serving == serving over
        // unpack()-ed dense weights, bit for bit, through prefill AND decode
        let (pm, dense) = packed_pair();
        let mut rng = Pcg64::new(1);
        let toks: Vec<i32> = (0..12).map(|_| rng.below(pm.config().vocab) as i32).collect();
        let mut c1 = KvCache::new(pm.config());
        let mut c2 = KvCache::new(&dense.config);
        let l1 = native::prefill(&pm, &mut c1, &toks);
        let l2 = native::prefill(&dense, &mut c2, &toks);
        assert_eq!(l1, l2, "prefill logits must be bit-identical");
        for t in [3i32, 7, 11, 40] {
            let d1 = native::decode_step(&pm, &mut c1, t);
            let d2 = native::decode_step(&dense, &mut c2, t);
            assert_eq!(d1, d2, "decode logits must be bit-identical (token {t})");
        }
    }

    #[test]
    fn serves_from_packed_without_densifying() {
        let (pm, _) = packed_pair();
        let vocab = pm.config().vocab;
        let mut server =
            Server::new(&pm, ServeOpts { max_batch: 3, seed: 1, ..Default::default() });
        let mut rng = Pcg64::new(2);
        for i in 0..4 {
            server.submit(Request::new(
                i,
                (0..6).map(|_| rng.below(vocab) as i32).collect(),
                5,
                if i % 2 == 0 {
                    Sampler::Greedy
                } else {
                    Sampler::TopK { k: 8, temperature: 0.8 }
                },
            ));
        }
        let (done, stats) = server.run();
        assert_eq!(done.len(), 4);
        assert!(done.iter().all(|c| c.generated.len() == 5));
        assert_eq!(stats.generated_tokens, 20);
        assert_eq!(stats.decoded_tokens, 16); // 20 minus one prefill sample each
        assert!(stats.decode_steps >= 4, "KV decode rounds expected");
    }

    #[test]
    fn packed_and_dense_servers_agree() {
        // same requests through the packed model and its dense unpack must
        // produce identical token streams (bit-identical logits + per-
        // request RNG streams)
        fn submit_reqs<P: DecoderParams + ?Sized>(server: &mut Server<'_, P>, vocab: usize) {
            let mut rng = Pcg64::new(8);
            for i in 0..3 {
                server.submit(Request::new(
                    i,
                    (0..5).map(|_| rng.below(vocab) as i32).collect(),
                    4,
                    Sampler::TopK { k: 4, temperature: 0.7 },
                ));
            }
        }
        let (pm, dense) = packed_pair();
        let vocab = pm.config().vocab;
        let mut s1 = Server::new(&pm, ServeOpts { max_batch: 2, seed: 3, ..Default::default() });
        submit_reqs(&mut s1, vocab);
        let (d1, _) = s1.run();
        let mut s2 = Server::new(&dense, ServeOpts { max_batch: 2, seed: 3, ..Default::default() });
        submit_reqs(&mut s2, vocab);
        let (d2, _) = s2.run();
        for (a, b) in d1.iter().zip(&d2) {
            assert_eq!(a.generated, b.generated, "request {}", a.id);
        }
    }

    #[test]
    fn packed_serving_unaffected_by_prefix_cache() {
        // determinism survives on the packed-direct path too: fused-kernel
        // prefill over a prefix-cache fork == full prefill, bit for bit
        let (pm, _) = packed_pair();
        let vocab = pm.config().vocab;
        let run = |prefix_cache: bool| {
            let mut s = Server::new(
                &pm,
                ServeOpts { max_batch: 2, seed: 5, prefix_cache, ..Default::default() },
            );
            let mut rng = Pcg64::new(3);
            let shared: Vec<i32> = (0..6).map(|_| rng.below(vocab) as i32).collect();
            for i in 0..4 {
                let mut p = shared.clone();
                p.push(rng.below(vocab) as i32);
                s.submit(Request::new(i, p, 4, Sampler::TopK { k: 4, temperature: 0.8 }));
            }
            let (done, _) = s.run();
            done.into_iter().map(|c| c.generated).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    /// Heterogeneous packed pair: every tensor class at a different scheme.
    fn mixed_pair() -> (PackedModel, Weights) {
        let w = Weights::random(OptConfig::test_config(), 17);
        let alloc =
            BitAllocation::parse("2x32,ffn_up=4x32,ffn_down=1x32,l0.q.w=3x16").unwrap();
        let pm = PackedModel::from_allocation(w, &alloc).unwrap();
        let dense = pm.unpacked_weights();
        (pm, dense)
    }

    #[test]
    fn mixed_precision_packed_forward_bit_identical_to_unpacked_dense() {
        // the mixed-precision acceptance pin: serving from heterogeneous
        // packed weights == serving from their dense unpack, bit for bit,
        // through prefill AND decode
        let (pm, dense) = mixed_pair();
        assert_eq!(pm.scheme_of("l0.up.w"), Some(QuantScheme::new(4, 32)));
        assert_eq!(pm.scheme_of("l1.down.w"), Some(QuantScheme::new(1, 32)));
        assert_eq!(pm.scheme_of("l0.q.w"), Some(QuantScheme::new(3, 16)));
        assert_eq!(pm.scheme_of("l1.q.w"), Some(QuantScheme::new(2, 32)));
        assert_eq!(pm.bits_summary(), "1..4-bit mixed");
        let mut rng = Pcg64::new(5);
        let toks: Vec<i32> = (0..10).map(|_| rng.below(pm.config().vocab) as i32).collect();
        let mut c1 = KvCache::new(pm.config());
        let mut c2 = KvCache::new(&dense.config);
        let l1 = native::prefill(&pm, &mut c1, &toks);
        let l2 = native::prefill(&dense, &mut c2, &toks);
        assert_eq!(l1, l2, "mixed prefill logits must be bit-identical");
        for t in [2i32, 9, 31] {
            let d1 = native::decode_step(&pm, &mut c1, t);
            let d2 = native::decode_step(&dense, &mut c2, t);
            assert_eq!(d1, d2, "mixed decode logits must be bit-identical (token {t})");
        }
    }

    #[test]
    fn mixed_and_uniform_servers_both_run_end_to_end() {
        let (pm, _) = mixed_pair();
        let vocab = pm.config().vocab;
        let mut server =
            Server::new(&pm, ServeOpts { max_batch: 2, seed: 4, ..Default::default() });
        let mut rng = Pcg64::new(6);
        for i in 0..3 {
            server.submit(Request::new(
                i,
                (0..5).map(|_| rng.below(vocab) as i32).collect(),
                4,
                Sampler::TopK { k: 4, temperature: 0.9 },
            ));
        }
        let (done, stats) = server.run();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.generated.len() == 4));
        assert_eq!(stats.generated_tokens, 12);
    }

    #[test]
    fn draft_model_is_smaller_and_speculation_is_transparent() {
        // the self-speculative pair: a 1-bit draft of the 2-bit target is
        // materially smaller, and serving with it attached changes nothing
        // about the completions — only how many tokens each round commits
        let (pm, _) = packed_pair();
        let draft = pm.draft(&BitAllocation::uniform(QuantScheme::new(1, 32))).unwrap();
        assert!(
            draft.packed_bytes() < pm.packed_bytes(),
            "1-bit draft ({} B) must undercut the 2-bit target ({} B)",
            draft.packed_bytes(),
            pm.packed_bytes()
        );
        let vocab = pm.config().vocab;
        let run = |spec: usize| {
            let opts = ServeOpts { max_batch: 2, seed: 6, spec, ..Default::default() };
            let mut s = Server::new(&pm, opts).with_draft(&draft);
            let mut rng = Pcg64::new(4);
            for i in 0..3 {
                s.submit(Request::new(
                    i,
                    (0..5).map(|_| rng.below(vocab) as i32).collect(),
                    6,
                    Sampler::Greedy,
                ));
            }
            let (done, stats) = s.run();
            (done.into_iter().map(|c| c.generated).collect::<Vec<_>>(), stats)
        };
        let (plain, plain_stats) = run(0);
        let (specd, spec_stats) = run(3);
        assert_eq!(plain, specd, "speculation changed packed-path completions");
        assert_eq!(plain_stats.verify_chunks, 0, "spec=0 must not verify");
        assert!(spec_stats.verify_chunks > 0, "spec=3 must run chunked verifies");
        assert_eq!(
            plain_stats.generated_tokens, spec_stats.generated_tokens,
            "token accounting must agree across modes"
        );
    }

    #[test]
    fn from_allocation_rejects_bad_groups() {
        let w = Weights::random(OptConfig::test_config(), 3);
        let alloc = BitAllocation::parse("2x64").unwrap(); // 64 ∤ 32-col attn
        assert!(PackedModel::from_allocation(w, &alloc).is_err());
    }

    #[test]
    fn memory_accounting_reports_compression() {
        let (pm, _) = packed_pair();
        assert_eq!(pm.n_packed(), 12); // 6 linears x 2 layers
        let bpp = pm.bits_per_param();
        // 2-bit codes + f16 scale / g32 + 2-bit zero / g32 ≈ 2.6, plus slack
        assert!(bpp > 2.0 && bpp < 3.2, "bits/param {bpp}");
    }
}

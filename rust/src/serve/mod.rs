//! Native packed-inference serving: a **continuous-batching scheduler**
//! over the incremental decode path, replacing the PR-2 drain loop.
//!
//! * [`scheduler`] — the engine: a pluggable [`AdmissionPolicy`] (FCFS,
//!   shortest-prompt-first, deadline-aware) fills freed decode slots
//!   mid-flight; malformed requests are *rejected with an error completion*
//!   ([`FinishReason::Rejected`]) instead of panicking the server; requests
//!   can be cancelled (queued or in-flight) through a [`CancelHandle`].
//! * [`prefix`] — a radix-trie prefix cache over token prefixes with
//!   refcounted KV pages and LRU eviction: requests sharing a prompt
//!   prefix skip the shared portion of prefill entirely
//!   (`KvCache::fork_at` in `model::native`).
//! * [`stream`] — per-request token sinks (streaming callbacks),
//!   stop-token / stop-sequence termination, and the finish reason
//!   attached to every [`Completion`].
//! * [`metrics`] — production telemetry: TTFT and inter-token latency
//!   histograms (p50/p95/p99), queue depth, prefix-cache hit rate and live
//!   KV bytes, dumped through `util::json`.
//!
//! The engine is generic over [`DecoderParams`], so the same loop serves a
//! dense [`crate::model::Weights`] or a [`PackedModel`] computing directly
//! on the bit-packed deployment weights (fused unpack→dequant→GEMV kernels
//! in `quant::packed` — no dense f32 materialization of quantized linears).
//!
//! Sampling is deterministic per request: every request draws from its own
//! RNG stream (`seed` ⊕ request id), and every kernel on the path computes
//! each sequence position independently, so completions are **bit-identical
//! across batch size, admission policy, thread count, and prefix cache
//! on/off** — pinned by `completions_invariant_to_batch_policy_and_prefix`.
//!
//! [`DecoderParams`]: crate::model::native::DecoderParams

pub mod metrics;
pub mod model;
pub mod prefix;
pub mod scheduler;
pub mod stream;

pub use metrics::{Histogram, ServeMetrics};
pub use model::PackedModel;
pub use prefix::{PrefixCache, PrefixStats};
/// The serving engine is also exported under PR-2's `Server` name, so
/// existing call sites keep working.
pub use scheduler::Scheduler as Server;
pub use scheduler::{AdmissionPolicy, CancelHandle, Scheduler};
pub use stream::{ChannelSink, FinishReason, FnSink, StopCondition, StreamEvent, TokenSink};

use std::time::Duration;

use crate::util::sampling::Sampler;

/// One generation request.
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    /// Tokens to generate; clamped to the remaining context on admission.
    pub max_new: usize,
    pub sampler: Sampler,
    /// Tokens that terminate generation ([`FinishReason::Stop`]).
    pub stop: Vec<i32>,
    /// Token sequences that terminate generation once the generated tail
    /// matches one of them.
    pub stop_seqs: Vec<Vec<i32>>,
    /// Admission priority: lower admits first under every policy
    /// (policy-specific ordering breaks ties).
    pub priority: i32,
    /// Soft deadline in milliseconds from submission; orders admission
    /// under [`AdmissionPolicy::Deadline`] (earliest deadline first).
    pub deadline_ms: Option<u64>,
    /// Streaming sink receiving every sampled token and the finish reason.
    pub sink: Option<Box<dyn TokenSink>>,
}

impl Request {
    pub fn new(id: usize, prompt: Vec<i32>, max_new: usize, sampler: Sampler) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampler,
            stop: Vec::new(),
            stop_seqs: Vec::new(),
            priority: 0,
            deadline_ms: None,
            sink: None,
        }
    }

    pub fn with_stop(mut self, stop: Vec<i32>) -> Request {
        self.stop = stop;
        self
    }

    pub fn with_stop_seqs(mut self, seqs: Vec<Vec<i32>>) -> Request {
        self.stop_seqs = seqs;
        self
    }

    pub fn with_priority(mut self, priority: i32) -> Request {
        self.priority = priority;
        self
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn with_sink(mut self, sink: Box<dyn TokenSink>) -> Request {
        self.sink = Some(sink);
        self
    }
}

/// A finished request.  Every submitted request produces exactly one
/// completion — including rejected and cancelled ones (`finish` says why).
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub finish: FinishReason,
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// Maximum sequences decoded concurrently per round.
    pub max_batch: usize,
    /// Base sampling seed (each request gets its own stream, split by id).
    pub seed: u64,
    /// Order in which queued requests claim freed decode slots.
    pub policy: AdmissionPolicy,
    /// Reuse KV pages across requests sharing prompt prefixes.
    pub prefix_cache: bool,
    /// Unique-page byte budget of the prefix cache (LRU eviction past it).
    pub prefix_cache_bytes: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            max_batch: 8,
            seed: 0,
            policy: AdmissionPolicy::Fcfs,
            prefix_cache: false,
            prefix_cache_bytes: 32 << 20,
        }
    }
}

/// Latency/throughput accounting for one [`Scheduler::run`].
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    /// Requests rejected at admission (malformed — see
    /// [`FinishReason::Rejected`]).
    pub rejected: usize,
    /// Requests cancelled (queued or mid-flight).
    pub cancelled: usize,
    /// Prompt tokens actually processed during prefill (prefix-cache hits
    /// excluded).
    pub prefill_tokens: usize,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: usize,
    /// All sampled tokens (including the one sampled at the prefill step).
    pub generated_tokens: usize,
    /// Tokens sampled in decode rounds only (excludes prefill samples).
    pub decoded_tokens: usize,
    /// Decode rounds executed (each round advances every active sequence).
    pub decode_steps: usize,
    pub prefill_time: Duration,
    pub decode_time: Duration,
}

impl ServeStats {
    /// Tokens produced per second in the decode phase (excludes the sample
    /// taken at prefill time, which is accounted under prefill).
    pub fn decode_tok_per_sec(&self) -> f64 {
        let secs = self.decode_time.as_secs_f64();
        if secs > 0.0 {
            self.decoded_tokens as f64 / secs
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "served {} requests ({} rejected, {} cancelled): {} prompt tokens \
             prefilled (+{} reused from prefix cache) in {:.1?}; \
             {} tokens generated over {} decode rounds in {:.1?} ({:.1} tok/s decode)",
            self.requests,
            self.rejected,
            self.cancelled,
            self.prefill_tokens,
            self.prefix_hit_tokens,
            self.prefill_time,
            self.generated_tokens,
            self.decode_steps,
            self.decode_time,
            self.decode_tok_per_sec(),
        )
    }
}

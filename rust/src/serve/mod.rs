//! Native packed-inference serving: a batched admission loop that prefills
//! each prompt once and then decodes every active sequence one token per
//! round against its own per-sequence [`KvCache`] — replacing the
//! full-context re-forward per token the serve example used to do.
//!
//! The server is generic over [`DecoderParams`], so the same loop serves a
//! dense [`crate::model::Weights`] or a [`PackedModel`] computing directly
//! on the bit-packed deployment weights (fused unpack→dequant→GEMV kernels
//! in `quant::packed` — no dense f32 materialization of quantized linears).
//!
//! Sampling is deterministic per request: every request draws from its own
//! RNG stream (`seed` ⊕ request id), so completions do not depend on batch
//! composition, admission order, or the number of pool threads — pinned by
//! `batch_size_does_not_change_outputs`.

pub mod model;

pub use model::PackedModel;

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::model::native::{self, DecoderParams, KvCache};
use crate::util::pool;
use crate::util::rng::Pcg64;
use crate::util::sampling::Sampler;

/// One generation request.
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    /// Tokens to generate; clamped to the remaining context on admission.
    pub max_new: usize,
    pub sampler: Sampler,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
}

/// Server knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// Maximum sequences decoded concurrently per round.
    pub max_batch: usize,
    /// Base sampling seed (each request gets its own stream, split by id).
    pub seed: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { max_batch: 8, seed: 0 }
    }
}

/// Latency/throughput accounting for one [`Server::run`].
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    /// Prompt tokens processed during prefill.
    pub prefill_tokens: usize,
    /// All sampled tokens (including the one sampled at the prefill step).
    pub generated_tokens: usize,
    /// Tokens sampled in decode rounds only (excludes prefill samples).
    pub decoded_tokens: usize,
    /// Decode rounds executed (each round advances every active sequence).
    pub decode_steps: usize,
    pub prefill_time: Duration,
    pub decode_time: Duration,
}

impl ServeStats {
    /// Tokens produced per second in the decode phase (excludes the sample
    /// taken at prefill time, which is accounted under prefill).
    pub fn decode_tok_per_sec(&self) -> f64 {
        let secs = self.decode_time.as_secs_f64();
        if secs > 0.0 {
            self.decoded_tokens as f64 / secs
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "served {} requests: {} prompt tokens prefilled in {:.1?}; \
             {} tokens generated over {} decode rounds in {:.1?} ({:.1} tok/s decode)",
            self.requests,
            self.prefill_tokens,
            self.prefill_time,
            self.generated_tokens,
            self.decode_steps,
            self.decode_time,
            self.decode_tok_per_sec(),
        )
    }
}

/// An admitted in-flight sequence.
struct Active {
    req: Request,
    cache: KvCache,
    generated: Vec<i32>,
    /// Most recently sampled token, not yet fed back through the model.
    last: i32,
    rng: Pcg64,
}

/// Batched serving loop over any [`DecoderParams`] source.
pub struct Server<'a, P: DecoderParams + ?Sized> {
    params: &'a P,
    opts: ServeOpts,
    queue: VecDeque<Request>,
}

impl<'a, P: DecoderParams + ?Sized> Server<'a, P> {
    pub fn new(params: &'a P, opts: ServeOpts) -> Server<'a, P> {
        assert!(opts.max_batch >= 1, "max_batch must be >= 1");
        Server { params, opts, queue: VecDeque::new() }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue to completion: admit up to `max_batch` sequences,
    /// prefill the admitted prompts in parallel (once each), then decode
    /// all active sequences one token per round (data-parallel over
    /// sequences — each owns its KV cache).
    pub fn run(&mut self) -> (Vec<Completion>, ServeStats) {
        let params = self.params;
        let max_seq = params.config().max_seq;
        let mut stats = ServeStats::default();
        let mut done: Vec<Completion> = Vec::new();
        let mut active: Vec<Active> = Vec::new();

        while !self.queue.is_empty() || !active.is_empty() {
            // -- admission: claim free slots, validate, set up state ---------
            let mut admitted: Vec<Active> = Vec::new();
            while active.len() + admitted.len() < self.opts.max_batch {
                let Some(mut req) = self.queue.pop_front() else { break };
                assert!(!req.prompt.is_empty(), "request {}: empty prompt", req.id);
                assert!(
                    req.prompt.len() < max_seq,
                    "request {}: prompt len {} must leave room in max_seq {}",
                    req.id,
                    req.prompt.len(),
                    max_seq
                );
                req.max_new = req.max_new.min(max_seq - req.prompt.len());
                stats.requests += 1;
                if req.max_new == 0 {
                    done.push(Completion { id: req.id, prompt: req.prompt, generated: Vec::new() });
                    continue;
                }
                stats.prefill_tokens += req.prompt.len();
                let cache = KvCache::new(params.config());
                let rng = Pcg64::with_stream(self.opts.seed, req.id as u64);
                admitted.push(Active { req, cache, generated: Vec::new(), last: 0, rng });
            }

            // -- prefill the admitted batch in parallel (one prompt each) ----
            if !admitted.is_empty() {
                let t0 = Instant::now();
                let threads = pool::num_threads().min(admitted.len());
                pool::parallel_chunks_mut(&mut admitted, 1, threads, |_i, slot| {
                    let a = &mut slot[0];
                    let logits = native::prefill(params, &mut a.cache, &a.req.prompt);
                    let first = a.req.sampler.sample(&logits, &mut a.rng) as i32;
                    a.generated.push(first);
                    a.last = first;
                });
                stats.prefill_time += t0.elapsed();
                stats.generated_tokens += admitted.len();
                active.append(&mut admitted);
            }

            // -- retire finished sequences (frees admission slots) -----------
            let mut i = 0;
            while i < active.len() {
                if active[i].generated.len() >= active[i].req.max_new {
                    let a = active.swap_remove(i);
                    done.push(Completion {
                        id: a.req.id,
                        prompt: a.req.prompt,
                        generated: a.generated,
                    });
                } else {
                    i += 1;
                }
            }
            if active.is_empty() {
                continue; // admit more, or fall out when the queue is dry
            }

            // -- one decode round: every active sequence advances one token --
            let t0 = Instant::now();
            let threads = pool::num_threads().min(active.len());
            pool::parallel_chunks_mut(&mut active, 1, threads, |_i, slot| {
                let a = &mut slot[0];
                let logits = native::decode_step(params, &mut a.cache, a.last);
                let next = a.req.sampler.sample(&logits, &mut a.rng) as i32;
                a.generated.push(next);
                a.last = next;
            });
            stats.decode_time += t0.elapsed();
            stats.decode_steps += 1;
            stats.decoded_tokens += active.len();
            stats.generated_tokens += active.len();
        }

        done.sort_by_key(|c| c.id);
        (done, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OptConfig, Weights};

    fn test_weights() -> Weights {
        Weights::random(OptConfig::test_config(), 3)
    }

    fn requests(n: usize, vocab: usize) -> Vec<Request> {
        let mut rng = Pcg64::new(5);
        (0..n)
            .map(|i| Request {
                id: i,
                prompt: (0..4 + i % 3).map(|_| rng.below(vocab) as i32).collect(),
                max_new: 3 + i % 4,
                sampler: if i % 2 == 0 {
                    Sampler::Greedy
                } else {
                    Sampler::TopK { k: 4, temperature: 0.9 }
                },
            })
            .collect()
    }

    #[test]
    fn serves_all_requests_to_completion() {
        let w = test_weights();
        let mut server = Server::new(&w, ServeOpts { max_batch: 2, seed: 0 });
        for r in requests(5, w.config.vocab) {
            server.submit(r);
        }
        assert_eq!(server.pending(), 5);
        let (done, stats) = server.run();
        assert_eq!(done.len(), 5);
        assert_eq!(stats.requests, 5);
        let total: usize = done.iter().map(|c| c.generated.len()).sum();
        assert_eq!(stats.generated_tokens, total);
        // every request samples exactly one token at prefill time
        assert_eq!(stats.decoded_tokens, total - 5);
        for c in &done {
            assert_eq!(c.generated.len(), 3 + c.id % 4);
            assert!(c.generated.iter().all(|&t| (t as usize) < w.config.vocab));
        }
    }

    #[test]
    fn batch_size_does_not_change_outputs() {
        let w = test_weights();
        let run = |max_batch: usize| {
            let mut s = Server::new(&w, ServeOpts { max_batch, seed: 42 });
            for r in requests(6, w.config.vocab) {
                s.submit(r);
            }
            let (done, _) = s.run();
            done.into_iter().map(|c| c.generated).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn max_new_clamped_to_context() {
        let w = test_weights();
        let max_seq = w.config.max_seq;
        let mut s = Server::new(&w, ServeOpts::default());
        s.submit(Request {
            id: 0,
            prompt: vec![1; max_seq - 2],
            max_new: 100,
            sampler: Sampler::Greedy,
        });
        let (done, _) = s.run();
        assert_eq!(done[0].generated.len(), 2);
    }

    #[test]
    fn zero_max_new_completes_without_decoding() {
        let w = test_weights();
        let mut s = Server::new(&w, ServeOpts::default());
        s.submit(Request { id: 7, prompt: vec![1, 2, 3], max_new: 0, sampler: Sampler::Greedy });
        let (done, stats) = s.run();
        assert_eq!(done.len(), 1);
        assert!(done[0].generated.is_empty());
        assert_eq!(stats.decode_steps, 0);
        // the zero-max_new request never prefills or decodes, so the rate
        // accounting must not go negative/undercount (review finding)
        assert_eq!(stats.decoded_tokens, 0);
        assert_eq!(stats.generated_tokens, 0);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        let w = test_weights();
        let mut s = Server::new(&w, ServeOpts::default());
        s.submit(Request { id: 0, prompt: vec![], max_new: 1, sampler: Sampler::Greedy });
        s.run();
    }
}

//! Native packed-inference serving: a **continuous-batching scheduler**
//! over the incremental decode path, replacing the PR-2 drain loop.
//!
//! * [`scheduler`] — the engine: a pluggable [`AdmissionPolicy`] (FCFS,
//!   shortest-prompt-first, deadline-aware) fills freed decode slots
//!   mid-flight; malformed requests are *rejected with an error completion*
//!   ([`FinishReason::Rejected`]) instead of panicking the server; requests
//!   can be cancelled (queued or in-flight) through a [`CancelHandle`].
//! * [`prefix`] — a radix-trie prefix cache over token prefixes with
//!   refcounted KV pages and LRU eviction: requests sharing a prompt
//!   prefix skip the shared portion of prefill entirely
//!   (`KvCache::fork_at` in `model::native`).
//! * [`stream`] — per-request token sinks (streaming callbacks),
//!   stop-token / stop-sequence termination, and the finish reason
//!   attached to every [`Completion`].
//! * [`metrics`] — production telemetry: TTFT and inter-token latency
//!   histograms (p50/p95/p99), queue depth, prefix-cache hit rate and live
//!   KV bytes, speculative accepted-length histogram, dumped through
//!   `util::json`.
//! * [`router`] — a multi-replica front-end: [`Router`] fans requests out
//!   over N independent schedulers with consistent-hash prefix affinity,
//!   queue-depth balancing, deadline-aware spillover under saturation, and
//!   explicit load shedding ([`FinishReason::Rejected`]) past a
//!   configurable admission watermark.  Replica threads run under
//!   supervision: a panicking replica is caught, its queued and in-flight
//!   requests redispatch to survivors with bounded retries, and
//!   [`Router::shutdown`] drains gracefully.
//! * [`fault`] — deterministic seeded fault injection ([`FaultPlan`]:
//!   replica kills at round R, transient per-request dispatch errors,
//!   injected kernel stalls) so chaos runs replay bit-for-bit; free when
//!   no plan is attached.
//! * [`shard`] — tensor-parallel packed inference: [`ShardedModel`] splits
//!   every packed linear across row-range shards
//!   (`PackedTensor::slice_rows`) and concatenates the per-shard partial
//!   outputs — bit-identical to the unsharded model for any shard count.
//! * [`spec`] — self-speculative decoding: an ultra-low-bit draft model
//!   ([`PackedModel::draft`]) proposes `ServeOpts::spec` tokens per round
//!   and the target verifies them in one chunked forward
//!   (`model::native::forward_chunk`), committing multiple tokens per
//!   weight pass while staying bit-identical to plain decoding.
//!
//! The engine is generic over [`DecoderParams`], so the same loop serves a
//! dense [`crate::model::Weights`] or a [`PackedModel`] computing directly
//! on the bit-packed deployment weights (fused unpack→dequant→GEMV kernels
//! in `quant::packed` — no dense f32 materialization of quantized linears).
//!
//! Sampling is deterministic per request: every request draws from its own
//! RNG stream (`seed` ⊕ request id), and every kernel on the path computes
//! each sequence position independently, so completions are **bit-identical
//! across batch size, admission policy, thread count, and prefix cache
//! on/off** — pinned by `completions_invariant_to_batch_policy_and_prefix`.
//!
//! [`DecoderParams`]: crate::model::native::DecoderParams

/// Deterministic seeded fault injection (replica kills, transient errors,
/// stalls) for reproducible chaos runs.
pub mod fault;
/// TTFT / inter-token-latency histograms, queue depth, KV residency.
pub mod metrics;
/// The bit-packed deployment model ([`PackedModel`]) and its draft twin.
pub mod model;
/// Radix-trie prefix cache over copy-on-write KV pages.
pub mod prefix;
/// Multi-replica request router: affinity, balancing, spillover, shedding.
pub mod router;
/// Continuous-batching engine: admission, rounds, cancellation.
pub mod scheduler;
/// Tensor-parallel row sharding of the packed linears.
pub mod shard;
/// Speculative decoding: draft proposals + chunked verification.
pub mod spec;
/// Streaming sinks, stop conditions, and finish reasons.
pub mod stream;

pub use fault::{FaultInjector, FaultPlan};
pub use metrics::{CountHistogram, Histogram, ServeMetrics};
pub use model::PackedModel;
pub use prefix::{PrefixCache, PrefixStats};
pub use router::{DrainSummary, Router, RouterOpts, RouterStats};
/// The serving engine is also exported under PR-2's `Server` name, so
/// existing call sites keep working.
pub use scheduler::Scheduler as Server;
pub use scheduler::{AdmissionPolicy, CancelHandle, Scheduler};
pub use shard::{shard_ranges, ShardedModel};
pub use spec::SpecRound;
pub use stream::{ChannelSink, FinishReason, FnSink, StopCondition, StreamEvent, TokenSink};

use std::time::Duration;

use crate::util::sampling::Sampler;

/// One generation request.
pub struct Request {
    /// Caller-chosen identifier; also selects the request's RNG stream, so
    /// completions depend on `(id, prompt, sampler)` and nothing else.
    pub id: usize,
    /// Prompt tokens (validated against the model's vocab at admission).
    pub prompt: Vec<i32>,
    /// Tokens to generate; clamped to the remaining context on admission.
    pub max_new: usize,
    /// Sampling strategy for this request.
    pub sampler: Sampler,
    /// Tokens that terminate generation ([`FinishReason::Stop`]).
    pub stop: Vec<i32>,
    /// Token sequences that terminate generation once the generated tail
    /// matches one of them.
    pub stop_seqs: Vec<Vec<i32>>,
    /// Admission priority: lower admits first under every policy
    /// (policy-specific ordering breaks ties).
    pub priority: i32,
    /// Soft deadline in milliseconds from submission; orders admission
    /// under [`AdmissionPolicy::Deadline`] (earliest deadline first).
    pub deadline_ms: Option<u64>,
    /// Streaming sink receiving every sampled token and the finish reason.
    pub sink: Option<Box<dyn TokenSink>>,
}

impl Request {
    /// A request with no stop conditions, default priority, no deadline and
    /// no sink (add those with the `with_*` builders).
    pub fn new(id: usize, prompt: Vec<i32>, max_new: usize, sampler: Sampler) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampler,
            stop: Vec::new(),
            stop_seqs: Vec::new(),
            priority: 0,
            deadline_ms: None,
            sink: None,
        }
    }

    /// Set the stop tokens ([`Request::stop`]).
    pub fn with_stop(mut self, stop: Vec<i32>) -> Request {
        self.stop = stop;
        self
    }

    /// Set the stop sequences ([`Request::stop_seqs`]).
    pub fn with_stop_seqs(mut self, seqs: Vec<Vec<i32>>) -> Request {
        self.stop_seqs = seqs;
        self
    }

    /// Set the admission priority ([`Request::priority`]).
    pub fn with_priority(mut self, priority: i32) -> Request {
        self.priority = priority;
        self
    }

    /// Set the soft deadline ([`Request::deadline_ms`]).
    pub fn with_deadline_ms(mut self, ms: u64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }

    /// Attach a streaming sink ([`Request::sink`]).
    pub fn with_sink(mut self, sink: Box<dyn TokenSink>) -> Request {
        self.sink = Some(sink);
        self
    }
}

/// Where one request's wall time went, attached to its [`Completion`].
/// Always populated (the clock reads are a handful of nanoseconds per
/// request — far below scheduler noise), independent of whether the span
/// recorder (`crate::obs`) is on.
///
/// Invariant: `ttft_us == queue_us + prefill_us` up to 1 µs truncation,
/// and the same boundary instants feed the request-lifecycle spans, so a
/// Chrome trace of the run shows the identical breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestTiming {
    /// Submit → admission into the running batch.
    pub queue_us: u64,
    /// Admission → first sampled token.
    pub prefill_us: u64,
    /// First sampled token → last sampled token.
    pub decode_us: u64,
    /// Submit → first sampled token (the TTFT the metrics histogram sees).
    pub ttft_us: u64,
    /// Decode rounds this request participated in.
    pub decode_rounds: u32,
}

/// A finished request.  Every submitted request produces exactly one
/// completion — including rejected and cancelled ones (`finish` says why).
///
/// Equality deliberately **ignores** [`Completion::timing`]: the
/// determinism pins compare completions across runs, and wall-clock
/// timings are the one field that legitimately differs between
/// bit-identical runs.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The id of the request that produced this completion.
    pub id: usize,
    /// The request's prompt tokens, returned unchanged.
    pub prompt: Vec<i32>,
    /// Every sampled token in order (empty for rejected requests).
    pub generated: Vec<i32>,
    /// Why generation ended.
    pub finish: FinishReason,
    /// Per-request queue/prefill/decode/TTFT breakdown (zeros for requests
    /// rejected before admission).
    pub timing: RequestTiming,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Completion) -> bool {
        self.id == other.id
            && self.prompt == other.prompt
            && self.generated == other.generated
            && self.finish == other.finish
    }
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// Maximum sequences decoded concurrently per round.
    pub max_batch: usize,
    /// Base sampling seed (each request gets its own stream, split by id).
    pub seed: u64,
    /// Order in which queued requests claim freed decode slots.
    pub policy: AdmissionPolicy,
    /// Reuse KV pages across requests sharing prompt prefixes.
    pub prefix_cache: bool,
    /// Unique-page byte budget of the prefix cache (LRU eviction past it).
    pub prefix_cache_bytes: usize,
    /// Self-speculative decoding: draft tokens proposed per decode round
    /// (0 = off).  Takes effect only once a draft model is attached via
    /// [`Scheduler::with_draft`]; completions are bit-identical to plain
    /// decoding either way — speculation is a pure throughput knob.
    pub spec: usize,
    /// Storage precision of every per-slot (and draft) KV cache.  `F32`
    /// (default) keeps serving fully bit-identical; `Int8`/`Int4` trade the
    /// documented per-element error bound of
    /// [`crate::model::native::KvDtype`] for ~3.6×/~6.4× lower live-KV
    /// residency (reported per dtype by [`ServeMetrics`]).
    pub kv_dtype: crate::model::native::KvDtype,
    /// Per-round wall-clock budget in milliseconds (`None` = unbounded,
    /// the default).  A slot whose decode step exceeds the budget finishes
    /// [`FinishReason::Failed`] at the next round boundary instead of
    /// holding the rest of the batch hostage — the escape hatch for a
    /// stalled kernel.
    pub round_budget_ms: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            max_batch: 8,
            seed: 0,
            policy: AdmissionPolicy::Fcfs,
            prefix_cache: false,
            prefix_cache_bytes: 32 << 20,
            spec: 0,
            kv_dtype: crate::model::native::KvDtype::F32,
            round_budget_ms: None,
        }
    }
}

/// Latency/throughput accounting for one [`Scheduler::run`].
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests that produced a completion during this run.
    pub requests: usize,
    /// Requests rejected at admission (malformed — see
    /// [`FinishReason::Rejected`]).
    pub rejected: usize,
    /// Requests cancelled (queued or mid-flight).
    pub cancelled: usize,
    /// Requests whose deadline expired while queued ([`FinishReason::TimedOut`]
    /// at admission, before any KV allocation).
    pub timed_out: usize,
    /// Requests abandoned with [`FinishReason::Failed`] (blown per-round
    /// budget; the router adds its own for exhausted retries).
    pub failed: usize,
    /// Prompt tokens actually processed during prefill (prefix-cache hits
    /// excluded).
    pub prefill_tokens: usize,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: usize,
    /// All sampled tokens (including the one sampled at the prefill step).
    pub generated_tokens: usize,
    /// Tokens sampled in decode rounds only (excludes prefill samples).
    pub decoded_tokens: usize,
    /// Decode rounds executed (each round advances every active sequence —
    /// by one token plain, by up to `spec + 1` tokens speculative).
    pub decode_steps: usize,
    /// Draft-model tokens proposed across all speculative rounds.
    pub draft_tokens: usize,
    /// Draft tokens the target's sampler accepted.
    pub spec_matched: usize,
    /// Chunked verify forwards executed (one per slot per speculative
    /// round that had draft budget).
    pub verify_chunks: usize,
    /// Wall time spent in prefill forwards.
    pub prefill_time: Duration,
    /// Wall time spent in decode rounds.
    pub decode_time: Duration,
}

impl ServeStats {
    /// Accumulate another run's stats into this one (field-wise sums).
    /// The router uses this to fold multiple supervision passes over one
    /// replica — a redispatch re-run plus the original — into one account.
    pub fn merge(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.cancelled += other.cancelled;
        self.timed_out += other.timed_out;
        self.failed += other.failed;
        self.prefill_tokens += other.prefill_tokens;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.generated_tokens += other.generated_tokens;
        self.decoded_tokens += other.decoded_tokens;
        self.decode_steps += other.decode_steps;
        self.draft_tokens += other.draft_tokens;
        self.spec_matched += other.spec_matched;
        self.verify_chunks += other.verify_chunks;
        self.prefill_time += other.prefill_time;
        self.decode_time += other.decode_time;
    }

    /// Fraction of proposed draft tokens the target accepted.
    pub fn spec_accept_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            0.0
        } else {
            self.spec_matched as f64 / self.draft_tokens as f64
        }
    }

    /// Tokens produced per second in the decode phase (excludes the sample
    /// taken at prefill time, which is accounted under prefill).
    pub fn decode_tok_per_sec(&self) -> f64 {
        let secs = self.decode_time.as_secs_f64();
        if secs > 0.0 {
            self.decoded_tokens as f64 / secs
        } else {
            0.0
        }
    }

    /// Mean tokens committed per chunked verify (each verify commits its
    /// matched drafts plus one correction/bonus sample; plain-fallback
    /// rounds are excluded).  0 when speculation never engaged.
    pub fn spec_tokens_per_verify(&self) -> f64 {
        if self.verify_chunks == 0 {
            0.0
        } else {
            (self.spec_matched + self.verify_chunks) as f64 / self.verify_chunks as f64
        }
    }

    /// One-line human-readable account of the run.
    pub fn summary(&self) -> String {
        let spec = if self.verify_chunks > 0 {
            format!(
                "; speculative: {}/{} draft tokens accepted ({:.0}%), \
                 {:.2} tokens/verify over {} verify chunks",
                self.spec_matched,
                self.draft_tokens,
                100.0 * self.spec_accept_rate(),
                self.spec_tokens_per_verify(),
                self.verify_chunks,
            )
        } else {
            String::new()
        };
        format!(
            "served {} requests ({} rejected, {} cancelled, {} timed out, \
             {} failed): {} prompt tokens \
             prefilled (+{} reused from prefix cache) in {:.1?}; \
             {} tokens generated over {} decode rounds in {:.1?} ({:.1} tok/s decode){spec}",
            self.requests,
            self.rejected,
            self.cancelled,
            self.timed_out,
            self.failed,
            self.prefill_tokens,
            self.prefix_hit_tokens,
            self.prefill_time,
            self.generated_tokens,
            self.decode_steps,
            self.decode_time,
            self.decode_tok_per_sec(),
        )
    }
}

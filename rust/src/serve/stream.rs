//! Per-request streaming output and termination: token sinks the scheduler
//! calls as each token is sampled, stop-token / stop-sequence conditions,
//! and the finish reason attached to every [`crate::serve::Completion`].

use std::sync::mpsc;

/// Why a request finished.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new` tokens (or exhausted the context window).
    Length,
    /// Sampled a stop token, or the generated tail completed a stop
    /// sequence.
    Stop,
    /// Cancelled through a [`crate::serve::CancelHandle`] before finishing.
    Cancelled,
    /// Rejected at admission; the payload says why.  A malformed request
    /// produces this completion instead of aborting the whole batch.
    Rejected(String),
    /// The request's `deadline_ms` expired while it waited in the queue;
    /// it finished before any prefill or KV allocation happened.
    TimedOut,
    /// Abandoned after an unrecoverable serving failure — replica death
    /// with redispatch retries exhausted, an injected transient fault that
    /// never cleared, or a decode round that blew the per-round wall-clock
    /// budget.  The payload says which.
    Failed(String),
}

impl FinishReason {
    /// Short stable label (metrics / JSON field values).
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected(_) => "rejected",
            FinishReason::TimedOut => "timed_out",
            FinishReason::Failed(_) => "failed",
        }
    }
}

/// Streaming sink for one request.  The scheduler calls it from the worker
/// thread driving the sequence (hence `Send`): `on_token` once per sampled
/// token, then `on_finish` exactly once.
pub trait TokenSink: Send {
    /// `index` is the 0-based position within the generated tokens.
    fn on_token(&mut self, token: i32, index: usize);
    /// Called once when the request leaves the scheduler (any reason,
    /// including rejection — in that case with no preceding `on_token`).
    fn on_finish(&mut self, _reason: &FinishReason) {}
}

/// Event delivered by [`ChannelSink`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEvent {
    /// One sampled token at 0-based generated `index`.
    Token {
        /// Position within the generated tokens.
        index: usize,
        /// The sampled token id.
        token: i32,
    },
    /// The request left the scheduler; sent exactly once, last.
    Finish(FinishReason),
}

/// [`TokenSink`] forwarding events over an mpsc channel, for consumers on
/// another thread (or drained after `run` in synchronous use).
pub struct ChannelSink {
    tx: mpsc::Sender<StreamEvent>,
}

impl ChannelSink {
    /// The sink plus the receiver its events arrive on.
    pub fn new() -> (ChannelSink, mpsc::Receiver<StreamEvent>) {
        let (tx, rx) = mpsc::channel();
        (ChannelSink { tx }, rx)
    }
}

impl TokenSink for ChannelSink {
    fn on_token(&mut self, token: i32, index: usize) {
        // receiver may be gone (consumer lost interest); generation goes on
        let _ = self.tx.send(StreamEvent::Token { index, token });
    }

    fn on_finish(&mut self, reason: &FinishReason) {
        let _ = self.tx.send(StreamEvent::Finish(reason.clone()));
    }
}

/// [`TokenSink`] from a closure over `(token, index)`; finish is dropped.
pub struct FnSink<F: FnMut(i32, usize) + Send>(pub F);

impl<F: FnMut(i32, usize) + Send> TokenSink for FnSink<F> {
    fn on_token(&mut self, token: i32, index: usize) {
        (self.0)(token, index)
    }
}

/// Stop-token / stop-sequence termination state for one request.
#[derive(Debug, Clone, Default)]
pub struct StopCondition {
    /// Single tokens that terminate generation when sampled.
    pub tokens: Vec<i32>,
    /// Token sequences that terminate generation once the generated tail
    /// matches one of them exactly.
    pub sequences: Vec<Vec<i32>>,
}

impl StopCondition {
    /// No stop tokens or sequences: generation runs to `max_new`.
    pub fn none() -> StopCondition {
        StopCondition::default()
    }

    /// True when no stop token or sequence is set.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty() && self.sequences.is_empty()
    }

    /// Does generation stop after `generated` (whose last element is the
    /// token just sampled)?  The terminating token/sequence is part of the
    /// completion.
    pub fn hit(&self, generated: &[i32]) -> bool {
        let Some(&last) = generated.last() else {
            return false;
        };
        if self.tokens.contains(&last) {
            return true;
        }
        self.sequences.iter().any(|s| {
            !s.is_empty()
                && generated.len() >= s.len()
                && &generated[generated.len() - s.len()..] == s.as_slice()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_tokens_match_last_only() {
        let stop = StopCondition { tokens: vec![5, 9], sequences: vec![] };
        assert!(!stop.hit(&[]));
        assert!(!stop.hit(&[5, 1])); // 5 earlier in the stream doesn't stop
        assert!(stop.hit(&[1, 5]));
        assert!(stop.hit(&[9]));
        assert!(!stop.hit(&[2, 3]));
    }

    #[test]
    fn stop_sequences_match_tail() {
        let stop = StopCondition { tokens: vec![], sequences: vec![vec![7, 8], vec![3]] };
        assert!(stop.hit(&[1, 7, 8]));
        assert!(!stop.hit(&[7, 8, 1]));
        assert!(stop.hit(&[3]));
        assert!(!stop.hit(&[7])); // prefix of a sequence is not a hit
        // an empty stop sequence never matches
        let degenerate = StopCondition { tokens: vec![], sequences: vec![vec![]] };
        assert!(!degenerate.hit(&[1, 2]));
    }

    #[test]
    fn empty_condition_never_hits() {
        let stop = StopCondition::none();
        assert!(stop.is_empty());
        assert!(!stop.hit(&[1, 2, 3]));
    }

    #[test]
    fn channel_sink_streams_in_order() {
        let (mut sink, rx) = ChannelSink::new();
        sink.on_token(10, 0);
        sink.on_token(20, 1);
        sink.on_finish(&FinishReason::Stop);
        let events: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(
            events,
            vec![
                StreamEvent::Token { index: 0, token: 10 },
                StreamEvent::Token { index: 1, token: 20 },
                StreamEvent::Finish(FinishReason::Stop),
            ]
        );
    }

    #[test]
    fn channel_sink_survives_dropped_receiver() {
        let (mut sink, rx) = ChannelSink::new();
        drop(rx);
        sink.on_token(1, 0); // must not panic
        sink.on_finish(&FinishReason::Length);
    }

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Length.label(), "length");
        assert_eq!(FinishReason::Rejected("x".into()).label(), "rejected");
        assert_eq!(FinishReason::TimedOut.label(), "timed_out");
        assert_eq!(FinishReason::Failed("replica died".into()).label(), "failed");
    }
}

//! Deterministic, seeded fault injection for chaos-testing the serving
//! stack: replica kills at a chosen round, transient per-request dispatch
//! errors, and injected kernel stalls.
//!
//! A [`FaultPlan`] is pure data parsed from a compact spec string
//! (`SERVE_FAULT_PLAN` / `--fault-plan`), and every injection decision is a
//! pure function of `(plan.seed, request id, attempt)` or a literal
//! `(replica, round)` match — no ambient RNG, no clocks — so a chaos run
//! replays **bit-for-bit**: the same plan over the same traffic kills the
//! same replica at the same round and fails the same dispatch attempts,
//! every time.  With no plan attached the serving hot paths pay one
//! `Option` check and nothing else.
//!
//! Spec grammar (comma-separated `key=value` pairs, keys repeatable):
//!
//! ```text
//! seed=42                    injection-decision seed (default 0)
//! kill=1@3                   replica 1 panics at the top of its round 3
//! transient=0.05             each dispatch attempt fails with p = 0.05
//! stall=7@2x40               request 7's decode at round 2 sleeps 40 ms
//! ```
//!
//! Rounds are counted per [`crate::serve::Scheduler::run`] call (the
//! trace-replay benches call `run` once per arrival wave, so `kill=1@3`
//! means "round 3 of the wave being served when the plan first matches").
//! The panic raised by a kill is *the injected fault itself*; the router's
//! supervision layer (`catch_unwind` + redispatch) is the component under
//! test.

use crate::obs::fault::{record_fault, FaultEvent};

/// A parsed, seeded fault-injection plan.  See the module docs for the
/// spec grammar and the determinism contract.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-attempt transient-failure decisions.
    pub seed: u64,
    /// `(replica, round)` pairs: the replica panics at the top of that
    /// scheduler round.
    pub kills: Vec<(usize, u64)>,
    /// Probability in `[0, 1]` that any single dispatch attempt of a
    /// request fails transiently (decided by hashing `(seed, id, attempt)`,
    /// so retries of the same request draw fresh, reproducible outcomes).
    pub transient: f64,
    /// `(request id, round, millis)` triples: that request's decode step
    /// sleeps `millis` at that round — a stalled kernel for the per-round
    /// wall-clock budget to catch.
    pub stalls: Vec<(usize, u64, u64)>,
}

impl FaultPlan {
    /// Parse a spec string (see the module docs for the grammar).
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault plan: {part:?} is not key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = value
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fault plan: bad seed {value:?}"))?;
                }
                "kill" => {
                    let (r, at) = value.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("fault plan: kill wants replica@round, got {value:?}")
                    })?;
                    plan.kills.push((
                        parse_num(r, "kill replica")? as usize,
                        parse_num(at, "kill round")?,
                    ));
                }
                "transient" => {
                    let p: f64 = value.trim().parse().map_err(|_| {
                        anyhow::anyhow!("fault plan: bad transient rate {value:?}")
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        anyhow::bail!("fault plan: transient rate {p} outside [0, 1]");
                    }
                    plan.transient = p;
                }
                "stall" => {
                    let (id, rest) = value.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("fault plan: stall wants id@roundxms, got {value:?}")
                    })?;
                    let (at, ms) = rest.split_once('x').ok_or_else(|| {
                        anyhow::anyhow!("fault plan: stall wants id@roundxms, got {value:?}")
                    })?;
                    plan.stalls.push((
                        parse_num(id, "stall request id")? as usize,
                        parse_num(at, "stall round")?,
                        parse_num(ms, "stall millis")?,
                    ));
                }
                other => anyhow::bail!(
                    "fault plan: unknown key {other:?} (seed|kill|transient|stall)"
                ),
            }
        }
        Ok(plan)
    }

    /// The plan named by `SERVE_FAULT_PLAN`, if set (empty/unset = no
    /// plan).  A malformed value is an error, not a silent no-op — a chaos
    /// run that quietly injected nothing would report fake resilience.
    pub fn from_env() -> crate::Result<Option<FaultPlan>> {
        match std::env::var("SERVE_FAULT_PLAN") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(FaultPlan::parse(&v)?)),
            _ => Ok(None),
        }
    }

    /// Does dispatch attempt `attempt` of request `id` fail transiently?
    /// Pure function of `(seed, id, attempt)` — reproducible bit-for-bit.
    pub fn transient_fails(&self, id: usize, attempt: usize) -> bool {
        if self.transient <= 0.0 {
            return false;
        }
        let h = splitmix64(self.seed ^ splitmix64((id as u64) << 24 ^ attempt as u64));
        ((h >> 11) as f64 / (1u64 << 53) as f64) < self.transient
    }

    /// The injection hooks for one replica's scheduler.
    pub fn injector_for(&self, replica: usize) -> FaultInjector {
        FaultInjector { plan: self.clone(), replica }
    }

    /// True when the plan injects nothing (the default).
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.stalls.is_empty() && self.transient <= 0.0
    }
}

fn parse_num(s: &str, what: &str) -> crate::Result<u64> {
    s.trim().parse().map_err(|_| anyhow::anyhow!("fault plan: bad {what} {s:?}"))
}

/// SplitMix64 — the finalizer behind the transient-failure decisions; good
/// avalanche from sequential inputs, no state.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One replica's view of a [`FaultPlan`]: the hooks the scheduler calls at
/// the top of every round and inside every decode step.  Plain data
/// (`Sync`), so the decode hook is callable from the parallel decode
/// closure.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    replica: usize,
}

impl FaultInjector {
    /// Called at the top of each scheduler round.
    ///
    /// # Panics
    /// Panics when the plan kills this replica at `round` — the panic *is*
    /// the injected fault; the router's supervision catches it.
    pub fn tick_round(&self, round: u64) {
        if self.plan.kills.iter().any(|&(r, at)| r == self.replica && at == round) {
            // PANIC-OK: this panic is the injected replica-death fault
            // itself — it only fires when an operator explicitly configured
            // a kill in SERVE_FAULT_PLAN/--fault-plan, and the router's
            // catch_unwind supervision layer is the component under test.
            panic!("fault injection: replica {} killed at round {round}", self.replica);
        }
    }

    /// Called from the decode closure for the slot serving request `id`:
    /// sleeps when the plan stalls that request at this round (simulating a
    /// wedged kernel for the per-round budget to convert into a `Failed`
    /// completion).
    pub fn maybe_stall(&self, id: usize, round: u64) {
        for &(rid, at, ms) in &self.plan.stalls {
            if rid == id && at == round {
                record_fault(FaultEvent::StallInjected);
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_roundtrips() {
        let p = FaultPlan::parse("seed=42, kill=1@3, transient=0.25, stall=7@2x40, kill=0@9")
            .expect("valid spec");
        assert_eq!(p.seed, 42);
        assert_eq!(p.kills, vec![(1, 3), (0, 9)]);
        assert_eq!(p.stalls, vec![(7, 2, 40)]);
        assert!((p.transient - 0.25).abs() < 1e-12);
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_empty_spec_is_empty_plan() {
        let p = FaultPlan::parse("").expect("empty spec");
        assert_eq!(p, FaultPlan::default());
        assert!(p.is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "kill",
            "kill=3",
            "kill=a@b",
            "transient=2.0",
            "transient=-0.1",
            "transient=x",
            "stall=7@2",
            "stall=7",
            "seed=",
            "warp=9",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must not parse");
        }
    }

    #[test]
    fn transient_decisions_are_deterministic_and_rate_shaped() {
        let p = FaultPlan { seed: 7, transient: 0.3, ..Default::default() };
        let q = FaultPlan { seed: 7, transient: 0.3, ..Default::default() };
        let mut fails = 0;
        for id in 0..2000 {
            let a = p.transient_fails(id, 0);
            assert_eq!(a, q.transient_fails(id, 0), "same seed must agree at id {id}");
            fails += a as usize;
        }
        // 2000 draws at p=0.3: far from both 0 and 2000 with margin
        assert!((400..=800).contains(&fails), "observed {fails}/2000 at p=0.3");
        // a retry is a fresh draw, not a replay of attempt 0
        assert!(
            (0..2000).any(|id| p.transient_fails(id, 0) != p.transient_fails(id, 1)),
            "attempts must draw independently"
        );
    }

    #[test]
    fn transient_rate_extremes() {
        let never = FaultPlan::default();
        let always = FaultPlan { transient: 1.0, ..Default::default() };
        for id in 0..64 {
            assert!(!never.transient_fails(id, 0));
            assert!(always.transient_fails(id, 0));
        }
    }

    #[test]
    fn injector_kill_panics_only_on_its_replica_and_round() {
        let plan = FaultPlan::parse("kill=1@3").expect("valid spec");
        plan.injector_for(0).tick_round(3); // other replica: no panic
        plan.injector_for(1).tick_round(2); // other round: no panic
        let hit = std::panic::catch_unwind(|| plan.injector_for(1).tick_round(3));
        let payload = hit.err().expect("kill must panic");
        let msg = crate::util::pool::panic_message(payload.as_ref());
        assert!(msg.contains("replica 1 killed at round 3"), "{msg:?}");
    }

    #[test]
    fn stall_is_noop_without_a_match() {
        let plan = FaultPlan::parse("stall=7@2x1").expect("valid spec");
        let inj = plan.injector_for(0);
        inj.maybe_stall(6, 2); // other id
        inj.maybe_stall(7, 1); // other round
    }
}

//! Multi-replica serving front-end: a [`Router`] over N independent
//! [`Scheduler`] replicas with prefix-affinity placement, queue-depth
//! balancing, deadline-aware spillover and explicit load shedding.
//!
//! Placement runs a strict four-step cascade per request:
//!
//! 1. **Affinity** — a consistent-hash ring (FNV-1a over the first
//!    [`RouterOpts::affinity_tokens`] prompt tokens, [`RouterOpts::virtual_nodes`]
//!    virtual nodes per replica) picks a home replica, so requests sharing a
//!    system-prompt prefix land on the same replica and hit its prefix cache.
//! 2. **Balance** — if the home replica's queue is at the admission
//!    watermark, the request diverts to the least-loaded replica instead.
//! 3. **Spillover** — if *every* replica is at the watermark but the request
//!    carries a deadline, it is admitted anyway on the least-loaded replica
//!    (pair with [`AdmissionPolicy::Deadline`] for earliest-deadline-first
//!    ordering under saturation).
//! 4. **Shed** — otherwise the request is refused immediately with
//!    [`FinishReason::Rejected`]: its sink is notified, a completion is
//!    synthesized, and no replica ever sees it.
//!
//! **Bit-identity across replica counts.** Each request samples from its own
//! RNG stream (`Pcg64::with_stream(seed, id)`) and decodes independently of
//! its batch-mates, so *which* replica serves a request cannot change its
//! tokens: completions are bit-identical across `replicas` ∈ {1, 2, 4} and
//! prefix-cache on/off for every non-shed request (pinned by
//! `completions_bit_identical_across_replica_counts`).
//!
//! [`AdmissionPolicy::Deadline`]: crate::serve::AdmissionPolicy::Deadline

use crate::model::native::DecoderParams;
use crate::obs::router::{record_route, RouteOutcome};
use crate::serve::{
    Completion, FinishReason, Request, RequestTiming, Scheduler, ServeMetrics, ServeOpts,
    ServeStats,
};

/// Router knobs (per-replica engine knobs live in [`ServeOpts`]).
#[derive(Debug, Clone, Copy)]
pub struct RouterOpts {
    /// Scheduler replicas to fan out over (clamped to ≥ 1).
    pub replicas: usize,
    /// Per-replica queued-request watermark: a replica with this many
    /// requests already queued is *saturated* and refuses non-deadline
    /// work once every replica is saturated.  `0` = unbounded (never shed).
    pub shed_watermark: usize,
    /// Prompt tokens hashed for prefix-affinity placement.  Requests whose
    /// prompts agree on this many leading tokens route to the same replica.
    pub affinity_tokens: usize,
    /// Virtual nodes per replica on the consistent-hash ring; more nodes
    /// spread distinct prefixes more evenly at the cost of a larger ring.
    pub virtual_nodes: usize,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts { replicas: 1, shed_watermark: 0, affinity_tokens: 16, virtual_nodes: 32 }
    }
}

/// Routing outcome totals for one [`Router`] (cumulative since creation)
/// plus the per-replica engine stats from the most recent [`Router::run`].
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    /// Requests submitted to the router (all four outcomes).
    pub submitted: usize,
    /// Requests placed on their consistent-hash home replica.
    pub affinity_routed: usize,
    /// Requests diverted to the least-loaded replica because the home
    /// replica was at the watermark.
    pub balanced: usize,
    /// Deadline-carrying requests admitted past the watermark with every
    /// replica saturated.
    pub spilled: usize,
    /// Requests refused with [`FinishReason::Rejected`] before reaching any
    /// replica.
    pub shed: usize,
    /// Engine stats per replica from the last `run` call, indexed by
    /// replica.
    pub per_replica: Vec<ServeStats>,
}

impl RouterStats {
    /// Fraction of submitted requests shed (0 when nothing was submitted).
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted as f64
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = FNV_OFFSET;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A front-end distributing requests over N [`Scheduler`] replicas sharing
/// one set of decoder parameters.  See the module docs for the placement
/// cascade and the bit-identity guarantee.
pub struct Router<'a, P: DecoderParams + ?Sized> {
    replicas: Vec<Scheduler<'a, P>>,
    opts: RouterOpts,
    /// Consistent-hash ring: `(point, replica)` sorted by point.
    ring: Vec<(u64, usize)>,
    /// Completions synthesized for shed requests, drained by `run`.
    shed_done: Vec<Completion>,
    submitted: usize,
    affinity_routed: usize,
    balanced: usize,
    spilled: usize,
    shed: usize,
}

impl<'a, P: DecoderParams + ?Sized> Router<'a, P> {
    /// Build a router with `opts.replicas` schedulers over `params`, every
    /// replica configured with the same `serve` knobs (notably the same
    /// `seed` — per-request RNG streams make placement seed-neutral).
    pub fn new(params: &'a P, opts: RouterOpts, serve: ServeOpts) -> Router<'a, P> {
        let n = opts.replicas.max(1);
        let replicas = (0..n).map(|_| Scheduler::new(params, serve)).collect();
        let mut ring: Vec<(u64, usize)> = (0..n)
            .flat_map(|r| {
                (0..opts.virtual_nodes.max(1)).map(move |v| {
                    let point = fnv1a(
                        (r as u64).to_le_bytes().into_iter().chain((v as u64).to_le_bytes()),
                    );
                    (point, r)
                })
            })
            .collect();
        // tie-break on replica index so the ring is deterministic even if
        // two virtual nodes hash to the same point
        ring.sort_unstable();
        Router {
            replicas,
            opts,
            ring,
            shed_done: Vec::new(),
            submitted: 0,
            affinity_routed: 0,
            balanced: 0,
            spilled: 0,
            shed: 0,
        }
    }

    /// Attach a draft model to every replica for speculative decoding
    /// (effective once `ServeOpts::spec > 0`).
    pub fn with_draft(mut self, draft: &'a dyn DecoderParams) -> Router<'a, P> {
        self.replicas = self.replicas.into_iter().map(|s| s.with_draft(draft)).collect();
        self
    }

    /// Number of scheduler replicas.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Queued requests summed over all replicas.
    pub fn pending(&self) -> usize {
        self.replicas.iter().map(|r| r.pending()).sum()
    }

    /// The consistent-hash home replica for `prompt`.
    fn affinity_replica(&self, prompt: &[i32]) -> usize {
        let key = fnv1a(
            prompt
                .iter()
                .take(self.opts.affinity_tokens)
                .flat_map(|t| t.to_le_bytes()),
        );
        let i = self.ring.partition_point(|&(p, _)| p < key);
        self.ring[i % self.ring.len()].1
    }

    /// The replica with the shortest queue (lowest index on ties, so
    /// placement is deterministic).
    fn least_loaded(&self) -> usize {
        let mut best = 0;
        for (i, r) in self.replicas.iter().enumerate().skip(1) {
            if r.pending() < self.replicas[best].pending() {
                best = i;
            }
        }
        best
    }

    /// Route one request through the placement cascade.  Shed requests are
    /// finished immediately (sink notified, completion synthesized) and
    /// surface in the next [`Router::run`] result with
    /// [`FinishReason::Rejected`].
    pub fn submit(&mut self, mut req: Request) {
        self.submitted += 1;
        let cap =
            if self.opts.shed_watermark == 0 { usize::MAX } else { self.opts.shed_watermark };
        let home = self.affinity_replica(&req.prompt);
        if self.replicas[home].pending() < cap {
            self.affinity_routed += 1;
            record_route(RouteOutcome::Affinity);
            self.replicas[home].submit(req);
            return;
        }
        let target = self.least_loaded();
        if self.replicas[target].pending() < cap {
            self.balanced += 1;
            record_route(RouteOutcome::Balanced);
            self.replicas[target].submit(req);
            return;
        }
        if req.deadline_ms.is_some() {
            self.spilled += 1;
            record_route(RouteOutcome::Spillover);
            self.replicas[target].submit(req);
            return;
        }
        self.shed += 1;
        record_route(RouteOutcome::Shed);
        let reason = FinishReason::Rejected(format!(
            "shed: all {} replicas at watermark {}",
            self.replicas.len(),
            self.opts.shed_watermark
        ));
        if let Some(sink) = req.sink.as_mut() {
            sink.on_finish(&reason);
        }
        self.shed_done.push(Completion {
            id: req.id,
            prompt: std::mem::take(&mut req.prompt),
            generated: Vec::new(),
            finish: reason,
            timing: RequestTiming::default(),
        });
    }

    /// Drain every replica — each on its own OS thread — and return the
    /// merged completions (replica results plus shed completions, sorted by
    /// request id) with the routing stats.  Callable repeatedly: each call
    /// serves the requests submitted since the previous one.
    pub fn run(&mut self) -> (Vec<Completion>, RouterStats) {
        let results: Vec<(Vec<Completion>, ServeStats)> = std::thread::scope(|scope| {
            let handles: Vec<_> =
                self.replicas.iter_mut().map(|r| scope.spawn(|| r.run())).collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(std::panic::resume_unwind))
                .collect()
        });
        let mut done: Vec<Completion> = std::mem::take(&mut self.shed_done);
        let mut per_replica = Vec::with_capacity(results.len());
        for (completions, stats) in results {
            done.extend(completions);
            per_replica.push(stats);
        }
        done.sort_by_key(|c| c.id);
        let stats = RouterStats {
            submitted: self.submitted,
            affinity_routed: self.affinity_routed,
            balanced: self.balanced,
            spilled: self.spilled,
            shed: self.shed,
            per_replica,
        };
        (done, stats)
    }

    /// Engine metrics merged across all replicas (histograms bucket-exact —
    /// see `ServeMetrics::merge`).
    pub fn aggregate_metrics(&self) -> ServeMetrics {
        let mut m = ServeMetrics::new();
        for r in &self.replicas {
            m.merge(r.metrics());
        }
        m
    }

    /// Per-replica engine metrics, indexed by replica.
    pub fn replica_metrics(&self, replica: usize) -> &ServeMetrics {
        self.replicas[replica].metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OptConfig, Weights};
    use crate::serve::stream::TokenSink;
    use crate::util::propcheck;
    use crate::util::rng::Pcg64;
    use crate::util::sampling::Sampler;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn test_weights() -> Weights {
        Weights::random(OptConfig::test_config(), 3)
    }

    /// Sink counting `on_finish` calls (shared across requests).
    struct CountFinish(Arc<AtomicUsize>);

    impl TokenSink for CountFinish {
        fn on_token(&mut self, _token: i32, _index: usize) {}
        fn on_finish(&mut self, _reason: &FinishReason) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// A workload with shared prefixes: `families` distinct system prompts,
    /// `n` requests cycling over them with varied tails and samplers.
    fn requests(n: usize, families: usize, vocab: usize, rng_seed: u64) -> Vec<Request> {
        let mut rng = Pcg64::new(rng_seed);
        let prefixes: Vec<Vec<i32>> = (0..families)
            .map(|_| (0..6).map(|_| rng.below(vocab) as i32).collect())
            .collect();
        (0..n)
            .map(|i| {
                let mut prompt = prefixes[i % families].clone();
                prompt.extend((0..1 + i % 3).map(|_| rng.below(vocab) as i32));
                Request::new(
                    i,
                    prompt,
                    2 + i % 4,
                    if i % 2 == 0 {
                        Sampler::Greedy
                    } else {
                        Sampler::TopK { k: 4, temperature: 0.9 }
                    },
                )
            })
            .collect()
    }

    #[test]
    fn completions_bit_identical_across_replica_counts() {
        let w = test_weights();
        let serve = ServeOpts { max_batch: 2, ..Default::default() };
        let reference: Vec<Completion> = {
            let mut router = Router::new(&w, RouterOpts::default(), serve);
            for r in requests(10, 3, w.config.vocab, 11) {
                router.submit(r);
            }
            router.run().0
        };
        assert_eq!(reference.len(), 10);
        for replicas in [1usize, 2, 4] {
            for prefix_cache in [false, true] {
                let opts = RouterOpts { replicas, ..Default::default() };
                let mut router = Router::new(&w, opts, ServeOpts { prefix_cache, ..serve });
                for r in requests(10, 3, w.config.vocab, 11) {
                    router.submit(r);
                }
                let (done, stats) = router.run();
                assert_eq!(stats.shed, 0, "unbounded router must not shed");
                assert_eq!(
                    done, reference,
                    "completions diverged at replicas={replicas} prefix={prefix_cache}"
                );
            }
        }
    }

    #[test]
    fn affinity_groups_shared_prefixes_on_one_replica() {
        let w = test_weights();
        // affinity_tokens = 6 covers exactly the shared prefix, so the
        // varied tails don't perturb the hash
        let opts = RouterOpts { replicas: 4, affinity_tokens: 6, ..Default::default() };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        let reqs = requests(8, 1, w.config.vocab, 7);
        for r in reqs {
            router.submit(r);
        }
        let loaded: Vec<usize> =
            (0..4).map(|i| router.replicas[i].pending()).filter(|&p| p > 0).collect();
        assert_eq!(loaded, vec![8], "one replica owns the whole prefix family");
        let (done, stats) = router.run();
        assert_eq!(done.len(), 8);
        assert_eq!(stats.affinity_routed, 8);
        assert_eq!(stats.balanced + stats.spilled + stats.shed, 0);
    }

    #[test]
    fn watermark_balances_then_sheds_and_always_completes() {
        let w = test_weights();
        let opts = RouterOpts {
            replicas: 2,
            shed_watermark: 3,
            affinity_tokens: 6,
            ..Default::default()
        };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        let finishes = Arc::new(AtomicUsize::new(0));
        let n = 10;
        for mut r in requests(n, 1, w.config.vocab, 13) {
            r.sink = Some(Box::new(CountFinish(Arc::clone(&finishes))));
            router.submit(r);
        }
        let (done, stats) = router.run();
        // 2 replicas × watermark 3 admit 6; the rest shed
        assert_eq!(stats.shed, n - 6);
        assert!(stats.balanced > 0, "overflow past the home replica must balance first");
        assert_eq!(done.len(), n, "every request yields a completion, shed included");
        assert_eq!(finishes.load(Ordering::SeqCst), n, "every sink sees Finish, shed included");
        for c in &done {
            match &c.finish {
                FinishReason::Rejected(msg) => {
                    assert!(msg.contains("shed"), "{msg}");
                    assert!(c.generated.is_empty());
                }
                _ => assert!(!c.generated.is_empty()),
            }
        }
        // non-shed completions are bit-identical to an unbounded single replica
        let mut single = Router::new(&w, RouterOpts::default(), ServeOpts::default());
        for r in requests(n, 1, w.config.vocab, 13) {
            single.submit(r);
        }
        let (reference, _) = single.run();
        for c in done.iter().filter(|c| !matches!(c.finish, FinishReason::Rejected(_))) {
            assert_eq!(c, &reference[c.id], "non-shed request {} diverged", c.id);
        }
    }

    #[test]
    fn deadline_requests_spill_past_the_watermark() {
        let w = test_weights();
        let opts = RouterOpts {
            replicas: 2,
            shed_watermark: 1,
            affinity_tokens: 6,
            ..Default::default()
        };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        for (i, mut r) in requests(5, 1, w.config.vocab, 17).into_iter().enumerate() {
            if i >= 3 {
                r = r.with_deadline_ms(50 + i as u64);
            }
            router.submit(r);
        }
        let (done, stats) = router.run();
        assert_eq!(stats.spilled, 2, "deadline-carrying requests are admitted, not shed");
        assert_eq!(stats.shed, 1, "the saturated no-deadline request sheds");
        assert_eq!(done.len(), 5);
        let served = done.iter().filter(|c| !matches!(c.finish, FinishReason::Rejected(_)));
        assert_eq!(served.count(), 4);
    }

    #[test]
    fn run_is_repeatable_and_stats_accumulate() {
        let w = test_weights();
        let mut router =
            Router::new(&w, RouterOpts { replicas: 2, ..Default::default() }, ServeOpts::default());
        let reqs = requests(6, 2, w.config.vocab, 19);
        let mut all = Vec::new();
        for wave in reqs.chunks(3) {
            for r in wave {
                router.submit(Request::new(r.id, r.prompt.clone(), r.max_new, r.sampler));
            }
            let (done, _) = router.run();
            assert_eq!(done.len(), 3);
            all.extend(done);
        }
        let (_, stats) = router.run();
        assert_eq!(stats.submitted, 6, "routing counters are cumulative");
        assert_eq!(all.len(), 6);
        let m = router.aggregate_metrics();
        assert_eq!(m.finished_length as usize + m.finished_stop as usize, 6);
    }

    #[test]
    fn aggregate_metrics_match_per_replica_sums() {
        let w = test_weights();
        let mut router =
            Router::new(&w, RouterOpts { replicas: 4, ..Default::default() }, ServeOpts::default());
        for r in requests(12, 4, w.config.vocab, 23) {
            router.submit(r);
        }
        let (done, _) = router.run();
        assert_eq!(done.len(), 12);
        let agg = router.aggregate_metrics();
        let ttft_total: u64 = (0..4).map(|i| router.replica_metrics(i).ttft.count()).sum();
        assert_eq!(agg.ttft.count(), ttft_total);
        assert_eq!(agg.ttft.count(), 12);
    }

    #[test]
    fn ring_lookup_is_total_and_stable() {
        let w = test_weights();
        let router =
            Router::new(&w, RouterOpts { replicas: 3, ..Default::default() }, ServeOpts::default());
        propcheck::check("affinity ring lookup", 64, |rng| {
            let prompt: Vec<i32> =
                (0..1 + rng.below(24)).map(|_| rng.below(1 << 20) as i32).collect();
            let a = router.affinity_replica(&prompt);
            let b = router.affinity_replica(&prompt);
            propcheck::ensure(a == b, "lookup must be deterministic")?;
            propcheck::ensure(a < 3, "replica index in range")
        });
    }
}

//! Multi-replica serving front-end: a [`Router`] over N independent
//! [`Scheduler`] replicas with prefix-affinity placement, queue-depth
//! balancing, deadline-aware spillover and explicit load shedding.
//!
//! Placement runs a strict four-step cascade per request:
//!
//! 1. **Affinity** — a consistent-hash ring (FNV-1a over the first
//!    [`RouterOpts::affinity_tokens`] prompt tokens, [`RouterOpts::virtual_nodes`]
//!    virtual nodes per replica) picks a home replica, so requests sharing a
//!    system-prompt prefix land on the same replica and hit its prefix cache.
//! 2. **Balance** — if the home replica's queue is at the admission
//!    watermark, the request diverts to the least-loaded replica instead.
//! 3. **Spillover** — if *every* replica is at the watermark but the request
//!    carries a deadline, it is admitted anyway on the least-loaded replica
//!    (pair with [`AdmissionPolicy::Deadline`] for earliest-deadline-first
//!    ordering under saturation).
//! 4. **Shed** — otherwise the request is refused immediately with
//!    [`FinishReason::Rejected`]: its sink is notified, a completion is
//!    synthesized, and no replica ever sees it.
//!
//! **Bit-identity across replica counts.** Each request samples from its own
//! RNG stream (`Pcg64::with_stream(seed, id)`) and decodes independently of
//! its batch-mates, so *which* replica serves a request cannot change its
//! tokens: completions are bit-identical across `replicas` ∈ {1, 2, 4} and
//! prefix-cache on/off for every non-shed request (pinned by
//! `completions_bit_identical_across_replica_counts`).
//!
//! **Supervision and fault tolerance.** [`Router::run`] executes every
//! replica under `catch_unwind`.  When a replica thread dies the router
//! marks it dead (it never receives work again), recovers its still-queued
//! requests — sinks intact — straight from the scheduler's queue, rebuilds
//! its in-flight requests from retained [`RetrySpec`]s (sink lost with the
//! thread), and redispatches everything to surviving replicas with bounded
//! retries and exponential backoff ([`RouterOpts::max_retries`] /
//! [`RouterOpts::retry_backoff_ms`]).  Completion is **at-most-once by
//! request id**: a dead replica's unreported completions died with its
//! thread, so a redispatched request completes exactly once, and every
//! submitted request yields exactly one [`Completion`] — the unrecoverable
//! tail finishes [`FinishReason::Failed`].  Redispatched requests stay
//! bit-identical to a fault-free run (per-request RNG streams are
//! placement-neutral).  [`Router::shutdown`] drains gracefully: admission
//! stops, queued and in-flight work finishes under the same supervision,
//! and a [`DrainSummary`] reports the account.
//!
//! [`AdmissionPolicy::Deadline`]: crate::serve::AdmissionPolicy::Deadline

// DETERMINISM: BTreeMap/BTreeSet (deliberately not Hash*) back the
// supervision bookkeeping, so orphan recovery and redispatch iterate in
// request-id order and chaos runs replay bit-for-bit.
use std::collections::{BTreeMap, BTreeSet};

use crate::model::native::DecoderParams;
use crate::obs::fault::{record_fault, FaultEvent};
use crate::obs::router::{record_route, RouteOutcome};
use crate::serve::fault::FaultPlan;
use crate::serve::{
    Completion, FinishReason, Request, RequestTiming, Scheduler, ServeMetrics, ServeOpts,
    ServeStats,
};
use crate::util::sampling::Sampler;

/// Router knobs (per-replica engine knobs live in [`ServeOpts`]).
#[derive(Debug, Clone, Copy)]
pub struct RouterOpts {
    /// Scheduler replicas to fan out over (clamped to ≥ 1).
    pub replicas: usize,
    /// Per-replica queued-request watermark: a replica with this many
    /// requests already queued is *saturated* and refuses non-deadline
    /// work once every replica is saturated.  `0` = unbounded (never shed).
    pub shed_watermark: usize,
    /// Prompt tokens hashed for prefix-affinity placement.  Requests whose
    /// prompts agree on this many leading tokens route to the same replica.
    pub affinity_tokens: usize,
    /// Virtual nodes per replica on the consistent-hash ring; more nodes
    /// spread distinct prefixes more evenly at the cost of a larger ring.
    pub virtual_nodes: usize,
    /// Redispatch attempts per request after a replica death or injected
    /// transient fault, before the request finishes
    /// [`FinishReason::Failed`].
    pub max_retries: usize,
    /// Base of the exponential redispatch backoff in milliseconds (doubles
    /// per attempt, capped at 16× the base; `0` disables the sleep).
    pub retry_backoff_ms: u64,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts {
            replicas: 1,
            shed_watermark: 0,
            affinity_tokens: 16,
            virtual_nodes: 32,
            max_retries: 2,
            retry_backoff_ms: 1,
        }
    }
}

/// Routing outcome totals for one [`Router`] (cumulative since creation)
/// plus the per-replica engine stats from the most recent [`Router::run`].
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    /// Requests submitted to the router (all four outcomes).
    pub submitted: usize,
    /// Requests placed on their consistent-hash home replica.
    pub affinity_routed: usize,
    /// Requests diverted to the least-loaded replica because the home
    /// replica was at the watermark.
    pub balanced: usize,
    /// Deadline-carrying requests admitted past the watermark with every
    /// replica saturated.
    pub spilled: usize,
    /// Requests refused with [`FinishReason::Rejected`] before reaching any
    /// replica.
    pub shed: usize,
    /// Replica threads that died (panicked) over the router's lifetime.
    pub replica_deaths: usize,
    /// Redispatch attempts performed (orphaned or transiently-refused
    /// requests resubmitted to surviving replicas).
    pub redispatched: usize,
    /// Requests that exhausted their retry budget (or found no live
    /// replica) and finished [`FinishReason::Failed`].
    pub failed_requests: usize,
    /// Ids of every request a fault ever touched (orphaned by a replica
    /// death or refused by an injected transient error), sorted.  Requests
    /// *not* listed here were served on a fault-free path and are
    /// guaranteed bit-identical to a no-fault run.
    pub fault_touched: Vec<usize>,
    /// Engine stats per replica from the last `run` call, indexed by
    /// replica (supervision re-runs of one replica are folded in).
    pub per_replica: Vec<ServeStats>,
}

impl RouterStats {
    /// Fraction of submitted requests shed (0 when nothing was submitted).
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted as f64
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = FNV_OFFSET;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// What the router retains per placed request so it can rebuild and
/// redispatch the request if the owning replica dies mid-run.  The
/// streaming sink cannot be retained — it moves into the replica with the
/// request and is lost with the thread — so a redispatched *in-flight*
/// request re-runs sink-less (still-*queued* requests are recovered from
/// the dead scheduler with their sinks intact); its completion tokens are
/// unaffected either way (per-request RNG streams).  A rebuilt deadline
/// restarts from the redispatch instant.
struct RetrySpec {
    prompt: Vec<i32>,
    max_new: usize,
    sampler: Sampler,
    stop: Vec<i32>,
    stop_seqs: Vec<Vec<i32>>,
    priority: i32,
    deadline_ms: Option<u64>,
    /// Replica currently holding the request.
    replica: usize,
    /// Dispatch attempts already consumed beyond the first.
    attempts: usize,
}

impl RetrySpec {
    fn retain(req: &Request, replica: usize, attempts: usize) -> RetrySpec {
        RetrySpec {
            prompt: req.prompt.clone(),
            max_new: req.max_new,
            sampler: req.sampler,
            stop: req.stop.clone(),
            stop_seqs: req.stop_seqs.clone(),
            priority: req.priority,
            deadline_ms: req.deadline_ms,
            replica,
            attempts,
        }
    }

    fn rebuild(&mut self, id: usize) -> Request {
        let mut r =
            Request::new(id, std::mem::take(&mut self.prompt), self.max_new, self.sampler)
                .with_stop(std::mem::take(&mut self.stop))
                .with_stop_seqs(std::mem::take(&mut self.stop_seqs))
                .with_priority(self.priority);
        if let Some(ms) = self.deadline_ms {
            r = r.with_deadline_ms(ms);
        }
        r
    }
}

/// The account [`Router::shutdown`] returns after a graceful drain.
#[derive(Debug)]
pub struct DrainSummary {
    /// Requests still queued across replicas when the drain began.
    pub pending_at_shutdown: usize,
    /// Requests that finished [`FinishReason::Failed`] during the drain.
    pub failed: usize,
    /// Requests that finished [`FinishReason::TimedOut`] during the drain.
    pub timed_out: usize,
    /// Replica threads that died over the router's whole lifetime.
    pub replica_deaths: usize,
    /// Replicas still live after the drain.
    pub live_replicas: usize,
    /// Every completion the drain run produced (shed/refused included).
    pub completions: Vec<Completion>,
    /// The router stats as of the drain run (cumulative counters plus the
    /// drain's per-replica engine stats).
    pub stats: RouterStats,
}

impl DrainSummary {
    /// One-line human-readable account (what `--drain` prints).
    pub fn summary(&self) -> String {
        format!(
            "drained {} pending requests into {} completions ({} failed, {} timed out); \
             {} replica death(s), {}/{} replica(s) live",
            self.pending_at_shutdown,
            self.completions.len(),
            self.failed,
            self.timed_out,
            self.replica_deaths,
            self.live_replicas,
            self.live_replicas + self.replica_deaths,
        )
    }
}

/// A front-end distributing requests over N [`Scheduler`] replicas sharing
/// one set of decoder parameters.  See the module docs for the placement
/// cascade, the bit-identity guarantee and the supervision contract.
pub struct Router<'a, P: DecoderParams + ?Sized> {
    replicas: Vec<Scheduler<'a, P>>,
    opts: RouterOpts,
    /// Consistent-hash ring: `(point, replica)` sorted by point.
    ring: Vec<(u64, usize)>,
    /// Completions synthesized for shed/refused/failed requests, drained by
    /// `run`.
    shed_done: Vec<Completion>,
    /// Dead mask: `dead[i]` is set when replica `i`'s thread panicked; a
    /// dead replica never receives work again.
    dead: Vec<bool>,
    /// Retained rebuild specs for every request currently placed on a
    /// replica, keyed by request id (the supervision ledger).
    inflight: BTreeMap<usize, RetrySpec>,
    /// Deterministic fault plan under test, if any (chaos harness only).
    fault: Option<FaultPlan>,
    /// Set by [`Router::shutdown`]: admission refuses new work.
    draining: bool,
    /// Ids of requests a fault ever touched (orphaned or transiently
    /// refused); everything else is bit-identical to a no-fault run.
    fault_touched: BTreeSet<usize>,
    submitted: usize,
    affinity_routed: usize,
    balanced: usize,
    spilled: usize,
    shed: usize,
    replica_deaths: usize,
    redispatched: usize,
    failed_requests: usize,
}

impl<'a, P: DecoderParams + ?Sized> Router<'a, P> {
    /// Build a router with `opts.replicas` schedulers over `params`, every
    /// replica configured with the same `serve` knobs (notably the same
    /// `seed` — per-request RNG streams make placement seed-neutral).
    pub fn new(params: &'a P, opts: RouterOpts, serve: ServeOpts) -> Router<'a, P> {
        let n = opts.replicas.max(1);
        let replicas = (0..n).map(|_| Scheduler::new(params, serve)).collect();
        let mut ring: Vec<(u64, usize)> = (0..n)
            .flat_map(|r| {
                (0..opts.virtual_nodes.max(1)).map(move |v| {
                    let point = fnv1a(
                        (r as u64).to_le_bytes().into_iter().chain((v as u64).to_le_bytes()),
                    );
                    (point, r)
                })
            })
            .collect();
        // tie-break on replica index so the ring is deterministic even if
        // two virtual nodes hash to the same point
        ring.sort_unstable();
        Router {
            replicas,
            opts,
            ring,
            shed_done: Vec::new(),
            dead: vec![false; n],
            inflight: BTreeMap::new(),
            fault: None,
            draining: false,
            fault_touched: BTreeSet::new(),
            submitted: 0,
            affinity_routed: 0,
            balanced: 0,
            spilled: 0,
            shed: 0,
            replica_deaths: 0,
            redispatched: 0,
            failed_requests: 0,
        }
    }

    /// Attach a deterministic fault plan (see [`crate::serve::fault`]):
    /// every replica gets its injector (scripted kills and stalls) and the
    /// router applies the plan's transient dispatch errors at submit time.
    /// Chaos-testing only — a router without a plan pays nothing.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Router<'a, P> {
        for (i, r) in self.replicas.iter_mut().enumerate() {
            r.set_fault(plan.injector_for(i));
        }
        self.fault = Some(plan);
        self
    }

    /// Attach a draft model to every replica for speculative decoding
    /// (effective once `ServeOpts::spec > 0`).
    pub fn with_draft(mut self, draft: &'a dyn DecoderParams) -> Router<'a, P> {
        self.replicas = self.replicas.into_iter().map(|s| s.with_draft(draft)).collect();
        self
    }

    /// Number of scheduler replicas (dead ones included).
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Replicas whose threads have not died.
    pub fn live_replicas(&self) -> usize {
        self.dead.iter().filter(|d| !**d).count()
    }

    /// Queued requests summed over all replicas.
    pub fn pending(&self) -> usize {
        self.replicas.iter().map(|r| r.pending()).sum()
    }

    /// The consistent-hash home replica for `prompt` — where the placement
    /// cascade tries first.  Public so operators (and the chaos bench) can
    /// ask "which replica would serve this?" without submitting.
    pub fn affinity_replica(&self, prompt: &[i32]) -> usize {
        let key = fnv1a(
            prompt
                .iter()
                .take(self.opts.affinity_tokens)
                .flat_map(|t| t.to_le_bytes()),
        );
        let i = self.ring.partition_point(|&(p, _)| p < key);
        self.ring[i % self.ring.len()].1
    }

    /// The live replica with the shortest queue (lowest index on ties, so
    /// placement is deterministic); `None` when every replica is dead.
    fn least_loaded_live(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if self.dead[i] {
                continue;
            }
            if best.is_none_or(|b| r.pending() < self.replicas[b].pending()) {
                best = Some(i);
            }
        }
        best
    }

    /// Finish `req` immediately with `reason`: notify the sink and park a
    /// synthesized completion for the next `run` to surface.
    fn finish_now(&mut self, mut req: Request, reason: FinishReason) {
        if let Some(sink) = req.sink.as_mut() {
            sink.on_finish(&reason);
        }
        self.shed_done.push(Completion {
            id: req.id,
            prompt: std::mem::take(&mut req.prompt),
            generated: Vec::new(),
            finish: reason,
            timing: RequestTiming::default(),
        });
    }

    /// Exponential backoff before redispatch `attempt` (1-based):
    /// `retry_backoff_ms << (attempt - 1)`, capped at 16× the base; a base
    /// of 0 disables the sleep entirely.
    fn backoff(&self, attempt: usize) {
        if self.opts.retry_backoff_ms == 0 {
            return;
        }
        let factor = 1u64 << attempt.saturating_sub(1).min(4);
        std::thread::sleep(std::time::Duration::from_millis(
            self.opts.retry_backoff_ms.saturating_mul(factor),
        ));
    }

    /// Route one request through the placement cascade.  Shed requests are
    /// finished immediately (sink notified, completion synthesized) and
    /// surface in the next [`Router::run`] result with
    /// [`FinishReason::Rejected`].  When a fault plan injects transient
    /// dispatch errors, each error consumes one retry (with backoff); a
    /// request whose budget the injector exhausts finishes
    /// [`FinishReason::Failed`].  A draining router refuses everything.
    pub fn submit(&mut self, mut req: Request) {
        self.submitted += 1;
        if self.draining {
            self.shed += 1;
            record_route(RouteOutcome::Shed);
            let reason = FinishReason::Rejected(format!(
                "request {}: router is draining, admission stopped",
                req.id
            ));
            self.finish_now(req, reason);
            return;
        }
        let mut attempts = 0usize;
        if let Some(plan) = self.fault.clone() {
            while plan.transient_fails(req.id, attempts) {
                record_fault(FaultEvent::TransientInjected);
                self.fault_touched.insert(req.id);
                if attempts >= self.opts.max_retries {
                    let reason = FinishReason::Failed(format!(
                        "request {}: injected transient fault persisted through {attempts} \
                         retries",
                        req.id
                    ));
                    self.failed_requests += 1;
                    record_fault(FaultEvent::RequestFailed);
                    self.finish_now(req, reason);
                    return;
                }
                attempts += 1;
                self.backoff(attempts);
                self.redispatched += 1;
                record_fault(FaultEvent::Redispatch);
            }
        }
        self.place(req, attempts, false);
    }

    /// Place a request on a replica (the module-doc cascade), skipping dead
    /// replicas.  `redispatch` placements bypass the shed watermark —
    /// shedding already-admitted work would break the exactly-one-completion
    /// contract — and don't touch the routing counters.
    fn place(&mut self, req: Request, attempts: usize, redispatch: bool) {
        let cap =
            if self.opts.shed_watermark == 0 { usize::MAX } else { self.opts.shed_watermark };
        let home = self.affinity_replica(&req.prompt);
        let choice: Option<(usize, Option<RouteOutcome>)> = if redispatch {
            self.least_loaded_live().map(|t| (t, None))
        } else if !self.dead[home] && self.replicas[home].pending() < cap {
            Some((home, Some(RouteOutcome::Affinity)))
        } else {
            match self.least_loaded_live() {
                Some(t) if self.replicas[t].pending() < cap => {
                    Some((t, Some(RouteOutcome::Balanced)))
                }
                Some(t) if req.deadline_ms.is_some() => Some((t, Some(RouteOutcome::Spillover))),
                _ => None,
            }
        };
        match choice {
            Some((target, outcome)) => {
                match outcome {
                    Some(RouteOutcome::Affinity) => self.affinity_routed += 1,
                    Some(RouteOutcome::Balanced) => self.balanced += 1,
                    Some(RouteOutcome::Spillover) => self.spilled += 1,
                    _ => {}
                }
                if let Some(o) = outcome {
                    record_route(o);
                }
                self.inflight.insert(req.id, RetrySpec::retain(&req, target, attempts));
                self.replicas[target].submit(req);
            }
            None if self.live_replicas() == 0 => {
                // every replica is dead: nothing can ever serve this
                let reason = FinishReason::Failed(format!(
                    "request {}: all {} replicas are dead",
                    req.id,
                    self.replicas.len()
                ));
                self.fault_touched.insert(req.id);
                self.failed_requests += 1;
                record_fault(FaultEvent::RequestFailed);
                self.finish_now(req, reason);
            }
            None => {
                self.shed += 1;
                record_route(RouteOutcome::Shed);
                let reason = FinishReason::Rejected(format!(
                    "shed: all {} replicas at watermark {}",
                    self.live_replicas(),
                    self.opts.shed_watermark
                ));
                self.finish_now(req, reason);
            }
        }
    }

    /// Drain every live replica — each on its own OS thread — and return
    /// the merged completions (replica results plus synthesized shed /
    /// refused / failed completions, sorted by request id) with the routing
    /// stats.  Callable repeatedly: each call serves the requests submitted
    /// since the previous one.
    ///
    /// Replicas run under `catch_unwind`: a replica that panics is marked
    /// dead, its still-queued requests are recovered with their sinks
    /// intact, its in-flight requests are rebuilt from retained specs, and
    /// all of them redispatch to surviving replicas (bounded by
    /// [`RouterOpts::max_retries`], backing off exponentially between
    /// passes).  Requests whose budget runs out — or that outlive the last
    /// replica — finish [`FinishReason::Failed`].  Every placed request
    /// surfaces exactly once: a dead replica's unreported completions died
    /// with its thread, so a redispatch can never duplicate one.
    pub fn run(&mut self) -> (Vec<Completion>, RouterStats) {
        let n = self.replicas.len();
        let mut done: Vec<Completion> = Vec::new();
        let mut per_replica: Vec<ServeStats> = vec![ServeStats::default(); n];
        let mut pass = 0usize;
        loop {
            done.append(&mut self.shed_done);
            let dead_mask = self.dead.clone();
            type ReplicaOutcome = std::thread::Result<(Vec<Completion>, ServeStats)>;
            let results: Vec<(usize, ReplicaOutcome)> = std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .replicas
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| !dead_mask[*i])
                    .map(|(i, r)| {
                        let h = scope.spawn(move || {
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| r.run()))
                        });
                        (i, h)
                    })
                    .collect();
                // the outer join error (a panic that escaped catch_unwind,
                // e.g. in a panic payload's Drop) folds into the same path
                handles.into_iter().map(|(i, h)| (i, h.join().unwrap_or_else(Err))).collect()
            });
            for (i, res) in results {
                match res {
                    Ok((completions, stats)) => {
                        for c in &completions {
                            self.inflight.remove(&c.id);
                        }
                        per_replica[i].merge(&stats);
                        done.extend(completions);
                    }
                    Err(payload) => {
                        self.dead[i] = true;
                        self.replica_deaths += 1;
                        record_fault(FaultEvent::ReplicaDeath);
                        let msg = crate::util::pool::panic_message(payload.as_ref());
                        crate::warn_!(
                            "replica {i} died ({msg}); redispatching its requests"
                        );
                    }
                }
            }
            // orphans: the supervision ledger still holds specs owned by a
            // replica that is now dead
            let orphan_ids: Vec<usize> = self
                .inflight
                .iter()
                .filter(|(_, s)| self.dead[s.replica])
                .map(|(&id, _)| id)
                .collect();
            if orphan_ids.is_empty() {
                break;
            }
            // recover still-queued requests (sinks intact) from the dead
            // schedulers; anything not recovered was in flight and gets
            // rebuilt sink-less from its retained spec
            let mut recovered: BTreeMap<usize, Request> = BTreeMap::new();
            for i in 0..n {
                if self.dead[i] {
                    for r in self.replicas[i].take_queue() {
                        recovered.insert(r.id, r);
                    }
                }
            }
            pass += 1;
            self.backoff(pass);
            let live_left = self.live_replicas();
            for id in orphan_ids {
                let Some(mut spec) = self.inflight.remove(&id) else { continue };
                self.fault_touched.insert(id);
                let give_up: Option<String> = if live_left == 0 {
                    Some(format!("request {id}: all replicas died, nothing left to serve it"))
                } else if spec.attempts >= self.opts.max_retries {
                    Some(format!(
                        "request {id}: replica died and all {} redispatch attempts are spent",
                        spec.attempts
                    ))
                } else {
                    None
                };
                match give_up {
                    Some(why) => {
                        let reason = FinishReason::Failed(why);
                        let mut prompt = std::mem::take(&mut spec.prompt);
                        if let Some(mut r) = recovered.remove(&id) {
                            if let Some(sink) = r.sink.as_mut() {
                                sink.on_finish(&reason);
                            }
                            prompt = std::mem::take(&mut r.prompt);
                        }
                        self.failed_requests += 1;
                        record_fault(FaultEvent::RequestFailed);
                        done.push(Completion {
                            id,
                            prompt,
                            generated: Vec::new(),
                            finish: reason,
                            timing: RequestTiming::default(),
                        });
                    }
                    None => {
                        let req = recovered.remove(&id).unwrap_or_else(|| spec.rebuild(id));
                        self.redispatched += 1;
                        record_fault(FaultEvent::Redispatch);
                        self.place(req, spec.attempts + 1, true);
                    }
                }
            }
        }
        done.append(&mut self.shed_done);
        done.sort_by_key(|c| c.id);
        let stats = RouterStats {
            submitted: self.submitted,
            affinity_routed: self.affinity_routed,
            balanced: self.balanced,
            spilled: self.spilled,
            shed: self.shed,
            replica_deaths: self.replica_deaths,
            redispatched: self.redispatched,
            failed_requests: self.failed_requests,
            fault_touched: self.fault_touched.iter().copied().collect(),
            per_replica,
        };
        (done, stats)
    }

    /// Graceful drain: stop admission (every later [`Router::submit`] is
    /// refused with [`FinishReason::Rejected`]), finish all queued work —
    /// replica supervision and redispatch stay active throughout — and
    /// report the account.  Further `run` calls remain legal and serve
    /// nothing new.
    pub fn shutdown(&mut self) -> DrainSummary {
        self.draining = true;
        let pending_at_shutdown = self.pending();
        let (completions, stats) = self.run();
        let failed =
            completions.iter().filter(|c| matches!(c.finish, FinishReason::Failed(_))).count();
        let timed_out =
            completions.iter().filter(|c| c.finish == FinishReason::TimedOut).count();
        DrainSummary {
            pending_at_shutdown,
            failed,
            timed_out,
            replica_deaths: stats.replica_deaths,
            live_replicas: self.live_replicas(),
            completions,
            stats,
        }
    }

    /// Engine metrics merged across all replicas (histograms bucket-exact —
    /// see `ServeMetrics::merge`).
    pub fn aggregate_metrics(&self) -> ServeMetrics {
        let mut m = ServeMetrics::new();
        for r in &self.replicas {
            m.merge(r.metrics());
        }
        m
    }

    /// Per-replica engine metrics, indexed by replica.
    pub fn replica_metrics(&self, replica: usize) -> &ServeMetrics {
        self.replicas[replica].metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OptConfig, Weights};
    use crate::serve::stream::TokenSink;
    use crate::util::propcheck;
    use crate::util::rng::Pcg64;
    use crate::util::sampling::Sampler;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn test_weights() -> Weights {
        Weights::random(OptConfig::test_config(), 3)
    }

    /// Sink counting `on_finish` calls (shared across requests).
    struct CountFinish(Arc<AtomicUsize>);

    impl TokenSink for CountFinish {
        fn on_token(&mut self, _token: i32, _index: usize) {}
        fn on_finish(&mut self, _reason: &FinishReason) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// A workload with shared prefixes: `families` distinct system prompts,
    /// `n` requests cycling over them with varied tails and samplers.
    fn requests(n: usize, families: usize, vocab: usize, rng_seed: u64) -> Vec<Request> {
        let mut rng = Pcg64::new(rng_seed);
        let prefixes: Vec<Vec<i32>> = (0..families)
            .map(|_| (0..6).map(|_| rng.below(vocab) as i32).collect())
            .collect();
        (0..n)
            .map(|i| {
                let mut prompt = prefixes[i % families].clone();
                prompt.extend((0..1 + i % 3).map(|_| rng.below(vocab) as i32));
                Request::new(
                    i,
                    prompt,
                    2 + i % 4,
                    if i % 2 == 0 {
                        Sampler::Greedy
                    } else {
                        Sampler::TopK { k: 4, temperature: 0.9 }
                    },
                )
            })
            .collect()
    }

    #[test]
    fn completions_bit_identical_across_replica_counts() {
        let w = test_weights();
        let serve = ServeOpts { max_batch: 2, ..Default::default() };
        let reference: Vec<Completion> = {
            let mut router = Router::new(&w, RouterOpts::default(), serve);
            for r in requests(10, 3, w.config.vocab, 11) {
                router.submit(r);
            }
            router.run().0
        };
        assert_eq!(reference.len(), 10);
        for replicas in [1usize, 2, 4] {
            for prefix_cache in [false, true] {
                let opts = RouterOpts { replicas, ..Default::default() };
                let mut router = Router::new(&w, opts, ServeOpts { prefix_cache, ..serve });
                for r in requests(10, 3, w.config.vocab, 11) {
                    router.submit(r);
                }
                let (done, stats) = router.run();
                assert_eq!(stats.shed, 0, "unbounded router must not shed");
                assert_eq!(
                    done, reference,
                    "completions diverged at replicas={replicas} prefix={prefix_cache}"
                );
            }
        }
    }

    #[test]
    fn affinity_groups_shared_prefixes_on_one_replica() {
        let w = test_weights();
        // affinity_tokens = 6 covers exactly the shared prefix, so the
        // varied tails don't perturb the hash
        let opts = RouterOpts { replicas: 4, affinity_tokens: 6, ..Default::default() };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        let reqs = requests(8, 1, w.config.vocab, 7);
        for r in reqs {
            router.submit(r);
        }
        let loaded: Vec<usize> =
            (0..4).map(|i| router.replicas[i].pending()).filter(|&p| p > 0).collect();
        assert_eq!(loaded, vec![8], "one replica owns the whole prefix family");
        let (done, stats) = router.run();
        assert_eq!(done.len(), 8);
        assert_eq!(stats.affinity_routed, 8);
        assert_eq!(stats.balanced + stats.spilled + stats.shed, 0);
    }

    #[test]
    fn watermark_balances_then_sheds_and_always_completes() {
        let w = test_weights();
        let opts = RouterOpts {
            replicas: 2,
            shed_watermark: 3,
            affinity_tokens: 6,
            ..Default::default()
        };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        let finishes = Arc::new(AtomicUsize::new(0));
        let n = 10;
        for mut r in requests(n, 1, w.config.vocab, 13) {
            r.sink = Some(Box::new(CountFinish(Arc::clone(&finishes))));
            router.submit(r);
        }
        let (done, stats) = router.run();
        // 2 replicas × watermark 3 admit 6; the rest shed
        assert_eq!(stats.shed, n - 6);
        assert!(stats.balanced > 0, "overflow past the home replica must balance first");
        assert_eq!(done.len(), n, "every request yields a completion, shed included");
        assert_eq!(finishes.load(Ordering::SeqCst), n, "every sink sees Finish, shed included");
        for c in &done {
            match &c.finish {
                FinishReason::Rejected(msg) => {
                    assert!(msg.contains("shed"), "{msg}");
                    assert!(c.generated.is_empty());
                }
                _ => assert!(!c.generated.is_empty()),
            }
        }
        // non-shed completions are bit-identical to an unbounded single replica
        let mut single = Router::new(&w, RouterOpts::default(), ServeOpts::default());
        for r in requests(n, 1, w.config.vocab, 13) {
            single.submit(r);
        }
        let (reference, _) = single.run();
        for c in done.iter().filter(|c| !matches!(c.finish, FinishReason::Rejected(_))) {
            assert_eq!(c, &reference[c.id], "non-shed request {} diverged", c.id);
        }
    }

    #[test]
    fn deadline_requests_spill_past_the_watermark() {
        let w = test_weights();
        let opts = RouterOpts {
            replicas: 2,
            shed_watermark: 1,
            affinity_tokens: 6,
            ..Default::default()
        };
        let mut router = Router::new(&w, opts, ServeOpts::default());
        for (i, mut r) in requests(5, 1, w.config.vocab, 17).into_iter().enumerate() {
            if i >= 3 {
                r = r.with_deadline_ms(50 + i as u64);
            }
            router.submit(r);
        }
        let (done, stats) = router.run();
        assert_eq!(stats.spilled, 2, "deadline-carrying requests are admitted, not shed");
        assert_eq!(stats.shed, 1, "the saturated no-deadline request sheds");
        assert_eq!(done.len(), 5);
        let served = done.iter().filter(|c| !matches!(c.finish, FinishReason::Rejected(_)));
        assert_eq!(served.count(), 4);
    }

    #[test]
    fn run_is_repeatable_and_stats_accumulate() {
        let w = test_weights();
        let mut router =
            Router::new(&w, RouterOpts { replicas: 2, ..Default::default() }, ServeOpts::default());
        let reqs = requests(6, 2, w.config.vocab, 19);
        let mut all = Vec::new();
        for wave in reqs.chunks(3) {
            for r in wave {
                router.submit(Request::new(r.id, r.prompt.clone(), r.max_new, r.sampler));
            }
            let (done, _) = router.run();
            assert_eq!(done.len(), 3);
            all.extend(done);
        }
        let (_, stats) = router.run();
        assert_eq!(stats.submitted, 6, "routing counters are cumulative");
        assert_eq!(all.len(), 6);
        let m = router.aggregate_metrics();
        assert_eq!(m.finished_length as usize + m.finished_stop as usize, 6);
    }

    #[test]
    fn aggregate_metrics_match_per_replica_sums() {
        let w = test_weights();
        let mut router =
            Router::new(&w, RouterOpts { replicas: 4, ..Default::default() }, ServeOpts::default());
        for r in requests(12, 4, w.config.vocab, 23) {
            router.submit(r);
        }
        let (done, _) = router.run();
        assert_eq!(done.len(), 12);
        let agg = router.aggregate_metrics();
        let ttft_total: u64 = (0..4).map(|i| router.replica_metrics(i).ttft.count()).sum();
        assert_eq!(agg.ttft.count(), ttft_total);
        assert_eq!(agg.ttft.count(), 12);
    }

    #[test]
    fn replica_death_redispatches_and_loses_nothing() {
        let w = test_weights();
        let serve = ServeOpts { max_batch: 2, ..Default::default() };
        let opts = RouterOpts { replicas: 4, retry_backoff_ms: 0, ..Default::default() };
        let reference: Vec<Completion> = {
            let mut router = Router::new(&w, opts, serve);
            for r in requests(12, 3, w.config.vocab, 29) {
                router.submit(r);
            }
            router.run().0
        };
        assert_eq!(reference.len(), 12);
        // kill the replica that owns request 0's prefix family, so the
        // victim is guaranteed to hold work when it dies at round 2
        let probe = requests(12, 3, w.config.vocab, 29);
        let victim = Router::new(&w, opts, serve).affinity_replica(&probe[0].prompt);
        let plan = FaultPlan::parse(&format!("seed=7,kill={victim}@2")).unwrap();
        let mut router = Router::new(&w, opts, serve).with_fault_plan(plan);
        for r in probe {
            router.submit(r);
        }
        let (done, stats) = router.run();
        assert_eq!(stats.replica_deaths, 1, "the victim must die at round 2");
        assert!(stats.redispatched > 0, "the victim's requests must redispatch");
        assert_eq!(router.live_replicas(), 3);
        let mut ids: Vec<usize> = done.iter().map(|c| c.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 12, "every request completes exactly once");
        let touched: BTreeSet<usize> = stats.fault_touched.iter().copied().collect();
        assert!(!touched.is_empty(), "the dead replica owned at least one request");
        // redispatched requests re-run from scratch on their own RNG
        // streams, so the whole result set — touched included — matches
        // the no-fault reference bit for bit
        assert_eq!(done, reference, "fault run diverged from the no-fault reference");
    }

    #[test]
    fn injected_transient_faults_retry_then_fail_when_persistent() {
        let w = test_weights();
        let opts = RouterOpts { replicas: 2, retry_backoff_ms: 0, ..Default::default() };
        // transient=1: every dispatch attempt is refused, so every request
        // exhausts its retry budget and fails without reaching a replica
        let plan = FaultPlan::parse("seed=3,transient=1").unwrap();
        let mut router = Router::new(&w, opts, ServeOpts::default()).with_fault_plan(plan);
        let finishes = Arc::new(AtomicUsize::new(0));
        for mut r in requests(4, 2, w.config.vocab, 31) {
            r.sink = Some(Box::new(CountFinish(Arc::clone(&finishes))));
            router.submit(r);
        }
        let (done, stats) = router.run();
        assert_eq!(done.len(), 4);
        assert_eq!(stats.failed_requests, 4);
        assert_eq!(stats.fault_touched.len(), 4);
        assert_eq!(finishes.load(Ordering::SeqCst), 4, "failed requests still notify sinks");
        for c in &done {
            match &c.finish {
                FinishReason::Failed(msg) => assert!(msg.contains("transient"), "{msg}"),
                other => panic!("expected Failed, got {other:?}"),
            }
            assert!(c.generated.is_empty());
        }
        // a mild rate: everything completes, and whatever the injector
        // touched either succeeded on retry or failed within budget
        let plan = FaultPlan::parse("seed=3,transient=0.3").unwrap();
        let mut router = Router::new(&w, opts, ServeOpts::default()).with_fault_plan(plan);
        for r in requests(8, 2, w.config.vocab, 31) {
            router.submit(r);
        }
        let (done, stats) = router.run();
        assert_eq!(done.len(), 8);
        assert!(stats.failed_requests <= stats.fault_touched.len());
        for c in &done {
            if !matches!(c.finish, FinishReason::Failed(_)) {
                assert!(!c.generated.is_empty(), "request {} served no tokens", c.id);
            }
        }
    }

    #[test]
    fn shutdown_drains_pending_work_and_refuses_new() {
        let w = test_weights();
        let mut router = Router::new(
            &w,
            RouterOpts { replicas: 2, ..Default::default() },
            ServeOpts::default(),
        );
        for r in requests(6, 2, w.config.vocab, 37) {
            router.submit(r);
        }
        let drain = router.shutdown();
        assert_eq!(drain.pending_at_shutdown, 6);
        assert_eq!(drain.completions.len(), 6, "a drain finishes everything in flight");
        assert_eq!((drain.failed, drain.timed_out, drain.replica_deaths), (0, 0, 0));
        assert_eq!(drain.live_replicas, 2);
        for c in &drain.completions {
            assert!(!c.generated.is_empty());
        }
        let s = drain.summary();
        assert!(s.contains("drained 6 pending requests"), "{s}");
        assert!(s.contains("2/2 replica(s) live"), "{s}");
        // admission is closed now: late work is refused, never queued
        router.submit(Request::new(100, vec![1, 2, 3], 2, Sampler::Greedy));
        assert_eq!(router.pending(), 0);
        let (done, _) = router.run();
        assert_eq!(done.len(), 1);
        match &done[0].finish {
            FinishReason::Rejected(msg) => assert!(msg.contains("draining"), "{msg}"),
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn chaos_random_fault_plans_preserve_serving_invariants() {
        // the chaos property: under ANY seeded fault plan, (a) every
        // submitted request yields exactly one completion, (b) requests the
        // faults never touched are bit-identical to a no-fault run, and
        // (c) every Failed completion was fault-touched
        let w = test_weights();
        propcheck::check("chaos fault plans", 6, |rng| {
            let n = 6 + rng.below(6);
            let replicas = 2 + rng.below(3);
            let families = 1 + rng.below(3);
            let traffic_seed = rng.next_u64() | 1;
            let opts =
                RouterOpts { replicas, retry_backoff_ms: 0, ..RouterOpts::default() };
            let serve = ServeOpts { max_batch: 2, ..ServeOpts::default() };
            let reference: Vec<Completion> = {
                let mut router = Router::new(&w, opts, serve);
                for r in requests(n, families, w.config.vocab, traffic_seed) {
                    router.submit(r);
                }
                router.run().0
            };
            let spec = format!(
                "seed={},kill={}@{},transient=0.{}",
                rng.next_u64() & 0xffff,
                rng.below(replicas),
                1 + rng.below(3),
                rng.below(3),
            );
            let plan = FaultPlan::parse(&spec)
                .map_err(|e| format!("plan {spec:?} failed to parse: {e}"))?;
            let mut router = Router::new(&w, opts, serve).with_fault_plan(plan);
            for r in requests(n, families, w.config.vocab, traffic_seed) {
                router.submit(r);
            }
            let (done, stats) = router.run();
            propcheck::ensure(
                done.len() == n,
                format!("plan {spec:?}: {} completions for {n} requests", done.len()),
            )?;
            let mut ids: Vec<usize> = done.iter().map(|c| c.id).collect();
            ids.dedup();
            propcheck::ensure(ids.len() == n, format!("plan {spec:?}: duplicate completions"))?;
            let touched: BTreeSet<usize> = stats.fault_touched.iter().copied().collect();
            for c in &done {
                if matches!(c.finish, FinishReason::Failed(_)) {
                    propcheck::ensure(
                        touched.contains(&c.id),
                        format!("plan {spec:?}: request {} failed untouched", c.id),
                    )?;
                } else if !touched.contains(&c.id) {
                    propcheck::ensure(
                        c == &reference[c.id],
                        format!("plan {spec:?}: untouched request {} diverged", c.id),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ring_lookup_is_total_and_stable() {
        let w = test_weights();
        let router =
            Router::new(&w, RouterOpts { replicas: 3, ..Default::default() }, ServeOpts::default());
        propcheck::check("affinity ring lookup", 64, |rng| {
            let prompt: Vec<i32> =
                (0..1 + rng.below(24)).map(|_| rng.below(1 << 20) as i32).collect();
            let a = router.affinity_replica(&prompt);
            let b = router.affinity_replica(&prompt);
            propcheck::ensure(a == b, "lookup must be deterministic")?;
            propcheck::ensure(a < 3, "replica index in range")
        });
    }
}

//! Production telemetry for the serve scheduler: fixed log-bucket latency
//! histograms (TTFT, inter-token), queue depth, prefix-cache hit rate and
//! live-KV accounting, serialized through [`crate::util::json`].
//!
//! Everything is fixed-size and allocation-free on the record path, so the
//! scheduler can stamp every token without perturbing the latencies it is
//! measuring.

use std::time::Duration;

use crate::model::native::KvDtype;
use crate::util::json::Json;

const N_BUCKETS: usize = 31;

/// Fixed log₂-bucket latency histogram: bucket `i` counts samples in
/// `[2^i µs, 2^(i+1) µs)`, covering 1 µs up to ~35 minutes.  Quantiles are
/// bucket upper bounds (≤ 2x overestimate), which is enough resolution for
/// p50/p95/p99 serving dashboards.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: [u64; N_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram { buckets: [0; N_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Fold `other`'s samples into this histogram (bucket-wise addition —
    /// exact, since both sides share the fixed bucket rule).  Used by the
    /// router to aggregate per-replica telemetry into a fleet view.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Number of log₂ buckets (exported for exporters/tests that walk the
    /// bucket array via [`Histogram::bucket`]).
    pub const N_BUCKETS: usize = N_BUCKETS;

    /// The documented bucket for a `us`-microsecond sample: `⌊log₂ us⌋`,
    /// with 0 µs clamped into bucket 0 and the top bucket catching
    /// everything ≥ 2³⁰ µs.  This is the *only* bucketing rule — `record`
    /// uses it verbatim, so exporters can reconstruct bucket membership.
    pub fn bucket_index(us: u64) -> usize {
        (us.max(1).ilog2() as usize).min(N_BUCKETS - 1)
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Samples recorded in bucket `i` (see [`Histogram::bucket_index`]).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i.min(N_BUCKETS - 1)]
    }

    /// Total recorded time (saturating at `u64::MAX` microseconds).
    pub fn sum(&self) -> Duration {
        Duration::from_micros(self.sum_us)
    }

    /// Mean recorded latency (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us / self.count)
        }
    }

    /// Largest recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket holding the `q`-quantile sample (clamped
    /// to the true maximum so p100 is exact).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                let upper = 1u64 << (i + 1).min(63);
                return Duration::from_micros(upper.min(self.max_us.max(1)));
            }
        }
        Duration::from_micros(self.max_us)
    }

    /// `{count, mean_us, p50_us, p95_us, p99_us, max_us}`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count as usize)
            .set("mean_us", self.mean().as_micros() as f64)
            .set("p50_us", self.quantile(0.50).as_micros() as f64)
            .set("p95_us", self.quantile(0.95).as_micros() as f64)
            .set("p99_us", self.quantile(0.99).as_micros() as f64)
            .set("max_us", self.max_us as f64)
    }
}

/// Maximum count tracked exactly by [`CountHistogram`] (larger samples
/// clamp into the last bucket).  Draft lengths are single digits in
/// practice, so 64 leaves ample headroom.
const COUNT_BUCKETS: usize = 65;

/// Fixed linear-bucket histogram over small non-negative counts — the
/// speculative accepted-length distribution (how many draft tokens each
/// verify step accepted).  Allocation-free on the record path, like
/// [`Histogram`].
#[derive(Debug, Clone)]
pub struct CountHistogram {
    buckets: [u64; COUNT_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for CountHistogram {
    fn default() -> CountHistogram {
        CountHistogram::new()
    }
}

impl CountHistogram {
    /// Empty histogram.
    pub fn new() -> CountHistogram {
        CountHistogram { buckets: [0; COUNT_BUCKETS], count: 0, sum: 0 }
    }

    /// Fold `other`'s samples into this histogram (bucket-wise addition).
    pub fn merge(&mut self, other: &CountHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Record one count sample.
    pub fn record(&mut self, n: usize) {
        self.buckets[n.min(COUNT_BUCKETS - 1)] += 1;
        self.count += 1;
        // saturating: a pathological token flood degrades the mean rather
        // than wrapping it (the Prometheus/JSON exporters read this sum)
        self.sum = self.sum.saturating_add(n as u64);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of all recorded samples (zero when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Total of all recorded samples (saturating at `u64::MAX`).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Samples recorded at exactly `n` (clamped into the last bucket).
    pub fn at(&self, n: usize) -> u64 {
        self.buckets[n.min(COUNT_BUCKETS - 1)]
    }

    /// `{count, mean, buckets: [per-value counts up to the largest seen]}`.
    pub fn to_json(&self) -> Json {
        let hi = self.buckets.iter().rposition(|&b| b > 0).map_or(0, |i| i + 1);
        Json::obj()
            .set("count", self.count as usize)
            .set("mean", self.mean())
            .set(
                "buckets",
                Json::Arr(self.buckets[..hi].iter().map(|&b| Json::from(b as usize)).collect()),
            )
    }
}

/// Telemetry for one scheduler run (or several — it accumulates).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Submit → first sampled token, per request.
    pub ttft: Histogram,
    /// Gap between consecutive tokens of one sequence, per decode step.
    pub inter_token: Histogram,
    /// Submit → admission into the running batch, per request.
    pub queue_wait: Histogram,
    /// Admission → first sampled token, per request
    /// (`ttft ≈ queue_wait + prefill` for any single request).
    pub prefill: Histogram,
    /// First sampled token → last sampled token, per request.
    pub decode: Histogram,
    queue_depth_sum: u64,
    queue_depth_max: usize,
    queue_samples: u64,
    /// Prefix-cache lookups (mirrors `serve::prefix::PrefixStats`).
    pub prefix_lookups: u64,
    /// Lookups that reused at least one cached token.
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped thanks to prefix reuse.
    pub prefix_hit_tokens: u64,
    /// Cache entries evicted to stay inside the page-byte budget.
    pub prefix_evictions: u64,
    /// Peak unique live KV bytes (active sequences + prefix cache, shared
    /// pages counted once).
    pub kv_live_bytes_peak: usize,
    /// What eager full-context allocation would have resident at the same
    /// peak (PR-2's per-sequence f32 `[max_seq, d_model]` stores — an
    /// f32 baseline regardless of `kv_dtype`, so quantized modes show
    /// their residency win against the same yardstick).
    pub kv_eager_bytes_peak: usize,
    /// Storage precision the run's KV caches used (labels the `kv` dump).
    pub kv_dtype: KvDtype,
    /// Requests that finished by generating `max_new` tokens.
    pub finished_length: u64,
    /// Requests that finished on a stop token / stop sequence.
    pub finished_stop: u64,
    /// Requests cancelled while queued or in flight.
    pub cancelled: u64,
    /// Requests rejected at admission (malformed, or shed by the router).
    pub rejected: u64,
    /// Requests whose deadline expired while queued (finished `TimedOut`
    /// before any KV allocation).
    pub timed_out: u64,
    /// Requests abandoned after an unrecoverable failure (replica death
    /// with retries exhausted, or a blown per-round budget).
    pub failed: u64,
    /// Speculative decoding: accepted draft tokens per verify step (the
    /// accepted-length histogram; one sample per chunked verify).
    pub spec_accept_len: CountHistogram,
    /// Tokens committed across all chunked verify steps (matched drafts
    /// plus the correction/bonus sample each).
    pub spec_committed_tokens: u64,
    /// Draft tokens proposed across all verify steps.
    pub spec_draft_tokens: u64,
}

impl ServeMetrics {
    /// Empty metrics (all histograms and counters at zero).
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Fold `other` into this metric set — histograms merge bucket-wise,
    /// counters add, peaks take the max, and `kv_dtype` keeps `self`'s
    /// value (router replicas share one [`crate::serve::ServeOpts`], so
    /// the dtypes agree by construction).  This is how
    /// `serve::router::Router::aggregate_metrics` builds the fleet-level
    /// dashboard view from per-replica telemetry.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.ttft.merge(&other.ttft);
        self.inter_token.merge(&other.inter_token);
        self.queue_wait.merge(&other.queue_wait);
        self.prefill.merge(&other.prefill);
        self.decode.merge(&other.decode);
        self.queue_depth_sum += other.queue_depth_sum;
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        self.queue_samples += other.queue_samples;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.prefix_evictions += other.prefix_evictions;
        self.kv_live_bytes_peak = self.kv_live_bytes_peak.max(other.kv_live_bytes_peak);
        self.kv_eager_bytes_peak = self.kv_eager_bytes_peak.max(other.kv_eager_bytes_peak);
        self.finished_length += other.finished_length;
        self.finished_stop += other.finished_stop;
        self.cancelled += other.cancelled;
        self.rejected += other.rejected;
        self.timed_out += other.timed_out;
        self.failed += other.failed;
        self.spec_accept_len.merge(&other.spec_accept_len);
        self.spec_committed_tokens += other.spec_committed_tokens;
        self.spec_draft_tokens += other.spec_draft_tokens;
    }

    /// Sample the queue depth at an admission round.
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.queue_depth_sum += depth as u64;
        self.queue_depth_max = self.queue_depth_max.max(depth);
        self.queue_samples += 1;
    }

    /// Deepest queue sampled at any admission round.
    pub fn queue_depth_max(&self) -> usize {
        self.queue_depth_max
    }

    /// Mean sampled queue depth (zero when nothing was sampled).
    pub fn queue_depth_mean(&self) -> f64 {
        if self.queue_samples == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.queue_samples as f64
        }
    }

    /// Fraction of prefix-cache lookups that reused at least one token.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Record a live-KV snapshot; keeps the peak.
    pub fn record_kv_bytes(&mut self, live: usize, eager_equivalent: usize) {
        self.kv_live_bytes_peak = self.kv_live_bytes_peak.max(live);
        self.kv_eager_bytes_peak = self.kv_eager_bytes_peak.max(eager_equivalent);
    }

    /// Record one slot's speculative round (one chunked verify step).
    pub fn record_spec_round(&mut self, round: &crate::serve::SpecRound) {
        self.spec_accept_len.record(round.matched);
        self.spec_committed_tokens += round.committed as u64;
        self.spec_draft_tokens += round.drafted as u64;
    }

    /// Mean tokens committed per chunked verify step — the speculative
    /// throughput multiplier over one-token-per-round decoding (1.0 means
    /// speculation is buying nothing).
    pub fn spec_tokens_per_verify(&self) -> f64 {
        let steps = self.spec_accept_len.count();
        if steps == 0 {
            0.0
        } else {
            self.spec_committed_tokens as f64 / steps as f64
        }
    }

    /// Full telemetry dump (the serve example prints this).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ttft", self.ttft.to_json())
            .set("inter_token", self.inter_token.to_json())
            .set(
                "request_timing",
                Json::obj()
                    .set("queue_wait", self.queue_wait.to_json())
                    .set("prefill", self.prefill.to_json())
                    .set("decode", self.decode.to_json()),
            )
            .set(
                "queue",
                Json::obj()
                    .set("depth_max", self.queue_depth_max)
                    .set("depth_mean", self.queue_depth_mean())
                    .set("samples", self.queue_samples as usize),
            )
            .set(
                "prefix_cache",
                Json::obj()
                    .set("lookups", self.prefix_lookups as usize)
                    .set("hits", self.prefix_hits as usize)
                    .set("hit_rate", self.prefix_hit_rate())
                    .set("hit_tokens", self.prefix_hit_tokens as usize)
                    .set("evictions", self.prefix_evictions as usize),
            )
            .set(
                "kv",
                Json::obj()
                    .set("dtype", self.kv_dtype.label())
                    .set("live_bytes_peak", self.kv_live_bytes_peak)
                    .set("eager_bytes_peak", self.kv_eager_bytes_peak),
            )
            .set(
                "speculative",
                Json::obj()
                    .set("verify_steps", self.spec_accept_len.count() as usize)
                    .set("draft_tokens", self.spec_draft_tokens as usize)
                    .set("committed_tokens", self.spec_committed_tokens as usize)
                    .set("tokens_per_verify", self.spec_tokens_per_verify())
                    .set("accepted_len", self.spec_accept_len.to_json()),
            )
            .set(
                "finished",
                Json::obj()
                    .set("length", self.finished_length as usize)
                    .set("stop", self.finished_stop as usize)
                    .set("cancelled", self.cancelled as usize)
                    .set("rejected", self.rejected as usize)
                    .set("timed_out", self.timed_out as usize)
                    .set("failed", self.failed as usize),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        let p50 = h.quantile(0.5);
        // third sample is 4ms; bucket upper bound gives at most 2x
        assert!(p50 >= Duration::from_millis(4) && p50 <= Duration::from_millis(8), "{p50:?}");
        // p100 is clamped to the true max
        assert_eq!(h.quantile(1.0), Duration::from_millis(100));
        assert!(h.quantile(0.99) <= Duration::from_millis(100));
        assert!(h.mean() >= Duration::from_millis(20));
    }

    #[test]
    fn histogram_empty_and_zero() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        h.record(Duration::ZERO); // lands in the first bucket, no panic
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn histogram_ordering_monotone() {
        let mut h = Histogram::new();
        let mut us = 1u64;
        for _ in 0..20 {
            h.record(Duration::from_micros(us));
            us = us.saturating_mul(3);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.quantile(1.0));
    }

    #[test]
    fn metrics_json_shape() {
        let mut m = ServeMetrics::new();
        m.ttft.record(Duration::from_millis(3));
        m.inter_token.record(Duration::from_micros(700));
        m.record_queue_depth(4);
        m.record_queue_depth(2);
        m.prefix_lookups = 4;
        m.prefix_hits = 1;
        m.prefix_hit_tokens = 64;
        m.record_kv_bytes(1000, 4000);
        m.kv_dtype = KvDtype::Int8;
        m.finished_length = 2;
        let j = m.to_json();
        assert_eq!(j.get("kv").unwrap().get("dtype").unwrap().as_str(), Some("int8"));
        assert_eq!(j.get("queue").unwrap().get("depth_max").unwrap().as_usize(), Some(4));
        let pc = j.get("prefix_cache").unwrap();
        assert_eq!(pc.get("hit_tokens").unwrap().as_usize(), Some(64));
        assert!((pc.get("hit_rate").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(j.get("kv").unwrap().get("live_bytes_peak").unwrap().as_usize(), Some(1000));
        assert!(j.get("ttft").unwrap().get("p95_us").unwrap().as_f64().unwrap() > 0.0);
        // the dump is valid JSON round-trip
        let text = j.to_string();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn count_histogram_buckets_and_mean() {
        let mut h = CountHistogram::new();
        assert_eq!(h.mean(), 0.0);
        for n in [0usize, 2, 2, 4] {
            h.record(n);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.at(2), 2);
        assert_eq!(h.at(1), 0);
        assert!((h.mean() - 2.0).abs() < 1e-12);
        // clamp: outsized samples land in the last bucket instead of panicking
        h.record(10_000);
        assert_eq!(h.at(COUNT_BUCKETS - 1), 1);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(5));
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), COUNT_BUCKETS, "clamped sample extends the dump");
        assert_eq!(buckets[2].as_usize(), Some(2));
    }

    #[test]
    fn spec_rounds_feed_accept_histogram() {
        use crate::serve::SpecRound;
        let mut m = ServeMetrics::new();
        assert_eq!(m.spec_tokens_per_verify(), 0.0);
        m.record_spec_round(&SpecRound { drafted: 4, matched: 4, committed: 5 });
        m.record_spec_round(&SpecRound { drafted: 4, matched: 1, committed: 2 });
        m.record_spec_round(&SpecRound { drafted: 2, matched: 0, committed: 1 });
        assert_eq!(m.spec_accept_len.count(), 3);
        assert_eq!(m.spec_draft_tokens, 10);
        assert_eq!(m.spec_committed_tokens, 8);
        assert!((m.spec_tokens_per_verify() - 8.0 / 3.0).abs() < 1e-12);
        let j = m.to_json();
        let spec = j.get("speculative").unwrap();
        assert_eq!(spec.get("verify_steps").unwrap().as_usize(), Some(3));
        assert_eq!(spec.get("draft_tokens").unwrap().as_usize(), Some(10));
        let accepted = spec.get("accepted_len").unwrap();
        assert_eq!(accepted.get("count").unwrap().as_usize(), Some(3));
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn histogram_quantile_at_count_zero_and_one() {
        let h = Histogram::new();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Duration::ZERO);
        }
        let mut h = Histogram::new();
        h.record(Duration::from_micros(300));
        // single sample: every quantile is that sample (bucket upper bound
        // clamped to the true max)
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Duration::from_micros(300), "q={q}");
        }
    }

    #[test]
    fn bucket_boundaries_land_in_documented_bucket() {
        // an exact power of two 2^i µs opens bucket i; 2^i - 1 closes i-1
        for i in [0usize, 1, 5, 20, 30] {
            let us = 1u64 << i;
            assert_eq!(Histogram::bucket_index(us), i, "2^{i} µs");
            if i > 1 {
                assert_eq!(Histogram::bucket_index(us - 1), i - 1, "2^{i}-1 µs");
            }
            let mut h = Histogram::new();
            h.record(Duration::from_micros(us));
            assert_eq!(h.bucket(i), 1);
            assert_eq!(h.count(), 1);
        }
        // 0 µs clamps into the first bucket, the overflow tail into the last
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(u64::MAX), Histogram::N_BUCKETS - 1);
    }

    #[test]
    fn histogram_sum_saturates_at_u64_max() {
        let mut h = Histogram::new();
        h.record(Duration::from_micros(u64::MAX));
        h.record(Duration::from_micros(u64::MAX));
        // saturated, not wrapped (a wrap would also panic in debug builds)
        assert_eq!(h.sum(), Duration::from_micros(u64::MAX));
        assert_eq!(h.count(), 2);
        assert_eq!(h.bucket(Histogram::N_BUCKETS - 1), 2);
        assert!(h.quantile(1.0) <= Duration::from_micros(u64::MAX));
    }

    #[test]
    fn count_histogram_saturates_sum_and_clamps_bucket() {
        let mut h = CountHistogram::new();
        h.record(usize::MAX);
        h.record(usize::MAX);
        assert_eq!(h.sum(), u64::MAX, "sum must saturate, not wrap");
        assert_eq!(h.at(COUNT_BUCKETS - 1), 2);
        assert_eq!(h.count(), 2);
        assert!(h.mean() > 0.0);
        assert!(crate::util::json::parse(&h.to_json().to_string()).is_ok());
    }

    #[test]
    fn metrics_json_shape_snapshot() {
        // exporter-drift tripwire: the exact top-level key set and the
        // per-histogram key set are load-bearing for perf tooling and the
        // Prometheus renderer — extending is fine, but must be deliberate
        let j = ServeMetrics::new().to_json();
        let keys: Vec<&str> = j.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "ttft",
                "inter_token",
                "request_timing",
                "queue",
                "prefix_cache",
                "kv",
                "speculative",
                "finished"
            ]
        );
        let rt = j.get("request_timing").unwrap();
        let rt_keys: Vec<&str> = rt.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(rt_keys, ["queue_wait", "prefill", "decode"]);
        for section in ["queue_wait", "prefill", "decode"] {
            let h = rt.get(section).unwrap();
            let hk: Vec<&str> = h.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(hk, ["count", "mean_us", "p50_us", "p95_us", "p99_us", "max_us"], "{section}");
        }
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn merge_equals_recording_into_one() {
        // merging per-replica metrics must equal having recorded every
        // sample into a single set — bucket-exact, not approximate
        let samples_a = [3u64, 70, 800];
        let samples_b = [5u64, 5, 90_000];
        let mut a = ServeMetrics::new();
        let mut b = ServeMetrics::new();
        let mut whole = ServeMetrics::new();
        for &us in &samples_a {
            a.ttft.record(Duration::from_micros(us));
            whole.ttft.record(Duration::from_micros(us));
        }
        for &us in &samples_b {
            b.ttft.record(Duration::from_micros(us));
            whole.ttft.record(Duration::from_micros(us));
        }
        a.record_queue_depth(3);
        whole.record_queue_depth(3);
        b.record_queue_depth(9);
        whole.record_queue_depth(9);
        a.finished_length = 2;
        b.finished_length = 1;
        whole.finished_length = 3;
        b.rejected = 4;
        whole.rejected = 4;
        a.spec_accept_len.record(2);
        whole.spec_accept_len.record(2);
        a.merge(&b);
        assert_eq!(a.ttft.count(), whole.ttft.count());
        for i in 0..Histogram::N_BUCKETS {
            assert_eq!(a.ttft.bucket(i), whole.ttft.bucket(i), "bucket {i}");
        }
        assert_eq!(a.ttft.quantile(0.95), whole.ttft.quantile(0.95));
        assert_eq!(a.ttft.sum(), whole.ttft.sum());
        assert_eq!(a.ttft.max(), whole.ttft.max());
        assert_eq!(a.queue_depth_max(), whole.queue_depth_max());
        assert!((a.queue_depth_mean() - whole.queue_depth_mean()).abs() < 1e-12);
        assert_eq!(a.finished_length, whole.finished_length);
        assert_eq!(a.rejected, whole.rejected);
        assert_eq!(a.spec_accept_len.count(), whole.spec_accept_len.count());
    }

    #[test]
    fn queue_depth_mean() {
        let mut m = ServeMetrics::new();
        assert_eq!(m.queue_depth_mean(), 0.0);
        m.record_queue_depth(3);
        m.record_queue_depth(5);
        assert!((m.queue_depth_mean() - 4.0).abs() < 1e-12);
        assert_eq!(m.queue_depth_max(), 5);
    }
}

//! Self-speculative decoding: a cheaper **draft model** (the same base
//! weights quantized at an aggressive low-bit allocation — nearly free in
//! memory next to the target, see [`crate::serve::PackedModel::draft`])
//! proposes `k` tokens per decode round, and the target model verifies the
//! whole proposal in **one chunked incremental forward**
//! ([`crate::model::native::forward_chunk`]) instead of `k` sequential
//! [`crate::model::native::decode_step`]s — amortizing every weight
//! matrix's memory traffic `k`× per verify.
//!
//! This module owns the draft side: catching the draft's KV cache up to the
//! committed token stream and greedily proposing the next `k` tokens.  The
//! verify/accept/rollback half lives in the scheduler's decode round
//! (`serve::scheduler`), because acceptance consumes the per-request
//! sampler + RNG stream: tokens are re-sampled **sequentially** from the
//! chunked verify logits and accepted while they agree with the draft, so
//! the emitted stream — and the RNG stream behind it — is bit-identical to
//! plain decoding for *every* sampler, not just greedy (the draft only
//! controls how many tokens each round commits, never which).  Rejected
//! suffixes roll back through the chunked KV cache's copy-on-write
//! [`crate::model::native::KvCache::truncate`].

use crate::model::native::{forward_cached, DecoderParams, KvCache};
use crate::util::sampling::argmax;

/// Per-round speculation telemetry for one slot, drained into
/// [`crate::serve::ServeStats`] / [`crate::serve::ServeMetrics`] at the
/// round boundary.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecRound {
    /// Draft tokens proposed this round (0 when the round degenerated to a
    /// plain decode step — no context or generation budget left to draft).
    pub drafted: usize,
    /// Leading draft tokens the target's sampler agreed with.
    pub matched: usize,
    /// Tokens actually committed to the completion this round (matched
    /// tokens plus the correction/bonus sample; >= 1).
    pub committed: usize,
}

/// Largest draft length a slot can absorb this round: each verify feeds
/// `k + 1` positions (the pending token plus `k` drafts) and commits at
/// most `k + 1` tokens, so `k` is bounded by the remaining generation
/// budget minus the guaranteed sample and by the remaining KV context
/// minus the pending token's position.
pub fn clamp_k(k: usize, remaining_new: usize, remaining_ctx: usize) -> usize {
    k.min(remaining_new.saturating_sub(1)).min(remaining_ctx.saturating_sub(1))
}

/// Greedily propose `k` draft tokens continuing the committed stream (the
/// request's prompt plus everything sampled so far, whose last token is
/// the pending one not yet fed to the target).
///
/// The draft cache holds K/V for a prefix of that stream; `gap` is the
/// rest — tokens `cache.len()..` of it.  It is at least the pending token
/// (typically 1-2 tokens on steady-state rounds) and the whole prompt on
/// the slot's first speculative round, and is fed in one chunked catch-up
/// forward.  Passing only the gap keeps steady-state rounds free of
/// O(prompt + generated) stream copies.  On return the cache holds
/// everything except the last draft (which stays pending exactly like the
/// target's `last`); the scheduler truncates it back to the verified
/// length after acceptance.
pub fn propose<D: DecoderParams + ?Sized>(
    draft: &D,
    cache: &mut KvCache,
    gap: &[i32],
    k: usize,
) -> Vec<i32> {
    debug_assert!(k >= 1, "propose: k must be >= 1");
    debug_assert!(!gap.is_empty(), "gap must include at least the pending token");
    let mut drafts = Vec::with_capacity(k);
    let logits = forward_cached(draft, cache, gap);
    let mut pending = argmax(&logits) as i32;
    drafts.push(pending);
    while drafts.len() < k {
        let logits = forward_cached(draft, cache, &[pending]);
        pending = argmax(&logits) as i32;
        drafts.push(pending);
    }
    drafts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OptConfig, Weights};
    use crate::util::rng::Pcg64;

    #[test]
    fn propose_catches_up_and_leaves_last_draft_pending() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 4);
        let mut rng = Pcg64::new(2);
        let committed: Vec<i32> = (0..7).map(|_| rng.below(cfg.vocab) as i32).collect();
        // cold cache: the catch-up gap is the whole committed stream
        let mut cache = KvCache::new(&cfg);
        let drafts = propose(&w, &mut cache, &committed, 4);
        assert_eq!(drafts.len(), 4);
        assert_eq!(cache.len(), committed.len() + 3, "last draft stays pending");
        assert!(drafts.iter().all(|&t| (t as usize) < cfg.vocab));
    }

    #[test]
    fn propose_equals_plain_greedy_continuation() {
        // drafting IS greedy decoding on the draft model: proposing k tokens
        // must equal k greedy decode steps from the same prefix, and a warm
        // cache (partial catch-up) must not change the proposal
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 5);
        let mut rng = Pcg64::new(3);
        let committed: Vec<i32> = (0..6).map(|_| rng.below(cfg.vocab) as i32).collect();

        let mut reference = Vec::new();
        let mut cache = KvCache::new(&cfg);
        let mut logits = crate::model::native::prefill(&w, &mut cache, &committed);
        for _ in 0..3 {
            let t = argmax(&logits) as i32;
            reference.push(t);
            logits = crate::model::native::decode_step(&w, &mut cache, t);
        }

        let mut cold = KvCache::new(&cfg);
        assert_eq!(propose(&w, &mut cold, &committed, 3), reference);

        let mut warm = KvCache::new(&cfg);
        crate::model::native::prefill(&w, &mut warm, &committed[..4]);
        assert_eq!(propose(&w, &mut warm, &committed[4..], 3), reference);
    }

    #[test]
    fn clamp_k_honors_budgets() {
        assert_eq!(clamp_k(4, 10, 10), 4);
        assert_eq!(clamp_k(4, 3, 10), 2, "leave room for the guaranteed sample");
        assert_eq!(clamp_k(4, 10, 2), 1, "leave room for the pending token");
        assert_eq!(clamp_k(4, 1, 10), 0, "one token left: plain decode");
        assert_eq!(clamp_k(4, 0, 0), 0);
    }
}

//! `.tok` token-corpus reader (format defined in `python/compile/datagen.py`):
//! `b"IVTK"`, u32 version, u32 vocab, u32 count, then `count` LE u32 tokens.

use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"IVTK";
const VERSION: u32 = 1;

/// A loaded token stream.
#[derive(Debug, Clone)]
pub struct TokenCorpus {
    pub vocab: usize,
    pub tokens: Vec<u32>,
}

pub fn read(path: &Path) -> crate::Result<TokenCorpus> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
    let mut hdr = [0u8; 16];
    f.read_exact(&mut hdr)?;
    anyhow::ensure!(&hdr[..4] == MAGIC, "{}: bad .tok magic", path.display());
    let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    anyhow::ensure!(version == VERSION, "unsupported .tok version {version}");
    let vocab = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
    let count = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
    let mut data = vec![0u8; count * 4];
    f.read_exact(&mut data)?;
    let tokens: Vec<u32> = data
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            (t as usize) < vocab,
            "{}: token {t} at {i} exceeds vocab {vocab}",
            path.display()
        );
    }
    Ok(TokenCorpus { vocab, tokens })
}

impl TokenCorpus {
    /// Slice into `[n_seqs, seqlen]` contiguous calibration/eval sequences
    /// (plus next-token targets).  Matches the python-side chunking.
    pub fn sequences(&self, n_seqs: usize, seqlen: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        let avail = (self.tokens.len() - 1) / seqlen;
        let n = n_seqs.min(avail);
        (0..n)
            .map(|s| {
                let start = s * seqlen;
                let toks = self.tokens[start..start + seqlen].iter().map(|&t| t as i32).collect();
                let tgts = self.tokens[start + 1..start + seqlen + 1].iter().map(|&t| t as i32).collect();
                (toks, tgts)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tok(path: &Path, vocab: u32, tokens: &[u32]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&VERSION.to_le_bytes()).unwrap();
        f.write_all(&vocab.to_le_bytes()).unwrap();
        f.write_all(&(tokens.len() as u32).to_le_bytes()).unwrap();
        for t in tokens {
            f.write_all(&t.to_le_bytes()).unwrap();
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("invarexplore_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_sequences() {
        let toks: Vec<u32> = (0..100).map(|i| i % 50).collect();
        let p = tmp("a.tok");
        write_tok(&p, 50, &toks);
        let c = read(&p).unwrap();
        assert_eq!(c.vocab, 50);
        assert_eq!(c.tokens, toks);
        let seqs = c.sequences(3, 16);
        assert_eq!(seqs.len(), 3);
        assert_eq!(seqs[0].0.len(), 16);
        // targets shifted by one
        assert_eq!(seqs[0].1[0], seqs[0].0[1]);
        assert_eq!(seqs[1].0[0] as u32, toks[16]);
    }

    #[test]
    fn sequences_clamped_to_available() {
        let toks: Vec<u32> = (0..33).collect();
        let p = tmp("b.tok");
        write_tok(&p, 64, &toks);
        let c = read(&p).unwrap();
        assert_eq!(c.sequences(100, 16).len(), 2);
    }

    #[test]
    fn out_of_vocab_rejected() {
        let p = tmp("c.tok");
        write_tok(&p, 4, &[1, 2, 9]);
        assert!(read(&p).is_err());
    }
}

//! Artifact I/O: the `.iwt` weight container, `.tok` token corpora,
//! reasoning-task JSON files and the artifacts manifest emitted by
//! `python/compile/aot.py`.

pub mod iwt;
pub mod manifest;
pub mod tasks;
pub mod tokens;

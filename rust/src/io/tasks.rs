//! Reasoning-task file reader: JSON lists of
//! `{"ctx": [tok...], "options": [[tok...], ...], "answer": i}` produced by
//! `python/compile/datagen.py`'s six task generators.

use std::path::Path;

use crate::util::json;

/// One few-shot multiple-choice example.
#[derive(Debug, Clone)]
pub struct TaskExample {
    pub ctx: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub answer: usize,
}

pub fn read(path: &Path) -> crate::Result<Vec<TaskExample>> {
    let root = json::parse_file(path)?;
    let arr = root
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{}: task file is not an array", path.display()))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, ex) in arr.iter().enumerate() {
        let ctx: Vec<i32> = ex
            .req("ctx")?
            .usize_array()
            .map_err(|e| anyhow::anyhow!("example {i} ctx: {e}"))?
            .into_iter()
            .map(|t| t as i32)
            .collect();
        let options = ex
            .req("options")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("example {i}: options not array"))?
            .iter()
            .map(|o| {
                o.usize_array()
                    .map(|v| v.into_iter().map(|t| t as i32).collect::<Vec<i32>>())
            })
            .collect::<Result<Vec<_>, _>>()?;
        let answer = ex.req("answer")?.as_usize().unwrap_or(usize::MAX);
        anyhow::ensure!(
            answer < options.len(),
            "example {i}: answer {answer} out of range ({} options)",
            options.len()
        );
        anyhow::ensure!(!ctx.is_empty(), "example {i}: empty ctx");
        out.push(TaskExample { ctx, options, answer });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("invarexplore_tasks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn parses_examples() {
        let p = tmp(
            "t.json",
            r#"[{"ctx": [1, 5, 9], "options": [[3], [4, 2]], "answer": 1}]"#,
        );
        let ex = read(&p).unwrap();
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].ctx, vec![1, 5, 9]);
        assert_eq!(ex[0].options[1], vec![4, 2]);
        assert_eq!(ex[0].answer, 1);
    }

    #[test]
    fn rejects_bad_answer() {
        let p = tmp("bad.json", r#"[{"ctx": [1], "options": [[2]], "answer": 3}]"#);
        assert!(read(&p).is_err());
    }

    #[test]
    fn rejects_empty_ctx() {
        let p = tmp("empty.json", r#"[{"ctx": [], "options": [[2]], "answer": 0}]"#);
        assert!(read(&p).is_err());
    }
}

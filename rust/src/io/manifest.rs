//! Artifacts manifest (`artifacts/manifest.json`, written by `aot.py`).
//!
//! The manifest is the single source of truth for the Rust side: model
//! configs, canonical parameter ordering, HLO program paths + signatures,
//! and dataset locations.  All paths are relative to the manifest's parent
//! directory, so the artifacts tree is relocatable.

use std::path::{Path, PathBuf};

use crate::model::config::OptConfig;
use crate::util::json::{self, Json};

/// Expected manifest version.
///
/// * Version 2 = zero-point-clamped quantization codec (PR 2): HLO programs
///   compiled from the earlier unclamped Pallas kernel silently disagree
///   with the host codec on single-sign groups.
/// * Version 3 = mixed-precision artifacts: the manifest carries
///   `quant_allocations` (heterogeneous per-tensor scheme presets the
///   standalone fake-quant programs are emitted for), so version-2 trees
///   lack the programs a mixed allocation needs.
///
/// Older trees are rejected with a regenerate hint.
pub const MANIFEST_VERSION: usize = 3;

/// One HLO program's signature.
#[derive(Debug, Clone)]
pub struct ProgramInfo {
    pub name: String,
    pub path: PathBuf,
    /// (param name, shape, dtype) in HLO parameter order.
    pub params: Vec<(String, Vec<usize>, String)>,
}

/// One model's entry.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub config: OptConfig,
    pub weights_path: PathBuf,
    pub param_names: Vec<String>,
    pub programs: Vec<ProgramInfo>,
}

impl ModelInfo {
    pub fn program(&self, name: &str) -> crate::Result<&ProgramInfo> {
        self.programs
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("model {}: no program {name:?}", self.config.name))
    }

    pub fn has_program(&self, name: &str) -> bool {
        self.programs.iter().any(|p| p.name == name)
    }
}

/// Dataset entries.
#[derive(Debug, Clone)]
pub struct DataInfo {
    pub vocab: usize,
    pub corpora: Vec<(String, PathBuf)>,
    pub tasks: Vec<(String, PathBuf)>,
}

impl DataInfo {
    pub fn corpus(&self, name: &str) -> crate::Result<&Path> {
        self.corpora
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_path())
            .ok_or_else(|| anyhow::anyhow!("no corpus {name:?} in manifest"))
    }

    pub fn task(&self, name: &str) -> crate::Result<&Path> {
        self.tasks
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_path())
            .ok_or_else(|| anyhow::anyhow!("no task {name:?} in manifest"))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.iter().map(|(n, _)| n.as_str()).collect()
    }
}

/// The full parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch: usize,
    pub seq: usize,
    pub quant_bits: Vec<usize>,
    pub quant_groups: Vec<usize>,
    /// Mixed-precision allocation presets (parse-validated
    /// [`crate::quant::BitAllocation`] strings, e.g.
    /// `"2x64,ffn_up=3x64"`).  Optional; empty for uniform-only trees.
    pub quant_allocations: Vec<crate::quant::BitAllocation>,
    pub models: Vec<(String, ModelInfo)>,
    pub data: DataInfo,
}

impl Manifest {
    /// Load `dir/manifest.json` (default dir: `artifacts/`, override with
    /// `INVAREXPLORE_ARTIFACTS`).
    pub fn load_default() -> crate::Result<Manifest> {
        let dir = std::env::var("INVAREXPLORE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.json");
        let root = json::parse_file(&path)?;
        Self::from_json(&root, dir)
    }

    pub fn from_json(root: &Json, dir: &Path) -> crate::Result<Manifest> {
        let version = root.req("version")?.as_usize().unwrap_or(0);
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "artifacts manifest version {version} != expected {MANIFEST_VERSION}: \
             the artifact schema changed (v2: zero-point clamp; v3: mixed-precision \
             quant_allocations); rerun `make artifacts`"
        );
        let batch_obj = root.req("batch")?;
        let batch = batch_obj.req("B")?.as_usize().unwrap();
        let seq = batch_obj.req("T")?.as_usize().unwrap();

        let mut models = Vec::new();
        for (name, m) in root.req("models")?.entries().unwrap_or(&[]) {
            let config = OptConfig::from_json(m.req("config")?)?;
            let weights_path = dir.join(m.req("weights")?.as_str().unwrap_or(""));
            let param_names = m
                .req("param_names")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_str().unwrap_or("").to_string())
                .collect();
            let mut programs = Vec::new();
            for (pname, p) in m.req("programs")?.entries().unwrap_or(&[]) {
                let params = p
                    .req("params")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|e| {
                        Ok((
                            e.req("name")?.as_str().unwrap_or("").to_string(),
                            e.req("shape")?.usize_array()?,
                            e.req("dtype")?.as_str().unwrap_or("f32").to_string(),
                        ))
                    })
                    .collect::<crate::Result<Vec<_>>>()?;
                programs.push(ProgramInfo {
                    name: pname.clone(),
                    path: dir.join(p.req("path")?.as_str().unwrap_or("")),
                    params,
                });
            }
            models.push((
                name.clone(),
                ModelInfo {
                    config,
                    weights_path,
                    param_names,
                    programs,
                },
            ));
        }

        let data_json = root.req("data")?;
        let mut corpora = Vec::new();
        for (n, c) in data_json.req("corpora")?.entries().unwrap_or(&[]) {
            corpora.push((n.clone(), dir.join(c.req("path")?.as_str().unwrap_or(""))));
        }
        let mut tasks = Vec::new();
        for (n, t) in data_json.req("tasks")?.entries().unwrap_or(&[]) {
            tasks.push((n.clone(), dir.join(t.req("path")?.as_str().unwrap_or(""))));
        }

        Ok(Manifest {
            root: dir.to_path_buf(),
            batch,
            seq,
            quant_bits: root
                .get("quant_bits")
                .map(|v| v.usize_array())
                .transpose()?
                .unwrap_or_default(),
            quant_groups: root
                .get("quant_groups")
                .map(|v| v.usize_array())
                .transpose()?
                .unwrap_or_default(),
            quant_allocations: root
                .get("quant_allocations")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|v| {
                    crate::quant::BitAllocation::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("quant_allocations: expected string"))?,
                    )
                })
                .collect::<crate::Result<Vec<_>>>()?,
            models,
            data: DataInfo {
                vocab: data_json.req("vocab")?.as_usize().unwrap_or(0),
                corpora,
                tasks,
            },
        })
    }

    pub fn model(&self, name: &str) -> crate::Result<&ModelInfo> {
        self.models
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m)
            .ok_or_else(|| {
                let avail: Vec<&str> = self.models.iter().map(|(n, _)| n.as_str()).collect();
                anyhow::anyhow!("no model {name:?} in manifest (available: {avail:?})")
            })
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Name of the standalone fake-quant program for a weight shape.
    pub fn quant_program_name(rows: usize, cols: usize, bits: usize, group: usize) -> String {
        format!("quant_{rows}x{cols}_{bits}b{group}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 3,
      "batch": {"B": 8, "T": 128},
      "quant_bits": [1, 2],
      "quant_groups": [32],
      "quant_allocations": ["2x64", "2x64,ffn_up=3x64,ffn_down=1x64"],
      "data": {
        "vocab": 2048,
        "corpora": {"wiki": {"path": "data/wiki.tok", "tokens": 100}},
        "tasks": {"bool": {"path": "data/task_bool.json", "n": 10}}
      },
      "models": {
        "m": {
          "config": {"name": "m", "vocab": 2048, "d_model": 64, "n_layers": 2,
                     "n_heads": 4, "d_ffn": 128, "max_seq": 128},
          "weights": "models/m.iwt",
          "param_names": ["emb", "pos"],
          "programs": {
            "embed": {"path": "programs/m/embed.hlo.txt",
                      "params": [{"name": "tokens", "shape": [8, 128], "dtype": "i32"}]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let root = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&root, Path::new("/art")).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.seq, 128);
        assert_eq!(m.model_names(), vec!["m"]);
        let model = m.model("m").unwrap();
        assert_eq!(model.config.d_model, 64);
        let prog = model.program("embed").unwrap();
        assert_eq!(prog.path, PathBuf::from("/art/programs/m/embed.hlo.txt"));
        assert_eq!(prog.params[0].1, vec![8, 128]);
        assert_eq!(prog.params[0].2, "i32");
        assert_eq!(m.data.corpus("wiki").unwrap(), Path::new("/art/data/wiki.tok"));
        assert!(m.data.corpus("nope").is_err());
        assert!(model.program("nope").is_err());
        // mixed-precision presets are parse-validated BitAllocations
        assert_eq!(m.quant_allocations.len(), 2);
        assert!(m.quant_allocations[0].is_uniform());
        assert_eq!(
            m.quant_allocations[1].scheme_for("l0.up.w"),
            crate::quant::QuantScheme::new(3, 64)
        );
    }

    #[test]
    fn bad_allocation_preset_rejected() {
        let bad = SAMPLE.replace("ffn_up=3x64", "lm_head=3x64");
        let root = json::parse(&bad).unwrap();
        let err = Manifest::from_json(&root, Path::new("/art")).unwrap_err();
        assert!(err.to_string().contains("unknown tensor"), "{err}");
    }

    #[test]
    fn missing_allocations_default_empty() {
        let no_alloc = SAMPLE.replace(
            "\"quant_allocations\": [\"2x64\", \"2x64,ffn_up=3x64,ffn_down=1x64\"],",
            "",
        );
        let root = json::parse(&no_alloc).unwrap();
        let m = Manifest::from_json(&root, Path::new("/art")).unwrap();
        assert!(m.quant_allocations.is_empty());
    }

    #[test]
    fn quant_program_name_format() {
        assert_eq!(Manifest::quant_program_name(512, 128, 2, 64), "quant_512x128_2b64");
    }

    #[test]
    fn stale_manifest_version_rejected() {
        // v1 predates the zero-point clamp, v2 the mixed-precision
        // allocations; both must fail loudly with a regenerate hint instead
        // of silently diverging at runtime
        for old in ["\"version\": 1", "\"version\": 2"] {
            let stale = SAMPLE.replace("\"version\": 3", old);
            let root = json::parse(&stale).unwrap();
            let err = Manifest::from_json(&root, Path::new("/art")).unwrap_err();
            assert!(err.to_string().contains("make artifacts"), "{err}");
        }
    }
}

//! `.iwt` tensor container reader/writer (format defined in
//! `python/compile/iwt.py` — keep in sync).
//!
//! Layout: `b"IVWT"` magic, u32 version, u64 header length, JSON header
//! (`{"tensors": {name: {dtype, shape, offset, nbytes}}, "meta": {...}}`),
//! then 64-byte-aligned little-endian tensor data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;
use crate::util::json::{self, Json};

const MAGIC: &[u8; 4] = b"IVWT";
const VERSION: u32 = 1;
const ALIGN: usize = 64;

/// A loaded weight file: named tensors + string metadata.
#[derive(Debug, Clone)]
pub struct IwtFile {
    /// Insertion-ordered (file order) tensor map.
    pub tensors: Vec<(String, Tensor)>,
    pub meta: BTreeMap<String, String>,
}

impl IwtFile {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|(n, _)| n.as_str()).collect()
    }
}

/// Read an `.iwt` file.  Rank-1 tensors load as single-row matrices;
/// higher ranks collapse leading dims (row-major semantics preserved).
pub fn read(path: &Path) -> crate::Result<IwtFile> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "{}: bad .iwt magic", path.display());
    let mut buf4 = [0u8; 4];
    f.read_exact(&mut buf4)?;
    let version = u32::from_le_bytes(buf4);
    anyhow::ensure!(version == VERSION, "unsupported .iwt version {version}");
    let mut buf8 = [0u8; 8];
    f.read_exact(&mut buf8)?;
    let hlen = u64::from_le_bytes(buf8) as usize;
    let mut header_bytes = vec![0u8; hlen];
    f.read_exact(&mut header_bytes)?;
    let header = json::parse(std::str::from_utf8(&header_bytes)?)
        .map_err(|e| anyhow::anyhow!("{}: header: {e}", path.display()))?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;

    let mut tensors = Vec::new();
    for (name, entry) in header.req("tensors")?.entries().unwrap_or(&[]) {
        let dtype = entry.req("dtype")?.as_str().unwrap_or("");
        anyhow::ensure!(dtype == "f32", "tensor {name}: unsupported dtype {dtype}");
        let shape = entry.req("shape")?.usize_array()?;
        let offset = entry.req("offset")?.as_usize().unwrap();
        let nbytes = entry.req("nbytes")?.as_usize().unwrap();
        anyhow::ensure!(offset % ALIGN == 0, "tensor {name}: unaligned offset");
        anyhow::ensure!(
            offset + nbytes <= data.len(),
            "tensor {name}: data out of bounds"
        );
        let numel: usize = shape.iter().product::<usize>().max(1);
        anyhow::ensure!(numel * 4 == nbytes, "tensor {name}: shape/nbytes mismatch");
        let mut vals = Vec::with_capacity(numel);
        for c in data[offset..offset + nbytes].chunks_exact(4) {
            vals.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        let (rows, cols) = match shape.len() {
            0 => (1, 1),
            1 => (1, shape[0]),
            _ => (shape[..shape.len() - 1].iter().product(), shape[shape.len() - 1]),
        };
        tensors.push((name.clone(), Tensor::from_vec(rows, cols, vals)));
    }

    let mut meta = BTreeMap::new();
    if let Some(entries) = header.get("meta").and_then(|m| m.entries()) {
        for (k, v) in entries {
            meta.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
        }
    }
    Ok(IwtFile { tensors, meta })
}

/// Write an `.iwt` file (used by `invarexplore apply` to materialize
/// transformed/quantized weights).  Rank-2 shapes only — that is all the
/// apply path ever writes; rank-1 tensors are stored as `[1, n]`.
pub fn write(
    path: &Path,
    tensors: &[(String, &Tensor, Vec<usize>)],
    meta: &BTreeMap<String, String>,
) -> crate::Result<()> {
    let mut entries = Vec::new();
    let mut offset = 0usize;
    let mut blobs: Vec<Vec<u8>> = Vec::new();
    for (name, t, shape) in tensors {
        let numel: usize = shape.iter().product();
        anyhow::ensure!(numel == t.numel(), "tensor {name}: shape/numel mismatch");
        let nbytes = t.numel() * 4;
        entries.push((
            name.clone(),
            Json::obj()
                .set("dtype", "f32")
                .set("shape", shape.iter().map(|&d| Json::from(d)).collect::<Vec<_>>())
                .set("offset", offset)
                .set("nbytes", nbytes),
        ));
        let mut blob = Vec::with_capacity(nbytes);
        for v in &t.data {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        offset += nbytes;
        let pad = (ALIGN - offset % ALIGN) % ALIGN;
        blob.extend(std::iter::repeat(0u8).take(pad));
        offset += pad;
        blobs.push(blob);
    }
    let meta_json = Json::Obj(
        meta.iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect(),
    );
    let header = Json::obj()
        .set("tensors", Json::Obj(entries))
        .set("meta", meta_json)
        .to_string();

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for b in &blobs {
        f.write_all(b)?;
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("invarexplore_iwt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let t1 = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t2 = Tensor::from_vec(1, 4, vec![0.5, -0.5, 1.5, -1.5]);
        let mut meta = BTreeMap::new();
        meta.insert("name".to_string(), "test".to_string());
        let p = tmp("rt.iwt");
        write(
            &p,
            &[
                ("a".to_string(), &t1, vec![2, 3]),
                ("b.c".to_string(), &t2, vec![4]),
            ],
            &meta,
        )
        .unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.get("a").unwrap(), &t1);
        assert_eq!(back.get("b.c").unwrap(), &t2);
        assert_eq!(back.meta["name"], "test");
        assert_eq!(back.names(), vec!["a", "b.c"]);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.iwt");
        std::fs::write(&p, b"XXXX0123456789ab").unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let t = Tensor::from_vec(1, 2, vec![1.0, 2.0]);
        let p = tmp("mismatch.iwt");
        assert!(write(&p, &[("x".to_string(), &t, vec![3])], &BTreeMap::new()).is_err());
    }
}

//! Telemetry for the serving front-end router: routing-decision counters
//! (affinity hit / queue-depth rebalance / deadline spillover / shed) and
//! a queue-pressure counter stream for the Chrome trace.
//!
//! `serve::router::Router` reports every routing decision here *after*
//! making it, so recording can never influence placement.  Like every
//! `obs` module this is gated on [`crate::obs::enabled`] — one relaxed
//! atomic load when tracing is off.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// How the router placed (or refused) one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Landed on its consistent-hash (prefix-affinity) replica.
    Affinity,
    /// Diverted to the least-loaded replica: the affinity target was at
    /// the admission watermark.
    Balanced,
    /// All replicas were saturated, but the request carried a deadline and
    /// spilled onto the least-loaded replica (EDF under saturation).
    Spillover,
    /// All replicas were saturated and the request carried no deadline —
    /// shed with `FinishReason::Rejected`.
    Shed,
}

impl RouteOutcome {
    /// Short stable label (metrics / JSON field values).
    pub fn label(self) -> &'static str {
        match self {
            RouteOutcome::Affinity => "affinity",
            RouteOutcome::Balanced => "balanced",
            RouteOutcome::Spillover => "spillover",
            RouteOutcome::Shed => "shed",
        }
    }

    fn idx(self) -> usize {
        match self {
            RouteOutcome::Affinity => 0,
            RouteOutcome::Balanced => 1,
            RouteOutcome::Spillover => 2,
            RouteOutcome::Shed => 3,
        }
    }
}

const N_OUTCOMES: usize = 4;

/// The counter state itself — instantiable so tests can exercise the exact
/// arithmetic on a private instance while production code shares one
/// gated global.
struct Counters {
    routed: [AtomicU64; N_OUTCOMES],
}

impl Counters {
    const fn new() -> Counters {
        Counters {
            routed: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }

    fn record(&self, outcome: RouteOutcome) {
        self.routed[outcome.idx()].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> RouterSnapshot {
        let mut s = RouterSnapshot::default();
        for (dst, src) in s.routed.iter_mut().zip(&self.routed) {
            *dst = src.load(Ordering::Relaxed);
        }
        s
    }

    fn reset(&self) {
        for c in &self.routed {
            c.store(0, Ordering::Relaxed);
        }
    }
}

static GLOBAL: Counters = Counters::new();

/// Record one routing decision.  Gated: free (one relaxed load) when
/// tracing is off; emits a `router.shed_total` counter sample when on and
/// the decision was a shed (the saturation signal dashboards watch).
pub fn record_route(outcome: RouteOutcome) {
    if !super::enabled() {
        return;
    }
    GLOBAL.record(outcome);
    if outcome == RouteOutcome::Shed {
        let shed = GLOBAL.snapshot().routed_of(RouteOutcome::Shed);
        super::trace::counter("router", "shed_total", shed as f64);
    }
}

/// Point-in-time copy of the routing-decision counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterSnapshot {
    /// Indexed like `RouteOutcome`: `[affinity, balanced, spillover, shed]`.
    pub routed: [u64; N_OUTCOMES],
}

impl RouterSnapshot {
    /// Requests that took `outcome`.
    pub fn routed_of(&self, outcome: RouteOutcome) -> u64 {
        self.routed[outcome.idx()]
    }

    /// All routing decisions recorded (including sheds).
    pub fn total(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Fraction of decisions that were sheds (0 when nothing was routed).
    pub fn shed_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.routed_of(RouteOutcome::Shed) as f64 / t as f64
        }
    }

    /// `{affinity, balanced, spillover, shed, shed_rate}`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("affinity", self.routed_of(RouteOutcome::Affinity) as usize)
            .set("balanced", self.routed_of(RouteOutcome::Balanced) as usize)
            .set("spillover", self.routed_of(RouteOutcome::Spillover) as usize)
            .set("shed", self.routed_of(RouteOutcome::Shed) as usize)
            .set("shed_rate", self.shed_rate())
    }
}

/// Read the global routing counters.
pub fn snapshot() -> RouterSnapshot {
    GLOBAL.snapshot()
}

/// Zero the global routing counters (test/run isolation).
pub fn reset() {
    GLOBAL.reset()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing_globally() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        reset();
        record_route(RouteOutcome::Affinity);
        record_route(RouteOutcome::Shed);
        assert_eq!(snapshot(), RouterSnapshot::default());
    }

    #[test]
    fn per_outcome_counts_and_shed_rate() {
        // a private instance: exact counts without racing other tests on
        // the gated global
        let c = Counters::new();
        c.record(RouteOutcome::Affinity);
        c.record(RouteOutcome::Affinity);
        c.record(RouteOutcome::Balanced);
        c.record(RouteOutcome::Shed);
        let s = c.snapshot();
        assert_eq!(s.routed_of(RouteOutcome::Affinity), 2);
        assert_eq!(s.routed_of(RouteOutcome::Balanced), 1);
        assert_eq!(s.routed_of(RouteOutcome::Spillover), 0);
        assert_eq!(s.routed_of(RouteOutcome::Shed), 1);
        assert_eq!(s.total(), 4);
        assert!((s.shed_rate() - 0.25).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("affinity").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(1));
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
        c.reset();
        assert_eq!(c.snapshot(), RouterSnapshot::default());
    }

    #[test]
    fn empty_snapshot_has_zero_shed_rate() {
        assert_eq!(RouterSnapshot::default().shed_rate(), 0.0);
        assert_eq!(RouterSnapshot::default().total(), 0);
    }

    #[test]
    fn enabled_global_samples_shed_counter() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        super::super::trace::clear();
        reset();
        record_route(RouteOutcome::Shed);
        crate::obs::set_enabled(false);
        assert!(snapshot().routed_of(RouteOutcome::Shed) >= 1);
        assert!(super::super::trace::take_events().iter().any(|e| e.name == "shed_total"));
        reset();
    }
}

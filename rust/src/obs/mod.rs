//! Zero-dependency tracing & telemetry: request-lifecycle spans, search and
//! kernel counters, Chrome-trace + Prometheus export.
//!
//! The recorder is **compiled in but runtime-gated**: when tracing is
//! disabled (the default) every instrumentation site costs a single relaxed
//! atomic load, and when enabled it *observes but never perturbs* — no
//! instrumentation site feeds back into scheduling, sampling, RNG streams or
//! kernel results, so every output stays bit-identical to a tracing-off run
//! (pinned by tests that re-run the serve determinism matrix and the search
//! trajectory pins with tracing on).
//!
//! Layout:
//! - [`trace`] — lock-light per-thread ring-buffer event recorder with
//!   RAII span guards, instant marks and counter samples;
//! - [`kernel`] — per-SIMD-tier GEMM/dequant byte+time counters
//!   (achieved GB/s for the packed kernels);
//! - [`search`] — per-move-family propose/accept counters and a windowed
//!   acceptance rate for the discrete search drivers;
//! - [`router`] — routing-decision counters (affinity / balanced /
//!   spillover / shed) for the multi-replica serving front-end;
//! - [`fault`] — supervision counters (replica deaths, redispatches,
//!   injected faults) for the fault-tolerance layer;
//! - [`chrome`] — Chrome trace-event-format JSON export
//!   (`chrome://tracing` / Perfetto loadable) via [`crate::util::json`];
//! - [`prometheus`] — Prometheus text-exposition rendering of
//!   [`crate::serve::ServeMetrics`] plus the kernel/search counters.
//!
//! Gating mirrors `quant::simd`'s dispatch: an explicit [`set_enabled`]
//! call (tests, `--trace-out`) beats the `INVAREXPLORE_TRACE` env value.
//! `INVAREXPLORE_TRACE` semantics: unset/empty/`0`/`off`/`false` disable;
//! `1`/`on`/`true` enable; any other value enables *and* names the Chrome
//! trace output path (see [`trace_out_path`]).

/// Chrome `chrome://tracing` / Perfetto JSON export of recorded spans.
pub mod chrome;
/// Fault-handling counters: replica deaths, redispatches, injected faults.
pub mod fault;
/// Per-SIMD-tier packed-GEMM counters (calls, bytes, bandwidth).
pub mod kernel;
/// Prometheus text-format rendering of every counter family.
pub mod prometheus;
/// Router counters: routed / shed / spilled requests per replica.
pub mod router;
/// Search telemetry: per-move-family proposal and acceptance counts.
pub mod search;
/// The span recorder itself: events, spans, and the global ring buffer.
pub mod trace;

use std::sync::atomic::{AtomicU8, Ordering};

const UNSET: u8 = u8::MAX;
static ENABLED: AtomicU8 = AtomicU8::new(UNSET);

/// Is the recorder on?  The hot-path gate: one relaxed atomic load once
/// resolved (the `#[cold]` env read happens only on the very first call).
#[inline]
pub fn enabled() -> bool {
    let v = ENABLED.load(Ordering::Relaxed);
    if v != UNSET {
        return v == 1;
    }
    init()
}

#[cold]
fn init() -> bool {
    let on = !matches!(
        std::env::var("INVAREXPLORE_TRACE").as_deref(),
        Err(_) | Ok("") | Ok("0") | Ok("off") | Ok("false")
    );
    // racing first calls may both resolve; harmless (same value), lock-free
    ENABLED.store(on as u8, Ordering::Relaxed);
    if on {
        crate::info!("tracing enabled (INVAREXPLORE_TRACE)");
    }
    on
}

/// Force the recorder on or off — the in-process hook tests and the
/// `--trace-out` CLI flag use instead of mutating the environment (see the
/// getenv/setenv UB note in `util::pool`'s tests).
pub fn set_enabled(on: bool) {
    ENABLED.store(on as u8, Ordering::Relaxed);
}

/// Output path carried by `INVAREXPLORE_TRACE` when its value is neither a
/// recognized on nor off token (so `INVAREXPLORE_TRACE=trace.json` both
/// enables tracing and names the dump file).
pub fn trace_out_path() -> Option<std::path::PathBuf> {
    match std::env::var("INVAREXPLORE_TRACE") {
        Ok(v) if !matches!(v.as_str(), "" | "0" | "1" | "off" | "on" | "false" | "true") => {
            Some(v.into())
        }
        _ => None,
    }
}

/// Serializes tests that flip the global recorder on, clear rings, or read
/// the global kernel/search counters — so two tracing tests can't
/// interleave their event streams.  (Tracing never changes behavior, so a
/// race would not corrupt *results* — this keeps each test's drained event
/// stream attributable to its own run.)
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_enabled_round_trips() {
        let _g = test_guard();
        let prev = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(prev);
    }

    #[test]
    fn trace_out_path_ignores_boolean_tokens() {
        // pure parse check on the helper's token set; the env itself is not
        // mutated here (setenv in tests is UB under concurrent getenv)
        for tok in ["", "0", "1", "off", "on", "false", "true"] {
            assert!(
                matches!(tok, "" | "0" | "1" | "off" | "on" | "false" | "true"),
                "token {tok:?} must stay in sync with trace_out_path"
            );
        }
    }
}

//! Prometheus text-exposition rendering (version 0.0.4 format) of
//! [`ServeMetrics`] plus the kernel and search counters — the scrape
//! surface for `invarexplore serve --prom-out` and the serve example.
//!
//! Latency histograms render as `summary` metrics (the log₂-bucket
//! quantiles are already the resolution the dashboards use); plain counts
//! render as `counter`s and point-in-time values as `gauge`s.  All
//! durations are exported in **seconds** per Prometheus convention.

use std::fmt::Write as _;

use super::fault::{FaultSnapshot, FAULT_EVENTS};
use super::kernel::{tier_label, KernelSnapshot};
use super::router::{RouteOutcome, RouterSnapshot};
use super::search::{MoveFamily, SearchSnapshot};
use crate::serve::{Histogram, ServeMetrics};

fn summary(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} summary");
    for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
        let _ = writeln!(
            out,
            "{name}{{quantile=\"{label}\"}} {}",
            h.quantile(q).as_secs_f64()
        );
    }
    let _ = writeln!(out, "{name}_sum {}", h.sum().as_secs_f64());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

fn counter(out: &mut String, name: &str, help: &str, labels: &[(&str, &str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    for (key, val, v) in labels {
        if key.is_empty() {
            let _ = writeln!(out, "{name} {v}");
        } else {
            let _ = writeln!(out, "{name}{{{key}=\"{val}\"}} {v}");
        }
    }
}

fn gauge(out: &mut String, name: &str, help: &str, labels: &[(&str, &str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    for (key, val, v) in labels {
        if key.is_empty() {
            let _ = writeln!(out, "{name} {v}");
        } else {
            let _ = writeln!(out, "{name}{{{key}=\"{val}\"}} {v}");
        }
    }
}

/// Render the serving metrics alone.
pub fn render_serve(m: &ServeMetrics) -> String {
    let mut out = String::new();
    summary(&mut out, "invarexplore_ttft_seconds", "Submit to first token", &m.ttft);
    summary(
        &mut out,
        "invarexplore_inter_token_seconds",
        "Gap between consecutive tokens",
        &m.inter_token,
    );
    summary(
        &mut out,
        "invarexplore_queue_wait_seconds",
        "Submit to admission",
        &m.queue_wait,
    );
    summary(
        &mut out,
        "invarexplore_prefill_seconds",
        "Admission to first token",
        &m.prefill,
    );
    summary(
        &mut out,
        "invarexplore_decode_seconds",
        "First token to finish",
        &m.decode,
    );
    gauge(
        &mut out,
        "invarexplore_queue_depth",
        "Admission-round queue depth",
        &[("stat", "max", m.queue_depth_max() as f64), ("stat", "mean", m.queue_depth_mean())],
    );
    counter(
        &mut out,
        "invarexplore_prefix_cache_total",
        "Prefix cache activity",
        &[
            ("event", "lookups", m.prefix_lookups as f64),
            ("event", "hits", m.prefix_hits as f64),
            ("event", "hit_tokens", m.prefix_hit_tokens as f64),
            ("event", "evictions", m.prefix_evictions as f64),
        ],
    );
    gauge(
        &mut out,
        "invarexplore_kv_bytes_peak",
        "Peak KV residency (live vs eager-f32 baseline)",
        &[
            ("kind", "live", m.kv_live_bytes_peak as f64),
            ("kind", "eager", m.kv_eager_bytes_peak as f64),
        ],
    );
    counter(
        &mut out,
        "invarexplore_finished_total",
        "Requests finished by reason",
        &[
            ("reason", "length", m.finished_length as f64),
            ("reason", "stop", m.finished_stop as f64),
            ("reason", "cancelled", m.cancelled as f64),
            ("reason", "rejected", m.rejected as f64),
            ("reason", "timed_out", m.timed_out as f64),
            ("reason", "failed", m.failed as f64),
        ],
    );
    counter(
        &mut out,
        "invarexplore_spec_tokens_total",
        "Speculative decoding token flow",
        &[
            ("kind", "draft", m.spec_draft_tokens as f64),
            ("kind", "committed", m.spec_committed_tokens as f64),
        ],
    );
    counter(
        &mut out,
        "invarexplore_spec_verify_steps_total",
        "Chunked verify steps",
        &[("", "", m.spec_accept_len.count() as f64)],
    );
    out
}

/// Render the kernel counters.
pub fn render_kernel(k: &KernelSnapshot) -> String {
    let mut out = String::new();
    let mut secs = Vec::new();
    let mut bytes = Vec::new();
    let mut gbps = Vec::new();
    let mut rows = Vec::new();
    for (i, t) in k.tiers.iter().enumerate() {
        if t.calls == 0 && t.dequant_bytes == 0 {
            continue;
        }
        let label = tier_label(i);
        secs.push(("tier", label, t.ns as f64 * 1e-9));
        bytes.push(("tier", label, t.bytes as f64));
        gbps.push(("tier", label, t.gbps()));
        rows.push(("tier", label, t.rows as f64));
    }
    if !secs.is_empty() {
        counter(&mut out, "invarexplore_kernel_gemm_seconds_total", "Packed GEMM wall time", &secs);
        counter(
            &mut out,
            "invarexplore_kernel_gemm_bytes_total",
            "Packed weight bytes streamed by GEMM",
            &bytes,
        );
        counter(&mut out, "invarexplore_kernel_gemm_rows_total", "GEMM output rows", &rows);
        gauge(
            &mut out,
            "invarexplore_kernel_gemm_gbps",
            "Achieved packed-weight bandwidth",
            &gbps,
        );
    }
    out
}

/// Render the search counters.
pub fn render_search(s: &SearchSnapshot) -> String {
    let mut out = String::new();
    if s.proposed.iter().all(|&p| p == 0) {
        return out;
    }
    counter(
        &mut out,
        "invarexplore_search_proposed_total",
        "Search moves proposed by family",
        &[
            ("family", "transform", s.proposed_of(MoveFamily::Transform) as f64),
            ("family", "bitswap", s.proposed_of(MoveFamily::BitSwap) as f64),
        ],
    );
    counter(
        &mut out,
        "invarexplore_search_accepted_total",
        "Search moves accepted by family",
        &[
            ("family", "transform", s.accepted_of(MoveFamily::Transform) as f64),
            ("family", "bitswap", s.accepted_of(MoveFamily::BitSwap) as f64),
        ],
    );
    out
}

/// Render the router routing-decision counters.
pub fn render_router(r: &RouterSnapshot) -> String {
    let mut out = String::new();
    if r.total() == 0 {
        return out;
    }
    counter(
        &mut out,
        "invarexplore_router_routed_total",
        "Router placement decisions by outcome",
        &[
            ("outcome", RouteOutcome::Affinity.label(), r.routed_of(RouteOutcome::Affinity) as f64),
            ("outcome", RouteOutcome::Balanced.label(), r.routed_of(RouteOutcome::Balanced) as f64),
            (
                "outcome",
                RouteOutcome::Spillover.label(),
                r.routed_of(RouteOutcome::Spillover) as f64,
            ),
            ("outcome", RouteOutcome::Shed.label(), r.routed_of(RouteOutcome::Shed) as f64),
        ],
    );
    gauge(
        &mut out,
        "invarexplore_router_shed_rate",
        "Fraction of routing decisions shed",
        &[("", "", r.shed_rate())],
    );
    out
}

/// Render the supervision / fault-injection counters.
pub fn render_faults(f: &FaultSnapshot) -> String {
    let mut out = String::new();
    if f.total() == 0 {
        return out;
    }
    let labels: Vec<(&str, &str, f64)> =
        FAULT_EVENTS.iter().map(|&e| ("event", e.label(), f.count_of(e) as f64)).collect();
    counter(
        &mut out,
        "invarexplore_faults_total",
        "Supervision events by kind (deaths, redispatches, injected faults)",
        &labels,
    );
    out
}

/// Full scrape page: serve metrics plus whatever global
/// kernel/search/router/fault counters have accumulated.
pub fn render(m: &ServeMetrics) -> String {
    let mut out = render_serve(m);
    out.push_str(&render_kernel(&super::kernel::snapshot()));
    out.push_str(&render_search(&super::search::snapshot()));
    out.push_str(&render_router(&super::router::snapshot()));
    out.push_str(&render_faults(&super::fault::snapshot()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn assert_exposition_format(text: &str) {
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (_name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
    }

    #[test]
    fn serve_rendering_is_well_formed() {
        let mut m = ServeMetrics::new();
        m.ttft.record(Duration::from_millis(3));
        m.inter_token.record(Duration::from_micros(700));
        m.queue_wait.record(Duration::from_micros(40));
        m.prefill.record(Duration::from_millis(2));
        m.decode.record(Duration::from_millis(9));
        m.record_queue_depth(4);
        m.prefix_lookups = 4;
        m.prefix_hits = 1;
        m.finished_length = 2;
        let text = render_serve(&m);
        assert_exposition_format(&text);
        assert!(text.contains("invarexplore_ttft_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("invarexplore_ttft_seconds_count 1"));
        assert!(text.contains("invarexplore_queue_wait_seconds_count 1"));
        assert!(text.contains("invarexplore_prefill_seconds_count 1"));
        assert!(text.contains("invarexplore_decode_seconds_count 1"));
        assert!(text.contains("invarexplore_finished_total{reason=\"length\"} 2"));
        assert!(text.contains("# TYPE invarexplore_ttft_seconds summary"));
        // seconds, not microseconds: 3ms TTFT stays < 1
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("invarexplore_ttft_seconds_sum"))
            .unwrap();
        let v: f64 = sum_line.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!(v > 0.0 && v < 1.0, "{sum_line}");
    }

    #[test]
    fn kernel_and_search_sections_render_when_active() {
        let mut k = KernelSnapshot::default();
        k.tiers[2] = super::super::kernel::TierSnap {
            ns: 1_000_000,
            bytes: 8_000_000,
            calls: 3,
            rows: 96,
            dequant_bytes: 0,
        };
        let text = render_kernel(&k);
        assert_exposition_format(&text);
        assert!(text.contains("invarexplore_kernel_gemm_gbps{tier=\"avx2\"} 8"));
        assert!(text.contains("invarexplore_kernel_gemm_rows_total{tier=\"avx2\"} 96"));
        // idle snapshot renders nothing
        assert!(render_kernel(&KernelSnapshot::default()).is_empty());

        let mut s = SearchSnapshot::default();
        s.proposed = [10, 4];
        s.accepted = [3, 1];
        let text = render_search(&s);
        assert_exposition_format(&text);
        assert!(text.contains("invarexplore_search_proposed_total{family=\"transform\"} 10"));
        assert!(text.contains("invarexplore_search_accepted_total{family=\"bitswap\"} 1"));
        assert!(render_search(&SearchSnapshot::default()).is_empty());
    }

    #[test]
    fn fault_section_renders_when_active() {
        let mut f = FaultSnapshot::default();
        f.events[0] = 1; // replica_death
        f.events[1] = 3; // redispatch
        let text = render_faults(&f);
        assert_exposition_format(&text);
        assert!(text.contains("invarexplore_faults_total{event=\"replica_death\"} 1"));
        assert!(text.contains("invarexplore_faults_total{event=\"redispatch\"} 3"));
        assert!(text.contains("invarexplore_faults_total{event=\"request_failed\"} 0"));
        assert!(render_faults(&FaultSnapshot::default()).is_empty());
    }

    #[test]
    fn finished_total_includes_fault_reasons() {
        let mut m = ServeMetrics::new();
        m.timed_out = 2;
        m.failed = 1;
        let text = render_serve(&m);
        assert!(text.contains("invarexplore_finished_total{reason=\"timed_out\"} 2"));
        assert!(text.contains("invarexplore_finished_total{reason=\"failed\"} 1"));
    }

    #[test]
    fn router_section_renders_when_active() {
        let r = RouterSnapshot { routed: [6, 2, 1, 1] };
        let text = render_router(&r);
        assert_exposition_format(&text);
        assert!(text.contains("invarexplore_router_routed_total{outcome=\"affinity\"} 6"));
        assert!(text.contains("invarexplore_router_routed_total{outcome=\"shed\"} 1"));
        assert!(text.contains("invarexplore_router_shed_rate 0.1"));
        assert!(render_router(&RouterSnapshot::default()).is_empty());
    }
}

//! Telemetry for the discrete search drivers: per-move-family
//! propose/accept counters and a short windowed acceptance rate.
//!
//! The InvarExplore search alternates two move families — invariance
//! `Transform`s (permute/sign/rotate) and mixed-precision `BitSwap`s — and
//! which family is actually *paying* is the first question every tuning
//! session asks (PTQ1.61 makes the same point for sub-2-bit search).  The
//! drivers in `search::hillclimb` / `search::scheduler` report each
//! proposal here after the accept decision is made, so recording can never
//! influence it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// Which kind of move a proposal drew (mirrors `search::hillclimb::Move`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoveFamily {
    /// An invariance transform (permutation / sign flip / rotation).
    Transform,
    /// A bit-width swap between two layers at fixed budget.
    BitSwap,
}

impl MoveFamily {
    /// Short stable label (metric names / JSON field values).
    pub fn label(self) -> &'static str {
        match self {
            MoveFamily::Transform => "transform",
            MoveFamily::BitSwap => "bitswap",
        }
    }

    fn idx(self) -> usize {
        match self {
            MoveFamily::Transform => 0,
            MoveFamily::BitSwap => 1,
        }
    }
}

const N_FAMILIES: usize = 2;

/// Sliding accept/reject window (last [`ACCEPT_WINDOW`] decisions) backing
/// the `search.accept_rate_w64` counter samples.
pub const ACCEPT_WINDOW: u32 = 64;

struct Window {
    bits: u64,
    len: u32,
}

/// The counter state itself — instantiable so tests can exercise the exact
/// arithmetic on a private instance while production code shares one
/// gated global.
struct Counters {
    proposed: [AtomicU64; N_FAMILIES],
    accepted: [AtomicU64; N_FAMILIES],
    window: Mutex<Window>,
}

impl Counters {
    const fn new() -> Counters {
        Counters {
            proposed: [AtomicU64::new(0), AtomicU64::new(0)],
            accepted: [AtomicU64::new(0), AtomicU64::new(0)],
            window: Mutex::new(Window { bits: 0, len: 0 }),
        }
    }

    /// Record one decision; returns the windowed acceptance rate after it.
    fn record(&self, family: MoveFamily, accepted: bool) -> f64 {
        let i = family.idx();
        self.proposed[i].fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.accepted[i].fetch_add(1, Ordering::Relaxed);
        }
        let mut w = self.window.lock().unwrap_or_else(|e| e.into_inner());
        w.bits = (w.bits << 1) | accepted as u64;
        w.len = (w.len + 1).min(ACCEPT_WINDOW);
        let mask = if w.len >= 64 { u64::MAX } else { (1u64 << w.len) - 1 };
        (w.bits & mask).count_ones() as f64 / w.len as f64
    }

    fn snapshot(&self) -> SearchSnapshot {
        let mut s = SearchSnapshot::default();
        for i in 0..N_FAMILIES {
            s.proposed[i] = self.proposed[i].load(Ordering::Relaxed);
            s.accepted[i] = self.accepted[i].load(Ordering::Relaxed);
        }
        s
    }

    fn reset(&self) {
        for i in 0..N_FAMILIES {
            self.proposed[i].store(0, Ordering::Relaxed);
            self.accepted[i].store(0, Ordering::Relaxed);
        }
        let mut w = self.window.lock().unwrap_or_else(|e| e.into_inner());
        w.bits = 0;
        w.len = 0;
    }
}

static GLOBAL: Counters = Counters::new();

/// Record one search proposal's outcome.  Gated: free (one relaxed load)
/// when tracing is off; emits an acceptance-rate counter sample when on.
pub fn record_move(family: MoveFamily, accepted: bool) {
    if !super::enabled() {
        return;
    }
    let rate = GLOBAL.record(family, accepted);
    super::trace::counter("search", "accept_rate_w64", rate);
}

/// Point-in-time copy of the per-family counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchSnapshot {
    /// Proposals drawn per family: `[transform, bitswap]`.
    pub proposed: [u64; N_FAMILIES],
    /// Proposals accepted per family, same order as `proposed`.
    pub accepted: [u64; N_FAMILIES],
}

impl SearchSnapshot {
    /// Proposals drawn for one family.
    pub fn proposed_of(&self, f: MoveFamily) -> u64 {
        self.proposed[f.idx()]
    }

    /// Proposals accepted for one family.
    pub fn accepted_of(&self, f: MoveFamily) -> u64 {
        self.accepted[f.idx()]
    }

    /// Lifetime acceptance rate for one family (0 when nothing proposed).
    pub fn accept_rate(&self, f: MoveFamily) -> f64 {
        let p = self.proposed[f.idx()];
        if p == 0 {
            0.0
        } else {
            self.accepted[f.idx()] as f64 / p as f64
        }
    }

    /// `{transform: {proposed, accepted, accept_rate}, bitswap: {...}}`.
    pub fn to_json(&self) -> Json {
        let fam = |f: MoveFamily| {
            Json::obj()
                .set("proposed", self.proposed_of(f) as usize)
                .set("accepted", self.accepted_of(f) as usize)
                .set("accept_rate", self.accept_rate(f))
        };
        Json::obj()
            .set("transform", fam(MoveFamily::Transform))
            .set("bitswap", fam(MoveFamily::BitSwap))
    }
}

/// Read the global per-family counters.
pub fn snapshot() -> SearchSnapshot {
    GLOBAL.snapshot()
}

/// Zero the global counters and acceptance window (test/run isolation).
pub fn reset() {
    GLOBAL.reset()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing_globally() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        reset();
        record_move(MoveFamily::Transform, true);
        assert_eq!(snapshot(), SearchSnapshot::default());
    }

    #[test]
    fn per_family_counts_and_rates() {
        // a private instance: exact counts without racing other tests on
        // the gated global
        let c = Counters::new();
        let r1 = c.record(MoveFamily::Transform, true);
        let r2 = c.record(MoveFamily::Transform, false);
        let r3 = c.record(MoveFamily::BitSwap, true);
        let s = c.snapshot();
        assert_eq!(s.proposed_of(MoveFamily::Transform), 2);
        assert_eq!(s.accepted_of(MoveFamily::Transform), 1);
        assert!((s.accept_rate(MoveFamily::Transform) - 0.5).abs() < 1e-12);
        assert_eq!(s.proposed_of(MoveFamily::BitSwap), 1);
        assert!((s.accept_rate(MoveFamily::BitSwap) - 1.0).abs() < 1e-12);
        // windowed rate after each decision: 1/1, 1/2, 2/3
        assert!((r1 - 1.0).abs() < 1e-12);
        assert!((r2 - 0.5).abs() < 1e-12);
        assert!((r3 - 2.0 / 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("transform").unwrap().get("proposed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("bitswap").unwrap().get("accepted").unwrap().as_usize(), Some(1));
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
        c.reset();
        assert_eq!(c.snapshot(), SearchSnapshot::default());
    }

    #[test]
    fn window_saturates_at_capacity() {
        let c = Counters::new();
        for _ in 0..(ACCEPT_WINDOW + 16) {
            c.record(MoveFamily::Transform, false);
        }
        let rate = c.record(MoveFamily::Transform, true);
        // exactly one accept in a full window of 64
        assert!((rate - 1.0 / ACCEPT_WINDOW as f64).abs() < 1e-12);
    }

    #[test]
    fn enabled_global_samples_rate_counter() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        super::super::trace::clear();
        reset();
        record_move(MoveFamily::BitSwap, true);
        crate::obs::set_enabled(false);
        let s = snapshot();
        assert!(s.proposed_of(MoveFamily::BitSwap) >= 1);
        assert!(super::super::trace::take_events()
            .iter()
            .any(|e| e.name == "accept_rate_w64"));
        reset();
    }
}

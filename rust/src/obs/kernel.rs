//! Per-SIMD-tier kernel counters for the packed serving kernels: bytes
//! streamed, GEMM rows, wall time per dispatch tier → achieved GB/s.
//!
//! The instrumented kernels (`quant::packed`) never touch a clock type
//! themselves — they take an opaque [`GemmTimer`] from here, so the
//! `nondet-clock` lint keeps `quant/` clock-free by construction and every
//! wall-clock read stays inside `obs/` with a `DETERMINISM:` note.
//!
//! Ultra-low-bit GEMV is a memory-bandwidth story (see the low-bit LLM
//! systems survey), so the headline derived metric is *packed weight bytes
//! streamed per second of kernel wall time*, split by dispatch tier: a
//! tier whose GB/s does not beat the one below is not paying for itself.

use std::sync::atomic::{AtomicU64, Ordering};
// DETERMINISM: kernel timing is observational only — elapsed nanoseconds
// feed the GB/s counters and trace export, never any kernel result or
// dispatch decision.
use std::time::Instant;

use crate::quant::simd;
use crate::util::json::Json;

/// One cell per [`simd::SimdLevel`] discriminant.
pub const N_TIERS: usize = 3;

/// Human label per tier index (matches `SimdLevel` discriminant order).
pub fn tier_label(i: usize) -> &'static str {
    ["scalar", "sse2", "avx2"][i.min(N_TIERS - 1)]
}

struct TierCell {
    ns: AtomicU64,
    bytes: AtomicU64,
    calls: AtomicU64,
    rows: AtomicU64,
    dequant_bytes: AtomicU64,
}

impl TierCell {
    const fn new() -> TierCell {
        TierCell {
            ns: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            calls: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            dequant_bytes: AtomicU64::new(0),
        }
    }
}

static TIERS: [TierCell; N_TIERS] = [TierCell::new(), TierCell::new(), TierCell::new()];

/// Wall-clock guard for one fused GEMM/GEMV call.  Inert (no clock read)
/// when tracing is disabled — the disabled cost at the call site is the
/// single relaxed load inside [`super::enabled`].
pub struct GemmTimer {
    // DETERMINISM: start stamp + tier index; observational only (module
    // clock note).
    start: Option<(Instant, usize)>,
}

/// Begin timing a packed GEMM call at the current dispatch tier.
#[inline]
pub fn gemm_timer() -> GemmTimer {
    if !super::enabled() {
        return GemmTimer { start: None };
    }
    let tier = simd::level() as usize;
    // DETERMINISM: start capture, observational only.
    GemmTimer { start: Some((Instant::now(), tier)) }
}

impl GemmTimer {
    /// Close the timed region, crediting `rows` output-row dot products and
    /// `bytes` of packed weight traffic to the tier the call dispatched at.
    #[inline]
    pub fn finish(self, rows: usize, bytes: usize) {
        let Some((t0, tier)) = self.start else { return };
        let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        let c = &TIERS[tier.min(N_TIERS - 1)];
        c.ns.fetch_add(ns, Ordering::Relaxed);
        c.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        c.rows.fetch_add(rows as u64, Ordering::Relaxed);
        c.calls.fetch_add(1, Ordering::Relaxed);
    }
}

/// Credit `bytes` of packed weights decoded by a standalone dequant entry
/// point (outside a timed GEMM) to the current tier.
#[inline]
pub fn add_dequant_bytes(bytes: usize) {
    if !super::enabled() {
        return;
    }
    let tier = simd::level() as usize;
    TIERS[tier.min(N_TIERS - 1)].dequant_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Point-in-time copy of one tier's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierSnap {
    /// Nanoseconds spent inside timed GEMM calls.
    pub ns: u64,
    /// Packed weight bytes streamed by timed GEMM calls.
    pub bytes: u64,
    /// Number of timed GEMM calls.
    pub calls: u64,
    /// Output rows produced across all calls.
    pub rows: u64,
    /// Packed bytes decoded by standalone dequant entry points.
    pub dequant_bytes: u64,
}

impl TierSnap {
    /// Achieved packed-weight bandwidth: bytes per nanosecond == GB/s.
    pub fn gbps(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.bytes as f64 / self.ns as f64
        }
    }
}

/// Point-in-time copy of all kernel counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelSnapshot {
    /// Per-SIMD-tier counters, indexed by `SimdLevel as usize`.
    pub tiers: [TierSnap; N_TIERS],
}

impl KernelSnapshot {
    /// Timed GEMM calls summed over every tier.
    pub fn total_calls(&self) -> u64 {
        self.tiers.iter().map(|t| t.calls).sum()
    }

    /// Packed weight bytes streamed, summed over every tier.
    pub fn total_bytes(&self) -> u64 {
        self.tiers.iter().map(|t| t.bytes).sum()
    }

    /// `{tiers: {scalar: {...}, sse2: {...}, avx2: {...}}}` — only tiers
    /// that recorded anything, so idle tiers don't pad dumps.
    pub fn to_json(&self) -> Json {
        let mut tiers = Json::obj();
        for (i, t) in self.tiers.iter().enumerate() {
            if t.calls == 0 && t.dequant_bytes == 0 {
                continue;
            }
            tiers = tiers.set(
                tier_label(i),
                Json::obj()
                    .set("gemm_calls", t.calls as usize)
                    .set("gemm_rows", t.rows as usize)
                    .set("gemm_bytes", t.bytes as usize)
                    .set("gemm_ns", t.ns as usize)
                    .set("gemm_gbps", t.gbps())
                    .set("dequant_bytes", t.dequant_bytes as usize),
            );
        }
        Json::obj().set("tiers", tiers)
    }

    /// Flat `(name, value)` pairs for the bench-JSON `counters` object and
    /// the perf-history GB/s drift check — one `kernel_gemm_gbps_<tier>`
    /// per active tier plus its byte/call volume (so a drift reader can
    /// discount low-volume samples).
    pub fn counters(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (i, t) in self.tiers.iter().enumerate() {
            if t.calls == 0 {
                continue;
            }
            let label = tier_label(i);
            out.push((format!("kernel_gemm_gbps_{label}"), t.gbps()));
            out.push((format!("kernel_gemm_bytes_{label}"), t.bytes as f64));
            out.push((format!("kernel_gemm_calls_{label}"), t.calls as f64));
        }
        out
    }
}

/// Read every tier's counters.
pub fn snapshot() -> KernelSnapshot {
    let mut s = KernelSnapshot::default();
    for (i, c) in TIERS.iter().enumerate() {
        s.tiers[i] = TierSnap {
            ns: c.ns.load(Ordering::Relaxed),
            bytes: c.bytes.load(Ordering::Relaxed),
            calls: c.calls.load(Ordering::Relaxed),
            rows: c.rows.load(Ordering::Relaxed),
            dequant_bytes: c.dequant_bytes.load(Ordering::Relaxed),
        };
    }
    s
}

/// Zero every counter (test/bench isolation; the counters are global).
pub fn reset() {
    for c in TIERS.iter() {
        c.ns.store(0, Ordering::Relaxed);
        c.bytes.store(0, Ordering::Relaxed);
        c.calls.store(0, Ordering::Relaxed);
        c.rows.store(0, Ordering::Relaxed);
        c.dequant_bytes.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_records_nothing() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        reset();
        let t = gemm_timer();
        t.finish(100, 1 << 20);
        add_dequant_bytes(1 << 20);
        assert_eq!(snapshot(), KernelSnapshot::default());
    }

    #[test]
    fn enabled_timer_accumulates_per_tier() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        reset();
        let tier = simd::level() as usize;
        let t = gemm_timer();
        std::hint::black_box(1 + 1);
        t.finish(64, 4096);
        add_dequant_bytes(512);
        crate::obs::set_enabled(false);
        let s = snapshot();
        // ≥, not ==: other tests' instrumented kernels may run while the
        // recorder is briefly on (the counters are global)
        assert!(s.tiers[tier].calls >= 1);
        assert!(s.tiers[tier].rows >= 64);
        assert!(s.tiers[tier].bytes >= 4096);
        assert!(s.tiers[tier].dequant_bytes >= 512);
        assert!(s.tiers[tier].gbps() >= 0.0);
        // JSON dump names the active tier and parses back
        let j = s.to_json();
        let tj = j.get("tiers").unwrap().get(tier_label(tier)).unwrap();
        assert!(tj.get("gemm_calls").unwrap().as_usize().unwrap() >= 1);
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
        // flat counters carry the gbps key the perf gate parses
        let names: Vec<_> = s.counters().into_iter().map(|(n, _)| n).collect();
        assert!(names.iter().any(|n| n == &format!("kernel_gemm_gbps_{}", tier_label(tier))));
        reset();
        assert_eq!(snapshot(), KernelSnapshot::default());
    }

    #[test]
    fn gbps_is_bytes_per_ns() {
        let t = TierSnap { ns: 2_000, bytes: 4_000, calls: 1, rows: 1, dequant_bytes: 0 };
        assert!((t.gbps() - 2.0).abs() < 1e-12);
        assert_eq!(TierSnap::default().gbps(), 0.0);
    }
}

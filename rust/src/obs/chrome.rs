//! Chrome trace-event-format export (`chrome://tracing` / Perfetto / the
//! `about:tracing` JSON flavor) for the recorder's event stream.
//!
//! We emit the object form — `{"traceEvents": [...], "displayTimeUnit":
//! "ms"}` — with `ph: "X"` complete events for spans, `ph: "i"` instants
//! and `ph: "C"` counters, all timestamped in microseconds since the trace
//! epoch as the format requires.  Serialization goes through
//! [`crate::util::json`]; no new dependency.

use std::path::Path;

use super::trace::{self, Event, Phase};
use crate::util::json::Json;

/// One event in Chrome trace-event JSON shape (shared with
/// `util::bench`'s TRACE_<suite>.json writer, which splices recorder
/// events in next to its own bench-row spans).
pub(crate) fn event_json(ev: &Event) -> Json {
    let mut j = Json::obj()
        .set("name", ev.name)
        .set("cat", ev.cat)
        .set("ph", ev.ph.ph())
        .set("pid", 1usize)
        .set("tid", ev.tid as usize)
        .set("ts", ev.ts_us as f64);
    match ev.ph {
        Phase::Complete => {
            j = j.set("dur", ev.dur_us as f64);
            if ev.id != 0 {
                j = j.set("args", Json::obj().set("id", ev.id as usize));
            }
        }
        Phase::Mark => {
            // "t": thread-scoped instant (the viewer draws it on its track)
            j = j.set("s", "t");
            if ev.id != 0 {
                j = j.set("args", Json::obj().set("id", ev.id as usize));
            }
        }
        Phase::Counter => {
            j = j.set("args", Json::obj().set("value", ev.value));
        }
    }
    j
}

/// Render an event stream as a Chrome trace JSON document.
pub fn trace_json(events: &[Event]) -> Json {
    Json::obj()
        .set("displayTimeUnit", "ms")
        .set("traceEvents", Json::Arr(events.iter().map(event_json).collect()))
}

/// Write `events` to `path` as Chrome trace JSON.
pub fn write(path: &Path, events: &[Event]) -> crate::Result<()> {
    std::fs::write(path, trace_json(events).to_string())?;
    Ok(())
}

/// Drain the recorder and write everything to `path`; returns how many
/// events were dumped.  If any were lost to ring overflow, a final
/// `trace.dropped_events` counter records the loss in-band.
pub fn dump(path: &Path) -> crate::Result<usize> {
    let mut events = trace::take_events();
    let dropped = trace::dropped_total();
    if dropped > 0 {
        let ts = events.last().map_or(0, |e| e.ts_us);
        events.push(Event {
            cat: "trace",
            name: "trace.dropped_events",
            ph: Phase::Counter,
            ts_us: ts,
            dur_us: 0,
            tid: 0,
            id: 0,
            value: dropped as f64,
        });
        trace::reset_dropped();
    }
    write(path, &events)?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ph: Phase) -> Event {
        Event {
            cat: "serve",
            name: "prefill",
            ph,
            ts_us: 120,
            dur_us: 30,
            tid: 2,
            id: 7,
            value: 1.5,
        }
    }

    #[test]
    fn complete_event_shape() {
        let j = event_json(&ev(Phase::Complete));
        assert_eq!(j.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(j.get("ts").unwrap().as_f64(), Some(120.0));
        assert_eq!(j.get("dur").unwrap().as_f64(), Some(30.0));
        assert_eq!(j.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("tid").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("args").unwrap().get("id").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn counter_and_mark_shapes() {
        let c = event_json(&ev(Phase::Counter));
        assert_eq!(c.get("ph").unwrap().as_str(), Some("C"));
        assert!((c.get("args").unwrap().get("value").unwrap().as_f64().unwrap() - 1.5) < 1e-12);
        assert!(c.get("dur").is_none());
        let m = event_json(&ev(Phase::Mark));
        assert_eq!(m.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(m.get("s").unwrap().as_str(), Some("t"));
    }

    #[test]
    fn document_round_trips_and_is_loadable_shape() {
        let events = [ev(Phase::Complete), ev(Phase::Counter), ev(Phase::Mark)];
        let doc = trace_json(&events);
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        let arr = back.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        for e in arr {
            // every event carries the fields trace viewers key on
            assert!(e.get("name").unwrap().as_str().is_some());
            assert!(e.get("ph").unwrap().as_str().is_some());
            assert!(e.get("ts").unwrap().as_f64().is_some());
        }
        assert_eq!(back.req("displayTimeUnit").unwrap().as_str(), Some("ms"));
    }

    #[test]
    fn dump_writes_file_and_flags_drops() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        trace::clear();
        trace::counter("test", "x", 1.0);
        crate::obs::set_enabled(false);
        let dir = std::env::temp_dir().join("invarexplore_obs_chrome_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let n = dump(&path).unwrap();
        assert!(n >= 1);
        let j = crate::util::json::parse_file(&path).unwrap();
        let arr = j.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(arr
            .iter()
            .any(|e| e.get("name").unwrap().as_str() == Some("x")));
    }
}

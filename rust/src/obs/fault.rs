//! Fault-handling telemetry: injected-fault and recovery counters for the
//! serving stack's supervision layer.
//!
//! `serve::router`'s supervision (and `serve::fault`'s injectors) report
//! every event here *after* acting on it, so recording can never influence
//! recovery decisions.  Like every `obs` module this is gated on
//! [`crate::obs::enabled`] — one relaxed atomic load when tracing is off.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// One fault-handling event in the serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// A replica thread died (panicked) during a router run.
    ReplicaDeath,
    /// An orphaned (or transiently-refused) request was resubmitted to a
    /// surviving replica.
    Redispatch,
    /// An injected transient fault refused one dispatch attempt.
    TransientInjected,
    /// An injected stall delayed one slot's decode round.
    StallInjected,
    /// A request exhausted its retry budget and finished `Failed`.
    RequestFailed,
    /// A queued request's deadline expired and it finished `TimedOut`.
    RequestTimedOut,
}

impl FaultEvent {
    /// Short stable label (metrics / JSON field values).
    pub fn label(self) -> &'static str {
        match self {
            FaultEvent::ReplicaDeath => "replica_death",
            FaultEvent::Redispatch => "redispatch",
            FaultEvent::TransientInjected => "transient_injected",
            FaultEvent::StallInjected => "stall_injected",
            FaultEvent::RequestFailed => "request_failed",
            FaultEvent::RequestTimedOut => "request_timed_out",
        }
    }

    fn idx(self) -> usize {
        match self {
            FaultEvent::ReplicaDeath => 0,
            FaultEvent::Redispatch => 1,
            FaultEvent::TransientInjected => 2,
            FaultEvent::StallInjected => 3,
            FaultEvent::RequestFailed => 4,
            FaultEvent::RequestTimedOut => 5,
        }
    }
}

/// Every [`FaultEvent`], in `idx` order (snapshot/JSON/Prometheus order).
pub const FAULT_EVENTS: [FaultEvent; 6] = [
    FaultEvent::ReplicaDeath,
    FaultEvent::Redispatch,
    FaultEvent::TransientInjected,
    FaultEvent::StallInjected,
    FaultEvent::RequestFailed,
    FaultEvent::RequestTimedOut,
];

const N_EVENTS: usize = FAULT_EVENTS.len();

/// The counter state itself — instantiable so tests can exercise the exact
/// arithmetic on a private instance while production code shares one gated
/// global.
struct Counters {
    events: [AtomicU64; N_EVENTS],
}

impl Counters {
    const fn new() -> Counters {
        Counters {
            events: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }

    fn record(&self, event: FaultEvent) {
        self.events[event.idx()].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> FaultSnapshot {
        let mut s = FaultSnapshot::default();
        for (dst, src) in s.events.iter_mut().zip(&self.events) {
            *dst = src.load(Ordering::Relaxed);
        }
        s
    }

    fn reset(&self) {
        for c in &self.events {
            c.store(0, Ordering::Relaxed);
        }
    }
}

static GLOBAL: Counters = Counters::new();

/// Record one fault-handling event.  Gated: free (one relaxed load) when
/// tracing is off; emits a `fault.replica_deaths` counter sample when on
/// and a replica died (the signal dashboards page on).
pub fn record_fault(event: FaultEvent) {
    if !super::enabled() {
        return;
    }
    GLOBAL.record(event);
    if event == FaultEvent::ReplicaDeath {
        let deaths = GLOBAL.snapshot().count_of(FaultEvent::ReplicaDeath);
        super::trace::counter("fault", "replica_deaths", deaths as f64);
    }
}

/// Point-in-time copy of the fault-handling counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSnapshot {
    /// Indexed like [`FaultEvent::label`]s in [`FAULT_EVENTS`] order.
    pub events: [u64; N_EVENTS],
}

impl FaultSnapshot {
    /// Occurrences of `event`.
    pub fn count_of(&self, event: FaultEvent) -> u64 {
        self.events[event.idx()]
    }

    /// All fault events recorded.
    pub fn total(&self) -> u64 {
        self.events.iter().sum()
    }

    /// One key per [`FaultEvent`] label.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for e in FAULT_EVENTS {
            j = j.set(e.label(), self.count_of(e) as usize);
        }
        j
    }
}

/// Read the global fault counters.
pub fn snapshot() -> FaultSnapshot {
    GLOBAL.snapshot()
}

/// Zero the global fault counters (test/run isolation).
pub fn reset() {
    GLOBAL.reset()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing_globally() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        reset();
        record_fault(FaultEvent::ReplicaDeath);
        record_fault(FaultEvent::Redispatch);
        assert_eq!(snapshot(), FaultSnapshot::default());
    }

    #[test]
    fn per_event_counts_and_json() {
        // a private instance: exact counts without racing other tests on
        // the gated global
        let c = Counters::new();
        c.record(FaultEvent::ReplicaDeath);
        c.record(FaultEvent::Redispatch);
        c.record(FaultEvent::Redispatch);
        c.record(FaultEvent::RequestTimedOut);
        let s = c.snapshot();
        assert_eq!(s.count_of(FaultEvent::ReplicaDeath), 1);
        assert_eq!(s.count_of(FaultEvent::Redispatch), 2);
        assert_eq!(s.count_of(FaultEvent::StallInjected), 0);
        assert_eq!(s.total(), 4);
        let j = s.to_json();
        assert_eq!(j.get("redispatch").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("request_timed_out").unwrap().as_usize(), Some(1));
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
        c.reset();
        assert_eq!(c.snapshot(), FaultSnapshot::default());
    }

    #[test]
    fn labels_are_unique_and_stable() {
        let labels: Vec<&str> = FAULT_EVENTS.iter().map(|e| e.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len(), "duplicate fault labels");
        assert_eq!(labels[0], "replica_death");
    }

    #[test]
    fn enabled_global_samples_death_counter() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        super::super::trace::clear();
        reset();
        record_fault(FaultEvent::ReplicaDeath);
        crate::obs::set_enabled(false);
        assert!(snapshot().count_of(FaultEvent::ReplicaDeath) >= 1);
        assert!(super::super::trace::take_events().iter().any(|e| e.name == "replica_deaths"));
        reset();
    }
}

//! Lock-light per-thread ring-buffer event recorder.
//!
//! Each thread appends into its **own** bounded ring behind a mutex that
//! only that thread touches on the hot path (a global drain briefly locks
//! each ring), so recording is uncontended: one relaxed gate load when
//! tracing is off, one uncontended lock + array store when it is on.  Rings
//! are bounded ([`RING_CAP`] events per thread) and drop **oldest** on
//! overflow, keeping the tail of a run — the interesting part — while
//! counting what was lost ([`dropped_total`]).
//!
//! Timestamps are microseconds since a process-wide trace epoch (first
//! event wins), matching the Chrome trace-event `ts` unit.  All clock reads
//! live in this module (and `obs::kernel`) so instrumented kernels under
//! `quant/` and `model/` never touch a clock type themselves.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, LazyLock, Mutex, MutexGuard};
// DETERMINISM: the trace clock is observational only — timestamps are
// recorded into event buffers and exported, never read back into any
// scheduling, sampling or numeric decision, so wall-clock nondeterminism
// cannot leak into results.
use std::time::Instant;

/// Events retained per thread before drop-oldest kicks in.
pub const RING_CAP: usize = 1 << 14;

/// Chrome trace-event phase of an [`Event`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// A closed span (`ph: "X"`): `ts_us` + `dur_us`.
    Complete,
    /// A point-in-time mark (`ph: "i"`).
    Mark,
    /// A sampled counter value (`ph: "C"`).
    Counter,
}

impl Phase {
    /// The Chrome trace-event `ph` code.
    pub fn ph(self) -> &'static str {
        match self {
            Phase::Complete => "X",
            Phase::Mark => "i",
            Phase::Counter => "C",
        }
    }
}

/// One recorded trace event.  `&'static str` names keep the record path
/// allocation-free; `id` carries the request/slot the event belongs to
/// (0 when not applicable) and `value` the sample for counter events.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Category (subsystem) label, e.g. `"serve"` or `"kernel"`.
    pub cat: &'static str,
    /// Event name within the category.
    pub name: &'static str,
    /// Chrome trace phase this event renders as.
    pub ph: Phase,
    /// Microseconds since the trace epoch.
    pub ts_us: u64,
    /// Span duration in microseconds (0 for marks/counters).
    pub dur_us: u64,
    /// Stable per-thread index (registration order, not OS thread id).
    pub tid: u64,
    /// Request/slot the event belongs to (0 when not applicable).
    pub id: u64,
    /// Sample value for counter events (0.0 otherwise).
    pub value: f64,
}

struct Ring {
    tid: u64,
    buf: Vec<Event>,
    /// Overwrite cursor once `buf` is full (points at the oldest event).
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.buf.len() < RING_CAP {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    /// Take all events oldest-first, leaving the ring empty.
    fn drain(&mut self) -> Vec<Event> {
        let mut out = std::mem::take(&mut self.buf);
        if out.len() == RING_CAP && self.next > 0 {
            out.rotate_left(self.next);
        }
        self.next = 0;
        out
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

static REGISTRY: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(Mutex::new(Ring { tid, buf: Vec::new(), next: 0, dropped: 0 }));
        lock(&REGISTRY).push(Arc::clone(&ring));
        ring
    };
}

// DETERMINISM: process-wide trace epoch; see the module-level clock note —
// only event timestamps derive from it.
static EPOCH: LazyLock<Instant> = LazyLock::new(
    // DETERMINISM: epoch capture, observational only.
    Instant::now,
);

/// Microseconds since the trace epoch (saturating at 0 for pre-epoch
/// instants, which can only happen for timestamps captured before tracing
/// was first enabled).
// DETERMINISM: converts an already-captured instant; observational only.
pub(crate) fn rel_us(t: Instant) -> u64 {
    t.saturating_duration_since(*EPOCH).as_micros().min(u64::MAX as u128) as u64
}

fn push(mut ev: Event) {
    LOCAL.with(|r| {
        let mut g = lock(r);
        ev.tid = g.tid;
        g.push(ev);
    });
}

/// RAII span: records a [`Phase::Complete`] event from construction to
/// drop.  Construct via [`span`]; when tracing is disabled at construction
/// the guard is inert (no clock read, nothing recorded on drop).
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    id: u64,
    // DETERMINISM: span start stamp, observational only (module clock note).
    start: Option<Instant>,
}

/// Open a span; the returned guard records it when dropped.
#[inline]
pub fn span(cat: &'static str, name: &'static str, id: u64) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { cat, name, id, start: None };
    }
    let _ = *EPOCH; // pin the epoch at or before every recorded stamp
    // DETERMINISM: span start capture, observational only.
    SpanGuard { cat, name, id, start: Some(Instant::now()) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            // DETERMINISM: span end capture, observational only.
            let end = Instant::now();
            push(Event {
                cat: self.cat,
                name: self.name,
                ph: Phase::Complete,
                ts_us: rel_us(t0),
                dur_us: end.saturating_duration_since(t0).as_micros().min(u64::MAX as u128)
                    as u64,
                tid: 0,
                id: self.id,
                value: 0.0,
            });
        }
    }
}

/// Record a closed span from externally-captured endpoints.  The scheduler
/// uses this to stamp per-request lifecycle phases whose boundaries it
/// already tracks (submit/admit/first-token/finish), so span-derived
/// durations agree with `ServeMetrics` to the microsecond.
// DETERMINISM: endpoint instants were captured by the caller; conversion
// here is observational only (module clock note).
pub fn complete(cat: &'static str, name: &'static str, id: u64, start: Instant, end: Instant) {
    if !super::enabled() {
        return;
    }
    let _ = *EPOCH;
    push(Event {
        cat,
        name,
        ph: Phase::Complete,
        ts_us: rel_us(start),
        dur_us: end.saturating_duration_since(start).as_micros().min(u64::MAX as u128) as u64,
        tid: 0,
        id,
        value: 0.0,
    });
}

/// Record a point-in-time mark at "now".
pub fn mark(cat: &'static str, name: &'static str, id: u64) {
    if !super::enabled() {
        return;
    }
    let _ = *EPOCH;
    // DETERMINISM: mark stamp, observational only.
    let ts = rel_us(Instant::now());
    push(Event { cat, name, ph: Phase::Mark, ts_us: ts, dur_us: 0, tid: 0, id, value: 0.0 });
}

/// Record a counter sample at "now".
pub fn counter(cat: &'static str, name: &'static str, value: f64) {
    if !super::enabled() {
        return;
    }
    let _ = *EPOCH;
    // DETERMINISM: counter stamp, observational only.
    let ts = rel_us(Instant::now());
    push(Event { cat, name, ph: Phase::Counter, ts_us: ts, dur_us: 0, tid: 0, id: 0, value });
}

/// Drain every thread's ring, returning all events sorted by
/// `(ts_us, tid)` (stable within a thread).  Dropped-event counts are
/// folded into [`dropped_total`].
pub fn take_events() -> Vec<Event> {
    let rings: Vec<_> = lock(&REGISTRY).iter().map(Arc::clone).collect();
    let mut out = Vec::new();
    for r in rings {
        let mut g = lock(&r);
        DROPPED.fetch_add(g.dropped, Ordering::Relaxed);
        g.dropped = 0;
        out.extend(g.drain());
    }
    out.sort_by_key(|e| (e.ts_us, e.tid));
    out
}

/// Events lost to ring overflow since the last [`reset_dropped`] (including
/// rings already drained).
pub fn dropped_total() -> u64 {
    let pending: u64 = lock(&REGISTRY).iter().map(|r| lock(r).dropped).sum();
    DROPPED.load(Ordering::Relaxed) + pending
}

/// Zero the dropped-event counter (rings keep their contents).
pub fn reset_dropped() {
    DROPPED.store(0, Ordering::Relaxed);
    for r in lock(&REGISTRY).iter() {
        lock(r).dropped = 0;
    }
}

/// Discard all buffered events and dropped counts (test isolation).
pub fn clear() {
    for r in lock(&REGISTRY).iter() {
        let mut g = lock(r);
        g.drain();
        g.dropped = 0;
    }
    DROPPED.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        clear();
        {
            let _s = span("test", "noop", 1);
            counter("test", "c", 1.0);
            mark("test", "m", 1);
        }
        // filter: concurrently-running (non-obs) tests share the global
        // rings, so only our own category proves anything
        assert!(take_events().iter().all(|e| e.cat != "test"));
    }

    #[test]
    fn span_guard_records_complete_event() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        clear();
        {
            let _s = span("test", "work", 42);
            std::hint::black_box(1 + 1);
        }
        counter("test", "gauge", 2.5);
        mark("test", "tick", 7);
        crate::obs::set_enabled(false);
        let evs: Vec<_> = take_events().into_iter().filter(|e| e.cat == "test").collect();
        let sp = evs.iter().find(|e| e.name == "work").expect("span recorded");
        assert_eq!(sp.ph, Phase::Complete);
        assert_eq!(sp.id, 42);
        let c = evs.iter().find(|e| e.name == "gauge").expect("counter recorded");
        assert_eq!(c.ph, Phase::Counter);
        assert!((c.value - 2.5).abs() < 1e-12);
        assert!(evs.iter().any(|e| e.name == "tick" && e.ph == Phase::Mark));
        // drained: a second take holds none of our events
        assert!(take_events().iter().all(|e| e.cat != "test"));
    }

    #[test]
    fn events_come_out_time_sorted() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        clear();
        for i in 0..32 {
            counter("test", "seq", i as f64);
        }
        crate::obs::set_enabled(false);
        let all = take_events();
        assert!(all.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        // same-thread order is preserved for equal timestamps
        let vals: Vec<_> =
            all.iter().filter(|e| e.name == "seq").map(|e| e.value as i64).collect();
        assert_eq!(vals, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = Ring { tid: 0, buf: Vec::new(), next: 0, dropped: 0 };
        let ev = |i: u64| Event {
            cat: "t",
            name: "e",
            ph: Phase::Counter,
            ts_us: i,
            dur_us: 0,
            tid: 0,
            id: 0,
            value: 0.0,
        };
        for i in 0..(RING_CAP as u64 + 10) {
            r.push(ev(i));
        }
        assert_eq!(r.dropped, 10);
        let out = r.drain();
        assert_eq!(out.len(), RING_CAP);
        // oldest-first, starting right after the 10 dropped events
        assert_eq!(out[0].ts_us, 10);
        assert_eq!(out.last().unwrap().ts_us, RING_CAP as u64 + 9);
    }
}

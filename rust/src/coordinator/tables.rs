//! Drivers regenerating every table and figure of the paper's evaluation
//! (DESIGN.md §4 experiment index).  Each driver prints a Markdown table
//! and writes CSV + Markdown into `results/`.
//!
//! Scale knobs: `INVAREXPLORE_STEPS` (search steps per cell),
//! `INVAREXPLORE_FULL=1` (paper scale).  Defaults are sized for a CPU
//! sandbox; the *shape* of each table (who wins, by roughly what factor)
//! is the reproduction target, not absolute values.

use std::path::{Path, PathBuf};

use crate::baselines::Method;
use crate::quant::{self, QuantScheme};
use crate::transform::TransformKinds;
use crate::util::csv::CsvWriter;

use super::pipeline::{self, PipelineOpts, PipelineReport};
use super::session::Session;

pub fn results_dir() -> PathBuf {
    let d = std::env::var("INVAREXPLORE_RESULTS").unwrap_or_else(|_| "results".into());
    let p = PathBuf::from(d);
    let _ = std::fs::create_dir_all(&p);
    p
}

fn write_md(path: &Path, content: &str) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

/// Markdown table builder.
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> MdTable {
        MdTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!("|{}|\n", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

fn fmt_ppl(p: f64) -> String {
    if p > 1e4 {
        format!("{:.2e}", p)
    } else {
        format!("{:.2}", p)
    }
}

fn acc_cell(r: &PipelineReport, searched: bool) -> String {
    let snap = if searched { r.searched.as_ref().unwrap() } else { &r.base };
    snap.reasoning
        .as_ref()
        .map(|(_, avg)| format!("{avg:.2}"))
        .unwrap_or_else(|| "-".into())
}

// ---------------------------------------------------------------------------
// Table 1 — main results
// ---------------------------------------------------------------------------

pub struct Table1Opts {
    pub models: Vec<String>,
    pub methods: Vec<Method>,
    pub scheme: QuantScheme,
    pub steps: usize,
    pub reasoning_n: usize,
    pub seed: u64,
}

pub fn table1(session: &Session, t1: &Table1Opts) -> crate::Result<String> {
    let mut md = MdTable::new(&{
        let mut h = vec!["Method"];
        for m in &t1.models {
            h.push(m);
        }
        h.push("metric");
        h
    });
    let mut csv = CsvWriter::create(
        &results_dir().join("table1_main.csv"),
        &["method", "model", "wiki_ppl", "c4_ppl", "reasoning_avg"],
    )?;

    // FP16 row
    let mut fp_cells_w = Vec::new();
    let mut fp_cells_acc = Vec::new();
    for model in &t1.models {
        let mut opts = PipelineOpts::new(model, Method::Rtn, t1.scheme);
        opts.reasoning_n = t1.reasoning_n;
        let snap = pipeline::eval_fp(session, model, &opts)?;
        csv.row(&[
            "FP32".into(),
            model.clone(),
            format!("{:.4}", snap.ppl_wiki),
            format!("{:.4}", snap.ppl_c4),
            snap.reasoning.as_ref().map(|(_, a)| format!("{a:.2}")).unwrap_or_default(),
        ])?;
        fp_cells_w.push(format!("{} / {}", fmt_ppl(snap.ppl_wiki), fmt_ppl(snap.ppl_c4)));
        fp_cells_acc.push(snap.reasoning.as_ref().map(|(_, a)| format!("{a:.2}")).unwrap_or("-".into()));
    }
    let mut row = vec!["FP32".to_string()];
    row.extend(fp_cells_w);
    row.push("wiki/c4 ppl".into());
    md.row(row);
    let mut row = vec!["FP32".to_string()];
    row.extend(fp_cells_acc);
    row.push("reasoning".into());
    md.row(row);

    for &method in &t1.methods {
        // (method, +InvarExplore) row pair
        let mut base_w = Vec::new();
        let mut base_acc = Vec::new();
        let mut ie_w = Vec::new();
        let mut ie_acc = Vec::new();
        for model in &t1.models {
            let mut opts = PipelineOpts::new(model, method, t1.scheme);
            opts.steps = if method == Method::Rtn { 0 } else { t1.steps };
            opts.reasoning_n = t1.reasoning_n;
            opts.seed = t1.seed;
            let r = pipeline::run_pipeline(session, &opts)?;
            csv.row(&[
                method.name().into(),
                model.clone(),
                format!("{:.4}", r.base.ppl_wiki),
                format!("{:.4}", r.base.ppl_c4),
                acc_cell(&r, false),
            ])?;
            base_w.push(format!("{} / {}", fmt_ppl(r.base.ppl_wiki), fmt_ppl(r.base.ppl_c4)));
            base_acc.push(acc_cell(&r, false));
            if let Some(s) = &r.searched {
                csv.row(&[
                    format!("{}+InvarExplore", method.name()),
                    model.clone(),
                    format!("{:.4}", s.ppl_wiki),
                    format!("{:.4}", s.ppl_c4),
                    acc_cell(&r, true),
                ])?;
                ie_w.push(format!("{} / {}", fmt_ppl(s.ppl_wiki), fmt_ppl(s.ppl_c4)));
                ie_acc.push(acc_cell(&r, true));
            }
        }
        let mut row = vec![method.name().to_string()];
        row.extend(base_w);
        row.push("wiki/c4 ppl".into());
        md.row(row);
        let mut row = vec![method.name().to_string()];
        row.extend(base_acc);
        row.push("reasoning".into());
        md.row(row);
        if !ie_w.is_empty() {
            let mut row = vec![format!("{}+InvarExplore", method.name())];
            row.extend(ie_w);
            row.push("wiki/c4 ppl".into());
            md.row(row);
            let mut row = vec![format!("{}+InvarExplore", method.name())];
            row.extend(ie_acc);
            row.push("reasoning".into());
            md.row(row);
        }
    }
    csv.flush()?;
    let out = format!(
        "## Table 1 (analog): main results — {} quantization, {} search steps/cell\n\n{}",
        t1.scheme,
        t1.steps,
        md.render()
    );
    write_md(&results_dir().join("table1_main.md"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 2 — transform ablation
// ---------------------------------------------------------------------------

pub fn table2(
    session: &Session,
    model: &str,
    scheme: QuantScheme,
    steps: usize,
    reasoning_n: usize,
    seed: u64,
) -> crate::Result<String> {
    let mut md = MdTable::new(&["Variant", "wiki ppl", "c4 ppl", "reasoning avg"]);
    let mut csv = CsvWriter::create(
        &results_dir().join("table2_ablation.csv"),
        &["variant", "wiki_ppl", "c4_ppl", "reasoning_avg"],
    )?;

    let variants: [(&str, &str); 4] = [("Permutation", "p"), ("Scaling", "s"), ("Rotation", "r"), ("All", "psr")];

    // AWQ base row (steps = 0)
    let mut base_opts = PipelineOpts::new(model, Method::Awq, scheme);
    base_opts.reasoning_n = reasoning_n;
    base_opts.seed = seed;
    let base = pipeline::run_pipeline(session, &base_opts)?;
    let base_acc = acc_cell(&base, false);
    md.row(vec![
        "AWQ".into(),
        fmt_ppl(base.base.ppl_wiki),
        fmt_ppl(base.base.ppl_c4),
        base_acc.clone(),
    ]);
    csv.row(&[
        "AWQ".into(),
        format!("{:.4}", base.base.ppl_wiki),
        format!("{:.4}", base.base.ppl_c4),
        base_acc,
    ])?;

    for (label, kinds) in variants {
        let mut opts = PipelineOpts::new(model, Method::Awq, scheme);
        opts.steps = steps;
        opts.kinds = TransformKinds::parse(kinds)?;
        opts.reasoning_n = reasoning_n;
        opts.seed = seed;
        let r = pipeline::run_pipeline(session, &opts)?;
        let s = r.searched.as_ref().unwrap();
        let acc = acc_cell(&r, true);
        md.row(vec![
            format!("+InvarExplore-{label}"),
            fmt_ppl(s.ppl_wiki),
            fmt_ppl(s.ppl_c4),
            acc.clone(),
        ]);
        csv.row(&[
            format!("+InvarExplore-{label}"),
            format!("{:.4}", s.ppl_wiki),
            format!("{:.4}", s.ppl_c4),
            acc,
        ])?;
    }
    csv.flush()?;
    let out = format!(
        "## Table 2 (analog): transform ablation — AWQ + {model}, {scheme}, {steps} steps\n\n{}",
        md.render()
    );
    write_md(&results_dir().join("table2_ablation.md"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 3 — bits × group sizes (+ measured bits/param)
// ---------------------------------------------------------------------------

pub fn table3(
    session: &Session,
    model: &str,
    steps: usize,
    reasoning_n: usize,
    seed: u64,
) -> crate::Result<String> {
    let mut md = MdTable::new(&[
        "Bits", "Group", "Bits/Param", "Method", "wiki ppl", "c4 ppl", "reasoning avg",
    ]);
    let mut csv = CsvWriter::create(
        &results_dir().join("table3_bits_groups.csv"),
        &["bits", "group", "bits_per_param", "method", "wiki_ppl", "c4_ppl", "reasoning_avg"],
    )?;

    // paper: (1,64), (2,64), (2,128), (3,128).  Our models' difficulty
    // curve sits one bit lower (DESIGN.md §1), so the sweep covers the
    // catastrophic (1-bit), hard (1-bit coarse), and saturated (2/3-bit)
    // regimes with groups scaled to our hidden dims.
    let settings: [(usize, usize); 4] = [(1, 32), (1, 64), (2, 64), (3, 64)];
    for (bits, group) in settings {
        let scheme = QuantScheme::new(bits, group);
        // measured bits/param from the packed codec on this model
        let w = session.weights(model)?;
        let p = crate::baselines::rtn::prepare(scheme, &w);
        let (packed, bytes) = p.pack_model(&p.fp);
        let total_params: usize = packed.iter().map(|(_, t)| t.rows * t.cols).sum();
        let bpp = bytes as f64 * 8.0 / total_params as f64;
        let _ = quant::PackedTensor::pack(&quant::quantize(w.get("l0.up.w"), scheme)); // exercised

        let mut opts = PipelineOpts::new(model, Method::Awq, scheme);
        opts.steps = steps;
        opts.reasoning_n = reasoning_n;
        opts.seed = seed;
        let r = pipeline::run_pipeline(session, &opts)?;
        let s = r.searched.as_ref().unwrap();
        for (mname, snap, acc) in [
            ("AWQ", &r.base, acc_cell(&r, false)),
            ("+InvarExplore", s, acc_cell(&r, true)),
        ] {
            md.row(vec![
                bits.to_string(),
                group.to_string(),
                format!("{bpp:.3}"),
                mname.into(),
                fmt_ppl(snap.ppl_wiki),
                fmt_ppl(snap.ppl_c4),
                acc.clone(),
            ]);
            csv.row(&[
                bits.to_string(),
                group.to_string(),
                format!("{bpp:.4}"),
                mname.into(),
                format!("{:.4}", snap.ppl_wiki),
                format!("{:.4}", snap.ppl_c4),
                acc,
            ])?;
        }
    }
    csv.flush()?;
    let out = format!(
        "## Table 3 (analog): bits × group — AWQ ± InvarExplore on {model}, {steps} steps\n\n{}",
        md.render()
    );
    write_md(&results_dir().join("table3_bits_groups.md"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 4 — activation-matching layers
// ---------------------------------------------------------------------------

pub fn table4(
    session: &Session,
    model: &str,
    scheme: QuantScheme,
    steps: usize,
    reasoning_n: usize,
    seed: u64,
) -> crate::Result<String> {
    let n_layers = session.manifest.model(model)?.config.n_layers;
    let mut md = MdTable::new(&["Matched layers", "H0 memory", "wiki ppl", "c4 ppl", "reasoning avg"]);
    let mut csv = CsvWriter::create(
        &results_dir().join("table4_act_matching.csv"),
        &["match_layers", "h0_bytes", "wiki_ppl", "c4_ppl", "reasoning_avg"],
    )?;

    let mut counts = vec![0usize, 1];
    if n_layers >= 2 {
        counts.push(n_layers / 2);
    }
    counts.push(n_layers);
    counts.dedup();

    for k in counts {
        let mut opts = PipelineOpts::new(model, Method::Awq, scheme);
        opts.steps = steps;
        opts.match_layers = k;
        opts.reasoning_n = reasoning_n;
        opts.seed = seed;
        let r = pipeline::run_pipeline(session, &opts)?;
        let s = r.searched.as_ref().unwrap();
        let acc = acc_cell(&r, true);
        md.row(vec![
            format!("{k} / {n_layers}"),
            format!("{:.2} MiB", r.h0_bytes as f64 / (1 << 20) as f64),
            fmt_ppl(s.ppl_wiki),
            fmt_ppl(s.ppl_c4),
            acc.clone(),
        ]);
        csv.row(&[
            k.to_string(),
            r.h0_bytes.to_string(),
            format!("{:.4}", s.ppl_wiki),
            format!("{:.4}", s.ppl_c4),
            acc,
        ])?;
    }
    csv.flush()?;
    let out = format!(
        "## Table 4 (analog): activation-matching layers — AWQ+InvarExplore on {model}, {steps} steps\n\n{}",
        md.render()
    );
    write_md(&results_dir().join("table4_act_matching.md"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 5 — per-task reasoning detail
// ---------------------------------------------------------------------------

pub fn table5(
    session: &Session,
    models: &[String],
    scheme: QuantScheme,
    steps: usize,
    reasoning_n: usize,
    seed: u64,
) -> crate::Result<String> {
    let task_names: Vec<String> = session
        .manifest
        .data
        .task_names()
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut header: Vec<&str> = vec!["Model", "Method"];
    let names_ref: Vec<&str> = task_names.iter().map(|s| s.as_str()).collect();
    header.extend(names_ref.iter());
    header.push("Avg");
    let mut md = MdTable::new(&header);
    let mut csv_header = vec!["model".to_string(), "method".to_string()];
    csv_header.extend(task_names.iter().cloned());
    csv_header.push("avg".into());
    let csv_header_refs: Vec<&str> = csv_header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::create(&results_dir().join("table5_reasoning.csv"), &csv_header_refs)?;

    let mut emit = |model: &str, method: &str, res: &[crate::eval::TaskResult], avg: f64| {
        let mut cells = vec![model.to_string(), method.to_string()];
        let mut csv_cells = cells.clone();
        for name in &task_names {
            let acc = res
                .iter()
                .find(|r| &r.task == name)
                .map(|r| format!("{:.2}", r.accuracy))
                .unwrap_or_default();
            cells.push(acc.clone());
            csv_cells.push(acc);
        }
        cells.push(format!("{avg:.2}"));
        csv_cells.push(format!("{avg:.2}"));
        md.row(cells);
        csv.row(&csv_cells)
    };

    for model in models {
        let mut opts = PipelineOpts::new(model, Method::Awq, scheme);
        opts.reasoning_n = reasoning_n;
        opts.steps = steps;
        opts.seed = seed;
        let fp = pipeline::eval_fp(session, model, &opts)?;
        if let Some((res, avg)) = &fp.reasoning {
            emit(model, "FP32", res, *avg)?;
        }
        let r = pipeline::run_pipeline(session, &opts)?;
        if let Some((res, avg)) = &r.base.reasoning {
            emit(model, "AWQ", res, *avg)?;
        }
        if let Some(s) = &r.searched {
            if let Some((res, avg)) = &s.reasoning {
                emit(model, "+InvarExplore", res, *avg)?;
            }
        }
    }
    csv.flush()?;
    let out = format!(
        "## Table 5 (analog): per-task reasoning detail — {scheme}, {steps} steps\n\n{}",
        md.render()
    );
    write_md(&results_dir().join("table5_reasoning.md"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 1 — optimization curves vs calibration size
// ---------------------------------------------------------------------------

pub struct Figure1Opts {
    pub model: String,
    pub scheme: QuantScheme,
    pub calib_seqs: Vec<usize>,
    pub total_steps: usize,
    pub segments: usize,
    pub seed: u64,
}

pub fn figure1(session: &Session, f1: &Figure1Opts) -> crate::Result<String> {
    let mut csv = CsvWriter::create(
        &results_dir().join("figure1_curves.csv"),
        &["calib_seqs", "step", "calib_loss", "test_ppl", "accept_rate"],
    )?;
    let mut loss_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut ppl_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut acc_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();

    for &n_seqs in &f1.calib_seqs {
        let mut opts = PipelineOpts::new(&f1.model, Method::Awq, f1.scheme);
        opts.calib_seqs = n_seqs;
        opts.seed = f1.seed;
        let mut run = super::pipeline::SearchRun::build(session, &opts)?;
        run.init()?;
        let seg = (f1.total_steps / f1.segments).max(1);
        let mut losses = Vec::new();
        let mut ppls = Vec::new();
        let mut accs = Vec::new();
        // step-0 point
        let ppl0 = run.test_ppl(session, "wiki", 32)?;
        losses.push((0.0, run.state.best.total(run.state.alpha)));
        ppls.push((0.0, ppl0));
        csv.row(&[
            n_seqs.to_string(),
            "0".into(),
            format!("{:.6}", run.state.best.total(run.state.alpha)),
            format!("{ppl0:.4}"),
            "".into(),
        ])?;
        for _ in 0..f1.segments {
            run.steps(seg)?;
            let step = run.state.step as f64;
            let loss = run.state.best.total(run.state.alpha);
            let ppl = run.test_ppl(session, "wiki", 32)?;
            let acc = run.state.accept_rate();
            losses.push((step, loss));
            ppls.push((step, ppl));
            accs.push((step, acc));
            csv.row(&[
                n_seqs.to_string(),
                run.state.step.to_string(),
                format!("{loss:.6}"),
                format!("{ppl:.4}"),
                format!("{acc:.4}"),
            ])?;
        }
        run.state
            .telemetry_csv(&results_dir().join(format!("figure1_telemetry_{n_seqs}seqs.csv")))?;
        loss_series.push((format!("{n_seqs} seqs"), losses));
        ppl_series.push((format!("{n_seqs} seqs"), ppls));
        acc_series.push((format!("{n_seqs} seqs"), accs));
    }
    csv.flush()?;

    let mut out = format!(
        "## Figure 1 (analog): optimization curves — AWQ+InvarExplore on {}, {} steps\n\n",
        f1.model, f1.total_steps
    );
    let as_refs = |s: &[(String, Vec<(f64, f64)>)]| -> Vec<(String, Vec<(f64, f64)>)> { s.to_vec() };
    for (title, series) in [
        ("(a) calibration loss", as_refs(&loss_series)),
        ("(b) WikiText test perplexity", as_refs(&ppl_series)),
        ("(c) acceptance ratio", as_refs(&acc_series)),
    ] {
        let refs: Vec<(&str, &[(f64, f64)])> =
            series.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
        out.push_str("```\n");
        out.push_str(&crate::util::plot::render(title, &refs, 64, 14));
        out.push_str("```\n\n");
    }
    write_md(&results_dir().join("figure1_curves.md"), &out)?;
    Ok(out)
}

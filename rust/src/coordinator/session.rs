//! Loaded-artifacts context shared by CLI commands, examples and benches.

use std::path::Path;

use crate::io::manifest::Manifest;
use crate::io::tokens::{self, TokenCorpus};
use crate::model::Weights;

pub struct Session {
    pub manifest: Manifest,
}

impl Session {
    /// Load from `artifacts/` (or `INVAREXPLORE_ARTIFACTS`).
    pub fn load_default() -> crate::Result<Session> {
        crate::util::logging::init();
        Ok(Session { manifest: Manifest::load_default()? })
    }

    pub fn load(dir: &Path) -> crate::Result<Session> {
        crate::util::logging::init();
        Ok(Session { manifest: Manifest::load(dir)? })
    }

    /// Trained FP weights of a model.
    pub fn weights(&self, model: &str) -> crate::Result<Weights> {
        let info = self.manifest.model(model)?;
        Weights::load(&info.weights_path, info.config.clone())
    }

    /// A corpus by name (`train` / `pile` / `wiki` / `c4`).
    pub fn corpus(&self, name: &str) -> crate::Result<TokenCorpus> {
        tokens::read(self.manifest.data.corpus(name)?)
    }

    /// Evenly-spaced activation-matching layer subset of size `k` (the
    /// paper matches 10 of 40 layers; Table 4 sweeps the count).
    pub fn match_layer_subset(n_layers: usize, k: usize) -> Vec<usize> {
        let k = k.min(n_layers);
        (1..=k)
            .map(|i| (i * n_layers).div_ceil(k) - 1)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn match_layer_subsets() {
        assert_eq!(Session::match_layer_subset(4, 0), Vec::<usize>::new());
        assert_eq!(Session::match_layer_subset(4, 1), vec![3]);
        assert_eq!(Session::match_layer_subset(4, 2), vec![1, 3]);
        assert_eq!(Session::match_layer_subset(4, 4), vec![0, 1, 2, 3]);
        // k > n clamps
        assert_eq!(Session::match_layer_subset(2, 10), vec![0, 1]);
    }
}

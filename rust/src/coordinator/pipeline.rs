//! The quantize → search → evaluate pipeline — one cell of Table 1.

use crate::baselines::{self, Method};
use crate::calib::CalibSet;
use crate::eval::{self, TaskResult};
use crate::quant::{BitAllocation, QuantScheme};
use crate::runtime::{Engine, Evaluator};
use crate::search::{
    self, AllocState, DraftRequest, Objective, SearchConfig, SearchState, XlaObjective,
};
use crate::transform::TransformKinds;

use super::session::Session;

/// Options for one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    pub model: String,
    pub method: Method,
    pub scheme: QuantScheme,
    /// Search steps; 0 = baseline only.
    pub steps: usize,
    /// Proposals drafted per search round (`--batch`); 1 = exact
    /// sequential semantics.
    pub batch: usize,
    pub kinds: TransformKinds,
    /// Number of activation-matching layers (Table 4 knob).
    pub match_layers: usize,
    /// Calibration sequences (paper: 32 × 512 tokens; Figure 1 knob).
    pub calib_seqs: usize,
    pub seed: u64,
    pub alpha: Option<f64>,
    /// Max eval sequences per perplexity corpus.
    pub eval_seqs: usize,
    /// Reasoning examples per task (0 = skip reasoning).
    pub reasoning_n: usize,
    pub shots: usize,
    /// Mixed-precision allocation (`--alloc`); `None` = uniform `scheme`.
    pub alloc: Option<BitAllocation>,
    /// Probability a search proposal is a bit-swap allocation move
    /// (`--alloc-prob`); > 0 enables allocation search.
    pub p_alloc: f64,
}

impl PipelineOpts {
    pub fn new(model: &str, method: Method, scheme: QuantScheme) -> PipelineOpts {
        PipelineOpts {
            model: model.to_string(),
            method,
            scheme,
            steps: 0,
            batch: 1,
            kinds: TransformKinds::all(),
            match_layers: 2,
            calib_seqs: 32,
            seed: 0,
            alpha: None,
            eval_seqs: 64,
            reasoning_n: 0,
            shots: 5,
            alloc: None,
            p_alloc: 0.0,
        }
    }

    /// The effective allocation: `--alloc` when given, else uniform at
    /// `scheme`.
    pub fn allocation(&self) -> BitAllocation {
        self.alloc
            .clone()
            .unwrap_or_else(|| BitAllocation::uniform(self.scheme))
    }
}

/// Evaluation snapshot (before or after search).
#[derive(Debug, Clone, Default)]
pub struct EvalSnapshot {
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub reasoning: Option<(Vec<TaskResult>, f64)>,
}

/// Report of one pipeline run.
pub struct PipelineReport {
    pub opts: PipelineOpts,
    pub ce_fp_calib: f64,
    pub base: EvalSnapshot,
    pub searched: Option<EvalSnapshot>,
    pub state: Option<SearchState>,
    /// H₀ memory (Table 4 column), bytes.
    pub h0_bytes: usize,
}

/// A live search run: objective + state, resumable in segments (Figure 1
/// evaluates test PPL between segments).
pub struct SearchRun {
    pub obj: XlaObjective,
    pub state: SearchState,
    pub cfg: SearchConfig,
    pub h0_bytes: usize,
    pub ce_fp_calib: f64,
}

impl SearchRun {
    /// Build the full stack for `opts`: weights → calib → baseline prepare →
    /// engine+evaluator (FP weights uploaded, H₀ captured) → objective.
    pub fn build(session: &Session, opts: &PipelineOpts) -> crate::Result<SearchRun> {
        let manifest = &session.manifest;
        let w = session.weights(&opts.model)?;
        let pile = session.corpus("pile")?;
        let calib = CalibSet::from_corpus(&pile, opts.calib_seqs, manifest.seq);
        crate::info!(
            "pipeline: model={} method={} scheme={} calib={}x{}",
            opts.model,
            opts.method.name(),
            opts.scheme,
            calib.n_seqs(),
            calib.seqlen()
        );

        let t0 = std::time::Instant::now();
        let alloc = opts.allocation();
        let prepared = baselines::prepare_mixed(opts.method, &alloc, &w, &calib, None)?;
        crate::info!(
            "prepared {} in {:?} (allocation {}, {:.3} bits/param)",
            opts.method.name(),
            t0.elapsed(),
            alloc.label(),
            alloc.bits_per_param(&w.config)
        );

        let mut engine = Engine::load(manifest, &opts.model)?;
        engine.upload_weights(&prepared.fp)?;
        let cfg = &prepared.fp.config;
        let match_layers = Session::match_layer_subset(cfg.n_layers, opts.match_layers);
        let mut evaluator = Evaluator::new(engine, &calib, match_layers)?;
        let ce_fp_calib = evaluator.capture_h0()?;
        crate::info!("FP calib CE {ce_fp_calib:.4}");
        let h0_bytes = evaluator.h0_bytes();

        let (n_layers, d_ffn) = (cfg.n_layers, cfg.d_ffn);
        let model_cfg = cfg.clone();
        let obj = XlaObjective::new(prepared, evaluator);
        let mut state = SearchState::new(n_layers, d_ffn, opts.seed);
        if opts.p_alloc > 0.0 {
            state = state.with_alloc(AllocState::new(&model_cfg, &alloc));
        }
        let cfg = SearchConfig {
            kinds: opts.kinds,
            alpha: opts.alpha,
            batch: opts.batch.max(1),
            p_alloc: opts.p_alloc.clamp(0.0, 1.0),
            ..SearchConfig::default()
        };
        Ok(SearchRun { obj, state, cfg, h0_bytes, ce_fp_calib })
    }

    /// Quantize + initial full eval (no-op if already initialized).
    pub fn init(&mut self) -> crate::Result<()> {
        search::hillclimb::ensure_init(&mut self.obj, &mut self.state, &self.cfg)
    }

    /// Resume from a saved checkpoint: re-initialize the quantized model,
    /// re-materialize every saved layer transform through the objective
    /// (so device weights, prefix cache and loss all reflect it), and carry
    /// over the step/accept counters and α.
    pub fn restore(&mut self, saved: crate::search::SearchState) -> crate::Result<()> {
        anyhow::ensure!(
            saved.transforms.len() == self.obj.n_layers(),
            "checkpoint layer count mismatch"
        );
        search::hillclimb::ensure_init(&mut self.obj, &mut self.state, &self.cfg)?;
        if saved.alpha > 0.0 {
            self.state.alpha = saved.alpha;
        }
        for (l, t) in saved.transforms.iter().enumerate() {
            if !t.is_identity() {
                let mut drafts = self.obj.draft(&[DraftRequest::transform(l, t.clone())])?;
                self.obj.eval_drafts(&drafts)?;
                let loss = self.obj.commit(drafts.swap_remove(0))?;
                self.state.best = loss;
            }
        }
        // re-materialize the checkpointed mixed-precision allocation (after
        // the transforms, so FFN tensors re-quantize under them)
        if let Some(alloc) = &saved.alloc {
            let loss = self.obj.restore_allocation(&alloc.entries, &saved.transforms)?;
            self.state.best = loss;
        }
        self.state.transforms = saved.transforms;
        self.state.step = saved.step;
        self.state.accepts = saved.accepts;
        self.state.alloc_accepts = saved.alloc_accepts;
        if saved.alloc.is_some() {
            // adopt the checkpoint's allocation + budget; a checkpoint
            // without one keeps the fresh AllocState `build` may have
            // attached for this run's `--alloc-prob`
            self.state.alloc = saved.alloc;
        }
        crate::info!(
            "resumed at step {} (loss {:.4}, {} accepts, {} bit swaps)",
            self.state.step,
            self.state.best.total(self.state.alpha),
            self.state.accepts,
            self.state.alloc_accepts
        );
        Ok(())
    }

    /// Run `n` more search proposals, in `cfg.batch`-wide rounds.
    pub fn steps(&mut self, n: usize) -> crate::Result<()> {
        search::run(&mut self.obj, &mut self.state, &self.cfg, n)
    }

    /// Evaluate perplexity + reasoning with the current quantized weights.
    pub fn snapshot(&self, session: &Session, opts: &PipelineOpts) -> crate::Result<EvalSnapshot> {
        let engine = &self.obj.eval.engine;
        let wiki = session.corpus("wiki")?;
        let c4 = session.corpus("c4")?;
        let ppl_wiki = eval::perplexity(engine, &wiki, opts.eval_seqs)?;
        let ppl_c4 = eval::perplexity(engine, &c4, opts.eval_seqs)?;
        let reasoning = if opts.reasoning_n > 0 {
            Some(eval::eval_all_tasks(
                engine,
                &session.manifest.data,
                opts.shots,
                opts.reasoning_n,
                opts.seed,
            )?)
        } else {
            None
        };
        Ok(EvalSnapshot { ppl_wiki, ppl_c4, reasoning })
    }

    /// Test perplexity on one corpus (Figure 1b segments).
    pub fn test_ppl(&self, session: &Session, corpus: &str, max_seqs: usize) -> crate::Result<f64> {
        let c = session.corpus(corpus)?;
        eval::perplexity(&self.obj.eval.engine, &c, max_seqs)
    }
}

/// Run the full pipeline for one (model, method, scheme) cell.
pub fn run_pipeline(session: &Session, opts: &PipelineOpts) -> crate::Result<PipelineReport> {
    let mut run = SearchRun::build(session, opts)?;
    run.init()?;
    let base = run.snapshot(session, opts)?;
    crate::info!(
        "{} baseline: wiki ppl {:.2}, c4 ppl {:.2}",
        opts.method.name(),
        base.ppl_wiki,
        base.ppl_c4
    );

    let (searched, state) = if opts.steps > 0 {
        run.steps(opts.steps)?;
        let snap = run.snapshot(session, opts)?;
        crate::info!(
            "+InvarExplore({}) after {} steps: wiki ppl {:.2}, c4 ppl {:.2} (accept {:.2})",
            run.cfg.kinds.label(),
            run.state.step,
            snap.ppl_wiki,
            snap.ppl_c4,
            run.state.accept_rate()
        );
        (Some(snap), Some(run.state))
    } else {
        (None, None)
    };

    Ok(PipelineReport {
        opts: opts.clone(),
        ce_fp_calib: run.ce_fp_calib,
        base,
        searched,
        state,
        h0_bytes: run.h0_bytes,
    })
}

/// Evaluate the *unquantized* FP model (the Table-1 "FP16" row).
pub fn eval_fp(session: &Session, model: &str, opts: &PipelineOpts) -> crate::Result<EvalSnapshot> {
    let w = session.weights(model)?;
    let mut engine = Engine::load(&session.manifest, model)?;
    engine.upload_weights(&w)?;
    let wiki = session.corpus("wiki")?;
    let c4 = session.corpus("c4")?;
    let reasoning = if opts.reasoning_n > 0 {
        Some(eval::eval_all_tasks(
            &engine,
            &session.manifest.data,
            opts.shots,
            opts.reasoning_n,
            opts.seed,
        )?)
    } else {
        None
    };
    Ok(EvalSnapshot {
        ppl_wiki: eval::perplexity(&engine, &wiki, opts.eval_seqs)?,
        ppl_c4: eval::perplexity(&engine, &c4, opts.eval_seqs)?,
        reasoning,
    })
}

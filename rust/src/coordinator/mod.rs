//! The coordinator: wires artifacts + baselines + search + runtime + eval
//! into the jobs the CLI, examples and benches run.
//!
//! * [`session`] — loaded artifacts context (manifest, corpora, weights);
//! * [`pipeline`] — the quantize→search→evaluate pipeline (one Table-1 cell);
//! * [`tables`] — drivers regenerating every table and figure of the paper.

pub mod pipeline;
pub mod session;
pub mod tables;

pub use pipeline::{PipelineOpts, PipelineReport, SearchRun};
pub use session::Session;

//! Calibration infrastructure: batch assembly from the Pile-like corpus,
//! per-linear activation capture (via the native forward) and Hessian
//! construction for GPTQ.

use crate::io::tokens::TokenCorpus;
use crate::model::native::{self, Capture, LayerInputs};
use crate::model::Weights;
use crate::tensor::linalg;
use crate::tensor::Tensor;

/// A calibration set: `n_seqs` sequences of `seqlen` tokens + shifted
/// targets (paper: 32 × 512-token Pile sequences; scaled here).
#[derive(Debug, Clone)]
pub struct CalibSet {
    pub tokens: Vec<Vec<i32>>,
    pub targets: Vec<Vec<i32>>,
    pub masks: Vec<Vec<f32>>,
}

impl CalibSet {
    pub fn from_corpus(corpus: &TokenCorpus, n_seqs: usize, seqlen: usize) -> CalibSet {
        let seqs = corpus.sequences(n_seqs, seqlen);
        assert!(!seqs.is_empty(), "calibration corpus too small");
        let masks = vec![vec![1.0f32; seqlen]; seqs.len()];
        let (tokens, targets) = seqs.into_iter().unzip();
        CalibSet { tokens, targets, masks }
    }

    pub fn n_seqs(&self) -> usize {
        self.tokens.len()
    }

    pub fn seqlen(&self) -> usize {
        self.tokens.first().map_or(0, |s| s.len())
    }

    pub fn n_tokens(&self) -> usize {
        self.n_seqs() * self.seqlen()
    }

    /// Split into runtime-batch-sized chunks (padding the last chunk by
    /// repeating its final sequence so every chunk has exactly `batch` rows;
    /// padded rows get zero masks).
    pub fn chunks(&self, batch: usize) -> Vec<CalibSet> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.n_seqs() {
            let end = (i + batch).min(self.n_seqs());
            let mut tokens: Vec<Vec<i32>> = self.tokens[i..end].to_vec();
            let mut targets: Vec<Vec<i32>> = self.targets[i..end].to_vec();
            let mut masks: Vec<Vec<f32>> = self.masks[i..end].to_vec();
            while tokens.len() < batch {
                tokens.push(tokens.last().unwrap().clone());
                targets.push(targets.last().unwrap().clone());
                masks.push(vec![0.0; self.seqlen()]);
            }
            out.push(CalibSet { tokens, targets, masks });
            i = end;
        }
        out
    }
}

/// Captured calibration statistics for every linear layer of the model.
#[derive(Debug)]
pub struct CalibStats {
    /// Per layer: inputs to q/k/v, o, up, down projections `[N, in]`.
    pub inputs: Vec<LayerInputs>,
    /// FP hidden stack per layer `[N, d]` (H₀ of Eqn. 23).
    pub hidden: Vec<Tensor>,
    /// FP cross-entropy on the calibration set.
    pub ce_fp: f64,
}

/// Run the FP model natively over the calibration set, capturing inputs.
pub fn capture(w: &Weights, calib: &CalibSet) -> CalibStats {
    let out = native::forward(
        w,
        &calib.tokens,
        &calib.targets,
        &calib.masks,
        Capture { hidden: true, linear_inputs: true, last_logits: false },
    );
    CalibStats {
        inputs: out.linear_inputs,
        hidden: out.hidden,
        ce_fp: out.ce,
    }
}

/// Per-channel mean |activation| — AWQ's importance signal (`s_x` in the
/// paper's Eqn.: scale ∝ act^α).
pub fn channel_mean_abs(x: &Tensor) -> Vec<f32> {
    let mut out = vec![0.0f32; x.cols];
    for r in 0..x.rows {
        for (c, v) in x.row(r).iter().enumerate() {
            out[c] += v.abs();
        }
    }
    let n = x.rows.max(1) as f32;
    for v in &mut out {
        *v /= n;
    }
    out
}

/// Damped GPTQ Hessian: `H = 2·XᵀX + λ·mean(diag)·I`.
pub fn hessian(x: &Tensor, damp: f64) -> Vec<f64> {
    let n = x.cols;
    let mut h = vec![0.0f64; n * n];
    linalg::sym_accumulate_xtx(&mut h, &x.data, x.rows, n, 2.0);
    linalg::symmetrize_upper(&mut h, n);
    let mean_diag: f64 = (0..n).map(|i| h[i * n + i]).sum::<f64>() / n as f64;
    let lambda = damp * mean_diag.max(1e-12);
    for i in 0..n {
        h[i * n + i] += lambda;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OptConfig;
    use crate::util::rng::Pcg64;

    fn corpus(n: usize, vocab: usize) -> TokenCorpus {
        let mut rng = Pcg64::new(0);
        TokenCorpus {
            vocab,
            tokens: (0..n).map(|_| rng.below(vocab) as u32).collect(),
        }
    }

    #[test]
    fn calibset_assembly() {
        let c = corpus(1000, 64);
        let cs = CalibSet::from_corpus(&c, 4, 32);
        assert_eq!(cs.n_seqs(), 4);
        assert_eq!(cs.seqlen(), 32);
        assert_eq!(cs.n_tokens(), 128);
        // shifted targets
        assert_eq!(cs.targets[0][0], cs.tokens[0][1]);
    }

    #[test]
    fn chunks_pad_with_zero_mask() {
        let c = corpus(2000, 64);
        let cs = CalibSet::from_corpus(&c, 5, 16);
        let chunks = cs.chunks(4);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[1].tokens.len(), 4);
        // padded rows have zero masks
        assert!(chunks[1].masks[1].iter().all(|&m| m == 0.0));
        assert!(chunks[1].masks[0].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn capture_shapes() {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 1);
        let c = corpus(600, cfg.vocab);
        let cs = CalibSet::from_corpus(&c, 3, 16);
        let stats = capture(&w, &cs);
        assert_eq!(stats.inputs.len(), cfg.n_layers);
        assert_eq!(stats.hidden.len(), cfg.n_layers);
        assert_eq!(stats.inputs[0].qkv_in.shape(), (48, cfg.d_model));
        assert_eq!(stats.inputs[0].down_in.shape(), (48, cfg.d_ffn));
        assert!(stats.ce_fp > 0.0);
    }

    #[test]
    fn channel_mean_abs_basic() {
        let x = Tensor::from_vec(2, 3, vec![1.0, -2.0, 0.0, 3.0, -4.0, 0.0]);
        assert_eq!(channel_mean_abs(&x), vec![2.0, 3.0, 0.0]);
    }

    #[test]
    fn hessian_is_spd() {
        let mut rng = Pcg64::new(2);
        let x = Tensor::from_vec(32, 8, (0..256).map(|_| rng.normal() as f32).collect());
        let h = hessian(&x, 0.01);
        // SPD => cholesky succeeds
        assert!(crate::tensor::linalg::cholesky(&h, 8).is_ok());
        // symmetric
        for i in 0..8 {
            for j in 0..8 {
                assert!((h[i * 8 + j] - h[j * 8 + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn hessian_damping_handles_rank_deficiency() {
        // fewer samples than dims -> XᵀX singular; damping must fix it
        let mut rng = Pcg64::new(3);
        let x = Tensor::from_vec(2, 8, (0..16).map(|_| rng.normal() as f32).collect());
        let h = hessian(&x, 0.01);
        assert!(crate::tensor::linalg::cholesky(&h, 8).is_ok());
    }
}

//! Transformer primitive ops on raw f32 slices + the blocked matmul kernels
//! used by the native forward pass (the calibration/oracle path; the search
//! hot path runs through XLA instead).

use super::Tensor;
use crate::util::pool;

/// Problem-size floor (`m·k·n` MACs) below which the parallel kernels stay
/// serial.  Shared by [`matmul_nt_par`] and the packed fused-GEMM tiles
/// (`quant::packed::{linear_into, linear_batch}`) so the serial/parallel
/// decision can't drift between the dense and packed paths — small
/// per-token decode GEMVs already run under the server's per-sequence
/// parallelism, and spawning scoped threads for them costs more than the
/// work (the original nested-parallelism footgun this constant de-dupes).
pub const fn par_threshold() -> usize {
    1 << 18
}

/// Columns `[j0, j1)` of one output row — the inner kernel shared by
/// [`matmul_nt`] and [`matmul_nt_blocked`].  4-wide j-blocking keeps 4
/// accumulators live and lets the compiler auto-vectorize the k loop;
/// leftover columns fall back to per-column [`dot`].  `j0` must be a
/// multiple of 4 so a tiled caller's blocked-vs-dot column split matches a
/// whole-row call exactly (bit-identity across tilings).
#[inline]
fn row_span(ar: &[f32], b: &[f32], k: usize, j0: usize, j1: usize, or: &mut [f32]) {
    debug_assert_eq!(j0 % 4, 0);
    let mut j = j0;
    while j + 4 <= j1 {
        let b0 = &b[j * k..(j + 1) * k];
        let b1 = &b[(j + 1) * k..(j + 2) * k];
        let b2 = &b[(j + 2) * k..(j + 3) * k];
        let b3 = &b[(j + 3) * k..(j + 4) * k];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for kk in 0..k {
            let av = ar[kk];
            s0 += av * b0[kk];
            s1 += av * b1[kk];
            s2 += av * b2[kk];
            s3 += av * b3[kk];
        }
        or[j] = s0;
        or[j + 1] = s1;
        or[j + 2] = s2;
        or[j + 3] = s3;
        j += 4;
    }
    while j < j1 {
        let br = &b[j * k..(j + 1) * k];
        or[j] = dot(ar, br);
        j += 1;
    }
}

/// `out[m,n] = a[m,k] @ b[n,k]^T` — the "linear layer" product where `b` is
/// a row-major `[out_features, in_features]` weight matrix.  Both operands
/// are traversed row-wise, so this is cache-friendly without packing.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        row_span(&a[i * k..(i + 1) * k], b, k, 0, n, &mut out[i * n..(i + 1) * n]);
    }
}

/// [`matmul_nt`] with the loop nest inverted into [64-row `b` tiles × all
/// `m` rows of `a`]: each weight tile is streamed from memory ONCE for the
/// whole batch instead of once per row, which is what makes tall-skinny
/// multi-row products — the `[k, vocab]` tied-head GEMM of chunked verify,
/// batched prefill logits — cache-blocked rather than `m`× re-streamed.
/// Bit-identical to [`matmul_nt`]: every output element runs the exact
/// same kk-sequential accumulation, only the order independent elements
/// are produced in changes (and the tile width is a multiple of 4, so the
/// blocked-vs-`dot` column split matches too).  Serial by design: callers
/// sit under the server's per-sequence parallelism.
pub fn matmul_nt_blocked(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    const B_TILE: usize = 64;
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + B_TILE).min(n);
        for i in 0..m {
            row_span(&a[i * k..(i + 1) * k], b, k, j0, j1, &mut out[i * n..(i + 1) * n]);
        }
        j0 = j1;
    }
}

/// Thread-parallel [`matmul_nt`] splitting over rows of `a`.
pub fn matmul_nt_par(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let threads = pool::num_threads();
    if m * n * k < par_threshold() || threads == 1 {
        return matmul_nt(a, b, m, k, n, out);
    }
    let rows_per_chunk = m.div_ceil(threads).max(1);
    pool::parallel_chunks_mut(out, rows_per_chunk * n, threads, |ci, chunk| {
        let row0 = ci * rows_per_chunk;
        let rows = chunk.len() / n;
        matmul_nt(&a[row0 * k..(row0 + rows) * k], b, rows, k, n, chunk);
    });
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        for l in 0..8 {
            acc[l] += a[c * 8 + l] * b[c * 8 + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Tensor-level linear layer: `x [t, in] @ w [out, in]^T + bias`.
pub fn linear(x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
    assert_eq!(x.cols, w.cols, "linear: in-dim mismatch");
    assert_eq!(bias.len(), w.rows, "linear: bias mismatch");
    let mut out = Tensor::zeros(x.rows, w.rows);
    matmul_nt_par(&x.data, &w.data, x.rows, x.cols, w.rows, &mut out.data);
    add_bias(&mut out, bias);
    out
}

/// `out[r, :] += bias` for every row — the bias half of [`linear`], shared
/// with the fused packed-weight kernels (`quant::packed`) so both the dense
/// and the packed-direct paths add bias with identical f32 semantics.
pub fn add_bias(out: &mut Tensor, bias: &[f32]) {
    assert_eq!(bias.len(), out.cols, "add_bias: bias mismatch");
    for r in 0..out.rows {
        for (o, b) in out.row_mut(r).iter_mut().zip(bias) {
            *o += *b;
        }
    }
}

/// LayerNorm over the last dim, matching the L2 model (eps 1e-5).
pub const LN_EPS: f32 = 1e-5;

pub fn layer_norm(x: &Tensor, w: &[f32], b: &[f32]) -> Tensor {
    assert_eq!(x.cols, w.len());
    let mut out = Tensor::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mean = row.iter().sum::<f32>() / x.cols as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let dst = out.row_mut(r);
        for c in 0..x.cols {
            dst[c] = (row[c] - mean) * inv * w[c] + b[c];
        }
    }
    out
}

/// In-place numerically-stable softmax over each row.
pub fn softmax_rows(x: &mut Tensor) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// `a += b` elementwise.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
}

/// Log-softmax of one row returning only the value at `index` — the
/// token-level log-prob used by the eval harness.
pub fn log_prob_at(logits: &[f32], index: usize) -> f32 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
    let lse = mx + logits.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
    logits[index] - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn naive_matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[j * k + kk];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive_property() {
        propcheck::check("matmul_nt == naive", 32, |rng| {
            let m = rng.below(9) + 1;
            let k = rng.below(33) + 1;
            let n = rng.below(17) + 1;
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0; m * n];
            matmul_nt(&a, &b, m, k, n, &mut out);
            propcheck::ensure_all_close(&out, &naive_matmul_nt(&a, &b, m, k, n), 1e-3, "matmul")
        });
    }

    #[test]
    fn matmul_par_matches_serial() {
        // bit-exact, not approximate: the parallel split only changes which
        // thread computes a row, never the row's accumulation — the pin the
        // shared par_threshold() satellite rides on.  (m, k, n) is sized
        // past the threshold so the parallel path actually engages.
        let mut rng = crate::util::rng::Pcg64::new(0);
        let (m, k, n) = (64, 96, 80);
        assert!(m * n * k >= par_threshold());
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let mut s = vec![0.0; m * n];
        let mut p = vec![0.0; m * n];
        matmul_nt(&a, &b, m, k, n, &mut s);
        matmul_nt_par(&a, &b, m, k, n, &mut p);
        for (i, (x, y)) in s.iter().zip(&p).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "serial != parallel at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matmul_blocked_bit_identical_to_plain() {
        // the cache-blocked loop nest must not change a single bit — over
        // n < one tile, n spanning tiles, and non-multiple-of-4 dot tails.
        propcheck::check("matmul_nt_blocked == matmul_nt", 24, |rng| {
            let m = rng.below(6) + 1;
            let k = rng.below(48) + 1;
            let n = rng.below(200) + 1;
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let mut plain = vec![0.0; m * n];
            let mut blocked = vec![0.0; m * n];
            matmul_nt(&a, &b, m, k, n, &mut plain);
            matmul_nt_blocked(&a, &b, m, k, n, &mut blocked);
            for (i, (x, y)) in plain.iter().zip(&blocked).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("m={m} k={k} n={n} idx={i}: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn linear_applies_bias() {
        let x = Tensor::from_vec(1, 2, vec![1.0, 2.0]);
        let w = Tensor::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = linear(&x, &w, &[10.0, 20.0]);
        assert_eq!(out.data, vec![11.0, 22.0]);
    }

    #[test]
    fn add_bias_every_row() {
        let mut x = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x.data, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Tensor::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let out = layer_norm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = out.data.iter().sum::<f32>() / 4.0;
        let var: f32 = out.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = Tensor::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0]);
        softmax_rows(&mut x);
        for r in 0..2 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.row(r).iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn log_prob_at_matches_manual() {
        let logits = [0.5f32, 1.5, -0.5];
        let lp = log_prob_at(&logits, 1);
        let z: f32 = logits.iter().map(|v| v.exp()).sum();
        assert!((lp - (1.5 - z.ln())).abs() < 1e-5);
    }

    #[test]
    fn relu_clamps() {
        let mut x = Tensor::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.0, 2.0]);
    }
}

//! Row-major 2-D f32 tensor.  Higher-rank arrays in this repo are expressed
//! as `[rows = product(leading dims), cols = last dim]` matrices plus
//! explicit shape bookkeeping at the call site — the transformer only ever
//! needs "matrix of row-vectors" semantics.

/// A dense row-major `rows x cols` f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Tensor { rows, cols, data }
    }

    /// 1-D vector as a single-row tensor.
    pub fn row_vec(data: Vec<f32>) -> Tensor {
        let cols = data.len();
        Tensor { rows: 1, cols, data }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Frobenius-norm squared distance to another tensor.
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum()
    }

    /// Mean squared error vs another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        self.sq_dist(other) / self.numel() as f64
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Gather rows: `out[i] = self[idx[i]]` (used by permutation transforms
    /// and the embedding lookup).
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            assert!(r < self.rows, "gather_rows: index {r} out of {}", self.rows);
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Gather columns: `out[:, j] = self[:, idx[j]]`.
    pub fn gather_cols(&self, idx: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// Elementwise in-place scale of row `r` by `s`.
    pub fn scale_row(&mut self, r: usize, s: f32) {
        for x in self.row_mut(r) {
            *x *= s;
        }
    }

    /// Elementwise in-place scale of column `c` by `s`.
    pub fn scale_col(&mut self, c: usize, s: f32) {
        for r in 0..self.rows {
            self.data[r * self.cols + c] *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn gather_rows_permutes() {
        let t = Tensor::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = t.gather_rows(&[2, 0, 1]);
        assert_eq!(g.data, vec![5., 6., 1., 2., 3., 4.]);
    }

    #[test]
    fn gather_cols_permutes() {
        let t = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let g = t.gather_cols(&[1, 2, 0]);
        assert_eq!(g.data, vec![2., 3., 1., 5., 6., 4.]);
    }

    #[test]
    fn mse_and_scale() {
        let a = Tensor::from_vec(1, 2, vec![0., 0.]);
        let b = Tensor::from_vec(1, 2, vec![2., 0.]);
        assert!((a.mse(&b) - 2.0).abs() < 1e-12);
        let mut c = Tensor::from_vec(2, 2, vec![1., 1., 1., 1.]);
        c.scale_row(0, 3.0);
        c.scale_col(1, 2.0);
        assert_eq!(c.data, vec![3., 6., 1., 2.]);
    }
}

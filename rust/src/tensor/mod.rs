//! Dense f32 tensor substrate: row-major matrices, blocked matmul kernels,
//! transformer primitive ops, and the small dense linear algebra (Cholesky)
//! needed by the GPTQ baseline.

pub mod linalg;
pub mod ops;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use tensor::Tensor;

//! Small dense linear algebra for the GPTQ baseline: Cholesky factorization,
//! triangular solves and SPD inversion of the (damped) Hessian `H = 2XXᵀ+λI`.
//!
//! f64 throughout — GPTQ's error-compensation recursion is sensitive to the
//! conditioning of the Hessian, and calibration Hessians here are small
//! (`in_features ≤ 1280`), so the O(n³) cost is negligible next to the
//! forward passes.

/// Cholesky factorization `A = L·Lᵀ` (lower-triangular, in place on a copy).
///
/// Returns an error if `A` is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> crate::Result<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                anyhow::ensure!(s > 0.0, "cholesky: not PD at pivot {i} (s={s})");
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve `L·y = b` (forward substitution), `L` lower-triangular.
pub fn solve_lower(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Solve `Lᵀ·x = y` (backward substitution).
pub fn solve_upper_t(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// SPD inverse via Cholesky: `A⁻¹ = L⁻ᵀ·L⁻¹`, column by column.
pub fn spd_inverse(a: &[f64], n: usize) -> crate::Result<Vec<f64>> {
    let l = cholesky(a, n)?;
    let mut inv = vec![0.0; n * n];
    let mut e = vec![0.0; n];
    for c in 0..n {
        e.fill(0.0);
        e[c] = 1.0;
        let y = solve_lower(&l, n, &e);
        let x = solve_upper_t(&l, n, &y);
        for r in 0..n {
            inv[r * n + c] = x[r];
        }
    }
    Ok(inv)
}

/// Upper-triangular Cholesky of the *inverse*: the `chol(H⁻¹)ᵀ` matrix that
/// GPTQ's fast path uses (Frantar et al. 2023, Alg. 1).  Returns the
/// upper-triangular factor `U` with `H⁻¹ = Uᵀ·U`.
pub fn cholesky_inverse_upper(h: &[f64], n: usize) -> crate::Result<Vec<f64>> {
    let inv = spd_inverse(h, n)?;
    // chol(inv) lower L with inv = L·Lᵀ; we want U = Lᵀ.
    let l = cholesky(&inv, n)?;
    let mut u = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Ok(u)
}

/// Dense symmetric rank-k update used by the Hessian builder:
/// `H += 2 · Xᵀ·X` where `x` is `[samples, n]` row-major.
pub fn sym_accumulate_xtx(h: &mut [f64], x: &[f32], samples: usize, n: usize, coeff: f64) {
    assert_eq!(h.len(), n * n);
    assert_eq!(x.len(), samples * n);
    for s in 0..samples {
        let row = &x[s * n..(s + 1) * n];
        for i in 0..n {
            let xi = row[i] as f64 * coeff;
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h[i * n..(i + 1) * n];
            for (j, hj) in hrow.iter_mut().enumerate().skip(i) {
                *hj += xi * row[j] as f64;
            }
        }
    }
}

/// Mirror the upper triangle into the lower (after accumulation).
pub fn symmetrize_upper(h: &mut [f64], n: usize) {
    for i in 0..n {
        for j in i + 1..n {
            h[j * n + i] = h[i * n + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, rng::Pcg64};

    fn random_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        // A = B·Bᵀ + n·I is SPD.
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        propcheck::check("L·Lᵀ == A", 16, |rng| {
            let n = rng.below(12) + 2;
            let a = random_spd(rng, n);
            let l = cholesky(&a, n).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    if (s - a[i * n + j]).abs() > 1e-8 * (1.0 + a[i * n + j].abs()) {
                        return Err(format!("A[{i},{j}] {s} vs {}", a[i * n + j]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn solve_roundtrip() {
        propcheck::check("A·x == b after solve", 16, |rng| {
            let n = rng.below(10) + 2;
            let a = random_spd(rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let l = cholesky(&a, n).map_err(|e| e.to_string())?;
            let y = solve_lower(&l, n, &b);
            let x = solve_upper_t(&l, n, &y);
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += a[i * n + j] * x[j];
                }
                if (s - b[i]).abs() > 1e-7 {
                    return Err(format!("row {i}: {s} vs {}", b[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Pcg64::new(2);
        let n = 8;
        let a = random_spd(&mut rng, n);
        let inv = spd_inverse(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn cholesky_inverse_upper_property() {
        // H⁻¹ == Uᵀ·U
        let mut rng = Pcg64::new(3);
        let n = 6;
        let h = random_spd(&mut rng, n);
        let u = cholesky_inverse_upper(&h, n).unwrap();
        let inv = spd_inverse(&h, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[k * n + i] * u[k * n + j];
                }
                assert!((s - inv[i * n + j]).abs() < 1e-8);
            }
        }
        // and U is upper-triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn hessian_accumulation() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 samples, n=2
        let mut h = vec![0.0f64; 4];
        sym_accumulate_xtx(&mut h, &x, 2, 2, 2.0);
        symmetrize_upper(&mut h, 2);
        // 2·XᵀX: X = [[1,2],[3,4]] -> XᵀX = [[10,14],[14,20]]
        assert_eq!(h, vec![20.0, 28.0, 28.0, 40.0]);
    }
}

//! Quantization baselines the paper composes with (Table 1):
//!
//! * **RTN** — plain round-to-nearest group quantization;
//! * **GPTQ** — sequential quantization with Hessian-based error
//!   compensation (Frantar et al., 2023), implemented from scratch in
//!   [`gptq`];
//! * **AWQ** — activation-aware weight scaling + clipping (Lin et al.,
//!   2024b) in [`awq`];
//! * **OmniQuant-lite** — learned equivalent scaling + learned clipping
//!   (Shao et al., 2024), with the gradient updates replaced by coordinate
//!   descent / grid search (documented substitution, DESIGN.md §1) in
//!   [`omniquant`].
//!
//! Each method "prepares" a model: it may rewrite the FP weights through an
//! *invariance-preserving* preprocessing (AWQ/OmniQuant fold per-channel
//! scales into adjacent ops) and it defines the *quantizer semantics* used
//! both for the full-model quantization and for the per-proposal
//! re-quantization inside the InvarExplore search loop.

pub mod awq;
pub mod gptq;
pub mod omniquant;
pub mod rtn;

use std::collections::HashMap;

use crate::calib::{CalibSet, CalibStats};
use crate::model::Weights;
use crate::quant::{self, clip, BitAllocation, QuantScheme};
use crate::tensor::Tensor;
use crate::transform::LayerTransform;

/// Baseline method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Rtn,
    Gptq,
    Awq,
    OmniQuant,
}

impl Method {
    pub fn parse(s: &str) -> crate::Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtn" => Method::Rtn,
            "gptq" => Method::Gptq,
            "awq" => Method::Awq,
            "omniquant" | "omni" => Method::OmniQuant,
            _ => anyhow::bail!("unknown method {s:?} (rtn|gptq|awq|omniquant)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::OmniQuant => "OmniQuant",
        }
    }

    pub fn all() -> [Method; 4] {
        [Method::Rtn, Method::Gptq, Method::Awq, Method::OmniQuant]
    }
}

/// Quantizer semantics attached to a prepared model.
pub enum Quantizer {
    /// Plain RTN fake-quant.
    Plain,
    /// RTN with per-group clip-ratio search over a grid.
    Clipped(&'static [f32]),
    /// GPTQ: per-linear damped Hessians; blocked (group-diagonal)
    /// compensation (see [`gptq`] for the exact/blocked trade-off).
    Gptq {
        hessians: HashMap<String, Vec<f64>>,
        exact: bool,
    },
}

/// A model prepared for quantization by one method.
pub struct Prepared {
    pub method: Method,
    /// Default (uniform) scheme — `alloc.default`; kept as a field because
    /// most of the stack is still scheme-first.
    pub scheme: QuantScheme,
    /// Per-tensor schemes.  Uniform (`alloc.default == scheme`, no
    /// overrides) unless built through [`prepare_mixed`] or mutated by an
    /// accepted bit-swap search move.
    pub alloc: BitAllocation,
    /// Preprocessed FP weights — the θ₀ the InvarExplore search transforms.
    pub fp: Weights,
    pub quantizer: Quantizer,
}

impl Prepared {
    /// Quantize (fake-quant) one linear weight under this method's
    /// semantics at the tensor's *allocated* scheme.  `name` is the
    /// canonical parameter name (`l0.down.w`); `transform` is the
    /// currently-applied FFN transform of that layer, needed only by GPTQ
    /// to transform the stored Hessian of `down.w`.
    pub fn quantize_tensor(
        &self,
        name: &str,
        w: &Tensor,
        transform: Option<&LayerTransform>,
    ) -> Tensor {
        self.quantize_tensor_with(name, w, self.alloc.scheme_for(name), transform)
    }

    /// [`Prepared::quantize_tensor`] at an explicit scheme — the bit-swap
    /// drafting path probes ±1-bit schemes without mutating the accepted
    /// allocation.
    pub fn quantize_tensor_with(
        &self,
        name: &str,
        w: &Tensor,
        scheme: QuantScheme,
        transform: Option<&LayerTransform>,
    ) -> Tensor {
        match &self.quantizer {
            Quantizer::Plain => quant::fake_quant(w, scheme),
            Quantizer::Clipped(grid) => clip::fake_quant_clip_search(w, scheme, grid),
            Quantizer::Gptq { hessians, exact } => {
                let h = hessians
                    .get(name)
                    .unwrap_or_else(|| panic!("GPTQ: no hessian for {name:?}"));
                let is_down = name.ends_with("down.w");
                let t = if is_down { transform } else { None };
                gptq::gptq_quantize(w, h, scheme, *exact, t)
            }
        }
    }

    /// Fully quantize a weight set (which may already carry transforms),
    /// producing the dequantized model fed to the evaluators.
    pub fn quantize_model(
        &self,
        weights: &Weights,
        transforms: Option<&[LayerTransform]>,
    ) -> Weights {
        let mut out = weights.clone();
        for name in weights.quant_names() {
            let layer = crate::model::config::split_layer_prefix(&name)
                .0
                .expect("quant names carry a layer prefix");
            let t = transforms.map(|ts| &ts[layer]);
            let q = self.quantize_tensor(&name, weights.get(&name), t);
            out.set(&name, q);
        }
        out
    }

    /// Packed (deployment) form of every quantizable tensor + total bytes,
    /// each tensor packed at its allocated scheme (heterogeneous
    /// allocations pack heterogeneous [`quant::PackedTensor`]s).
    ///
    /// Packing always uses the plain codec on the *method-quantized* values
    /// (codes are what they are; scales/zeros re-derived), which is a
    /// faithful memory model because all methods share the group layout.
    pub fn pack_model(&self, weights: &Weights) -> (Vec<(String, quant::PackedTensor)>, usize) {
        let mut out = Vec::new();
        let mut bytes = 0;
        for name in weights.quant_names() {
            let q = quant::quantize(weights.get(&name), self.alloc.scheme_for(&name));
            let p = quant::PackedTensor::pack(&q);
            bytes += p.nbytes();
            out.push((name, p));
        }
        (out, bytes)
    }

    /// Deployment serving form: the packed linears of `weights` plus this
    /// method's preprocessed FP weights for everything else — ready to
    /// serve through [`crate::serve::Server`] without densifying.
    pub fn packed_model(&self, weights: &Weights) -> crate::serve::PackedModel {
        let (packed, _) = self.pack_model(weights);
        crate::serve::PackedModel::new(self.fp.clone(), packed)
    }
}

/// Prepare a model for quantization under `method`.
///
/// `calib` is required by GPTQ/AWQ/OmniQuant (activation statistics); RTN
/// ignores it.  `stats` may be passed in to share one native-forward capture
/// across several methods.
pub fn prepare(
    method: Method,
    scheme: QuantScheme,
    weights: &Weights,
    calib: &CalibSet,
    stats: Option<&CalibStats>,
) -> crate::Result<Prepared> {
    let owned_stats;
    let stats = match (method, stats) {
        (Method::Rtn, _) => None,
        (_, Some(s)) => Some(s),
        (_, None) => {
            owned_stats = crate::calib::capture(weights, calib);
            Some(&owned_stats)
        }
    };
    match method {
        Method::Rtn => Ok(rtn::prepare(scheme, weights)),
        Method::Awq => Ok(awq::prepare(scheme, weights, stats.unwrap())),
        Method::OmniQuant => Ok(omniquant::prepare(scheme, weights, stats.unwrap())),
        Method::Gptq => Ok(gptq::prepare(scheme, weights, stats.unwrap())),
    }
}

/// [`prepare`] with a mixed-precision [`BitAllocation`]: the method's
/// preprocessing (scale folding, Hessians, clip grids) is calibrated at the
/// allocation's *default* scheme, while every tensor quantizes and packs at
/// its allocated scheme.  Group sizes are validated against the model's
/// tensor shapes up front.
pub fn prepare_mixed(
    method: Method,
    alloc: &BitAllocation,
    weights: &Weights,
    calib: &CalibSet,
    stats: Option<&CalibStats>,
) -> crate::Result<Prepared> {
    alloc.validate(&weights.config)?;
    let mut p = prepare(method, alloc.default, weights, calib, stats)?;
    p.alloc = alloc.clone();
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tokens::TokenCorpus;
    use crate::model::OptConfig;
    use crate::util::rng::Pcg64;

    pub(crate) fn test_setup() -> (Weights, CalibSet) {
        let cfg = OptConfig::test_config();
        let w = Weights::random(cfg.clone(), 42);
        let mut rng = Pcg64::new(7);
        let corpus = TokenCorpus {
            vocab: cfg.vocab,
            tokens: (0..700).map(|_| rng.below(cfg.vocab) as u32).collect(),
        };
        (w, CalibSet::from_corpus(&corpus, 4, 16))
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("awq").unwrap(), Method::Awq);
        assert_eq!(Method::parse("OMNI").unwrap(), Method::OmniQuant);
        assert!(Method::parse("xyz").is_err());
    }

    #[test]
    fn rtn_prepare_keeps_weights() {
        let (w, calib) = test_setup();
        let p = prepare(Method::Rtn, QuantScheme::new(2, 32), &w, &calib, None).unwrap();
        assert_eq!(p.fp.get("l0.up.w"), w.get("l0.up.w"));
        let q = p.quantize_model(&p.fp, None);
        // quantized linears differ; non-linears untouched
        assert_ne!(q.get("l0.up.w"), p.fp.get("l0.up.w"));
        assert_eq!(q.get("emb"), p.fp.get("emb"));
        assert_eq!(q.get("l0.ln1.w"), p.fp.get("l0.ln1.w"));
    }

    #[test]
    fn all_methods_quantize_all_linears() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        for m in Method::all() {
            let p = prepare(m, QuantScheme::new(2, 32), &w, &calib, Some(&stats)).unwrap();
            let q = p.quantize_model(&p.fp, None);
            for name in w.quant_names() {
                assert_ne!(
                    q.get(&name),
                    p.fp.get(&name),
                    "{} left {name} unquantized",
                    m.name()
                );
            }
        }
    }

    #[test]
    fn pack_model_reports_compression() {
        let (w, calib) = test_setup();
        let p = prepare(Method::Rtn, QuantScheme::new(2, 32), &w, &calib, None).unwrap();
        let (packed, bytes) = p.pack_model(&p.fp);
        assert_eq!(packed.len(), w.quant_names().len());
        let fp_bytes: usize = w.quant_names().iter().map(|n| w.get(n).numel() * 2).sum();
        assert!(bytes < fp_bytes / 4, "packed {bytes} vs fp16 {fp_bytes}");
    }

    #[test]
    fn mixed_allocation_quantizes_and_packs_per_tensor() {
        let (w, calib) = test_setup();
        let alloc = BitAllocation::parse("2x32,ffn_up=4x32,l0.q.w=1x16").unwrap();
        let p = prepare_mixed(Method::Rtn, &alloc, &w, &calib, None).unwrap();
        assert_eq!(p.scheme, QuantScheme::new(2, 32));
        // per-tensor quantization obeys the allocation: 4-bit up.w must be
        // strictly closer to FP than the same tensor at the 2-bit default
        let name = "l0.up.w";
        let four_bit = p.quantize_tensor(name, w.get(name), None);
        let two_bit = p.quantize_tensor_with(name, w.get(name), QuantScheme::new(2, 32), None);
        let err4 = w.get(name).mse(&four_bit);
        let err2 = w.get(name).mse(&two_bit);
        assert!(err4 < err2, "4-bit err {err4} !< 2-bit err {err2}");
        // packing carries per-tensor schemes
        let (packed, _) = p.pack_model(&p.fp);
        let find = |n: &str| packed.iter().find(|(pn, _)| pn == n).unwrap();
        assert_eq!(find("l0.up.w").1.scheme, QuantScheme::new(4, 32));
        assert_eq!(find("l1.up.w").1.scheme, QuantScheme::new(4, 32));
        assert_eq!(find("l0.q.w").1.scheme, QuantScheme::new(1, 16));
        assert_eq!(find("l1.q.w").1.scheme, QuantScheme::new(2, 32));
        assert_eq!(find("l0.down.w").1.scheme, QuantScheme::new(2, 32));
        // mixed packed model serves
        let pm = p.packed_model(&p.fp);
        assert_eq!(pm.n_packed(), w.quant_names().len());
    }

    #[test]
    fn mixed_allocation_group_mismatch_rejected() {
        let (w, calib) = test_setup();
        // q.w has 32 columns; group 64 cannot divide it
        let alloc = BitAllocation::parse("2x32,attn_q=2x64").unwrap();
        let err = prepare_mixed(Method::Rtn, &alloc, &w, &calib, None).unwrap_err();
        assert!(err.to_string().contains("not divisible"), "{err}");
    }

    #[test]
    fn packed_model_plumbs_into_serving() {
        let (w, calib) = test_setup();
        let p = prepare(Method::Rtn, QuantScheme::new(2, 32), &w, &calib, None).unwrap();
        let quantized = p.quantize_model(&p.fp, None);
        let pm = p.packed_model(&quantized);
        assert_eq!(pm.n_packed(), w.quant_names().len());
        assert!(pm.bits_per_param() < 4.0);
        // non-quantized params come from the prepared FP weights
        assert_eq!(pm.unpacked_weights().get("emb"), p.fp.get("emb"));
    }
}

//! OmniQuant-lite (Shao et al., 2024): *learnable* equivalent scaling +
//! *learnable* weight clipping.
//!
//! The original learns both by SGD through a straight-through estimator;
//! gradients are unavailable here by design (and the paper's whole point is
//! that discrete search composes with such methods), so this implementation
//! learns the same parameters by **derivative-free coordinate descent**:
//!
//! * per-channel equivalent scales start at the AWQ α=0.5 heuristic and are
//!   refined channel-block-wise over a multiplicative grid, accepting moves
//!   that lower the layer-output reconstruction error on a calibration
//!   subsample (the same block-wise error minimization objective OmniQuant
//!   optimizes);
//! * clipping uses the finer OMNI grid per group at quantization time.
//!
//! This is the documented substitution of DESIGN.md §1; the reproduced
//! claim is ordering (OmniQuant ≥ AWQ ≥ GPTQ ≥ RTN) and a smaller
//! +InvarExplore delta than AWQ's.

use super::{Method, Prepared, Quantizer};
use crate::baselines::awq::{scale_bias, scale_in_cols, scale_out_rows};
use crate::calib::{channel_mean_abs, CalibStats};
use crate::model::Weights;
use crate::quant::{clip, QuantScheme};
use crate::tensor::ops::matmul_nt;
use crate::tensor::Tensor;

/// Multiplicative moves tried per channel block during coordinate descent.
const MOVE_GRID: [f32; 4] = [0.7, 0.85, 1.2, 1.4];
/// Coordinate-descent sweeps.
const SWEEPS: usize = 2;
/// Channels per coordinate block (descent on blocks, not single channels).
const BLOCK: usize = 16;
/// Calibration rows used for the reconstruction objective.
const SEARCH_ROWS: usize = 128;

pub fn prepare(scheme: QuantScheme, weights: &Weights, stats: &CalibStats) -> Prepared {
    let mut fp = weights.clone();
    let cfg = fp.config.clone();

    for l in 0..cfg.n_layers {
        let li = &stats.inputs[l];

        // qkv input scales (shared, folded into LN1)
        let s_qkv = learn_scales(&[fp.layer(l, "q.w"), fp.layer(l, "k.w"), fp.layer(l, "v.w")], &li.qkv_in, scheme);
        for nm in ["q.w", "k.w", "v.w"] {
            scale_in_cols(fp.layer_mut(l, nm), &s_qkv);
        }
        fold_inv_ln(&mut fp, l, "ln1", &s_qkv);

        let s_o = learn_scales(&[fp.layer(l, "o.w")], &li.o_in, scheme);
        scale_in_cols(fp.layer_mut(l, "o.w"), &s_o);
        scale_out_rows(fp.layer_mut(l, "v.w"), &s_o, true);
        scale_bias(fp.layer_mut(l, "v.b"), &s_o, true);

        let s_up = learn_scales(&[fp.layer(l, "up.w")], &li.up_in, scheme);
        scale_in_cols(fp.layer_mut(l, "up.w"), &s_up);
        fold_inv_ln(&mut fp, l, "ln2", &s_up);

        let s_down = learn_scales(&[fp.layer(l, "down.w")], &li.down_in, scheme);
        scale_in_cols(fp.layer_mut(l, "down.w"), &s_down);
        scale_out_rows(fp.layer_mut(l, "up.w"), &s_down, true);
        scale_bias(fp.layer_mut(l, "up.b"), &s_down, true);
    }

    Prepared {
        method: Method::OmniQuant,
        scheme,
        alloc: super::BitAllocation::uniform(scheme),
        fp,
        quantizer: Quantizer::Clipped(&clip::OMNI_CLIP_GRID),
    }
}

fn fold_inv_ln(fp: &mut Weights, l: usize, ln: &str, s: &[f32]) {
    for suffix in ["w", "b"] {
        let t = fp.layer_mut(l, &format!("{ln}.{suffix}"));
        for (v, &sc) in t.data.iter_mut().zip(s) {
            *v /= sc;
        }
    }
}

/// Incremental reconstruction state for one consumer weight: keeps the
/// current effective weight `eff = Q(W·S)·S⁻¹` and its output `y1 = X·effᵀ`
/// so a candidate move on a channel block only re-quantizes the overlapped
/// quant groups and applies a rank-(block) update to `y1` — O(m·Δcols·out)
/// instead of a full re-quantize + matmul per move.
struct ReconState<'w> {
    w: &'w Tensor,
    eff: Tensor,
    y0: Vec<f32>,
    y1: Vec<f32>,
}

impl<'w> ReconState<'w> {
    fn new(w: &'w Tensor, s: &[f32], x: &Tensor, scheme: QuantScheme) -> ReconState<'w> {
        let eff = effective_weight(w, s, 0, w.cols, scheme);
        let (m, k, n) = (x.rows, x.cols, w.rows);
        let mut y0 = vec![0.0f32; m * n];
        let mut y1 = vec![0.0f32; m * n];
        matmul_nt(&x.data, &w.data, m, k, n, &mut y0);
        matmul_nt(&x.data, &eff.data, m, k, n, &mut y1);
        ReconState { w, eff, y0, y1 }
    }

    fn err(&self) -> f64 {
        self.y0
            .iter()
            .zip(&self.y1)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }

    /// Error if columns `[lo, hi)` used scales `s` (others unchanged).
    /// Returns (err, new column slab) without committing.
    fn probe(&self, s: &[f32], lo: usize, hi: usize, x: &Tensor, scheme: QuantScheme) -> (f64, Tensor) {
        let slab = effective_weight(self.w, s, lo, hi, scheme);
        // y1' = y1 + X[:, lo..hi] · (slab − eff[:, lo..hi])ᵀ
        let mut err = 0.0f64;
        let (m, n_out) = (x.rows, self.w.rows);
        for row in 0..m {
            let xr = x.row(row);
            let y0r = &self.y0[row * n_out..(row + 1) * n_out];
            let y1r = &self.y1[row * n_out..(row + 1) * n_out];
            for o in 0..n_out {
                let er = self.eff.row(o);
                let sr = slab.row(o);
                let mut delta = 0.0f32;
                for c in lo..hi {
                    delta += xr[c] * (sr[c - lo] - er[c]);
                }
                let d = (y0r[o] - (y1r[o] + delta)) as f64;
                err += d * d;
            }
        }
        (err, slab)
    }

    /// Commit a probed slab.
    fn commit(&mut self, slab: Tensor, lo: usize, hi: usize, x: &Tensor) {
        let (m, n_out) = (x.rows, self.w.rows);
        for row in 0..m {
            let xr = x.row(row);
            for o in 0..n_out {
                let er = self.eff.row(o);
                let sr = slab.row(o);
                let mut delta = 0.0f32;
                for c in lo..hi {
                    delta += xr[c] * (sr[c - lo] - er[c]);
                }
                self.y1[row * n_out + o] += delta;
            }
        }
        for o in 0..self.w.rows {
            self.eff.row_mut(o)[lo..hi].copy_from_slice(slab.row(o));
        }
    }
}

/// `Q(W[:, lo..hi]·S)·S⁻¹` for a group-aligned column range.
fn effective_weight(w: &Tensor, s: &[f32], lo: usize, hi: usize, scheme: QuantScheme) -> Tensor {
    debug_assert_eq!(lo % scheme.group, 0);
    debug_assert_eq!((hi - lo) % scheme.group, 0);
    let mut slab = Tensor::zeros(w.rows, hi - lo);
    for r in 0..w.rows {
        let src = &w.row(r)[lo..hi];
        let dst = slab.row_mut(r);
        for (d, (v, &sc)) in dst.iter_mut().zip(src.iter().zip(&s[lo..hi])) {
            *d = v * sc;
        }
    }
    let mut q = clip::fake_quant_clip_search(&slab, scheme, &clip::OMNI_CLIP_GRID);
    for r in 0..q.rows {
        for (v, &sc) in q.row_mut(r).iter_mut().zip(&s[lo..hi]) {
            *v /= sc;
        }
    }
    q
}

/// Learn per-channel scales for the consumers `ws` of input `x`.
fn learn_scales(ws: &[&Tensor], x: &Tensor, scheme: QuantScheme) -> Vec<f32> {
    let n = x.cols;
    let xsub = subsample(x, SEARCH_ROWS);
    // init: AWQ-style α = 0.5 heuristic
    let acts = channel_mean_abs(x);
    let mut wmag = vec![1e-8f32; n];
    for w in ws {
        for r in 0..w.rows {
            for (j, &v) in w.row(r).iter().enumerate() {
                wmag[j] = wmag[j].max(v.abs());
            }
        }
    }
    let mut s: Vec<f32> = acts
        .iter()
        .zip(&wmag)
        .map(|(&a, &m)| (a.max(1e-6) / m).sqrt().clamp(0.1, 10.0))
        .collect();

    let mut states: Vec<ReconState> = ws.iter().map(|w| ReconState::new(w, &s, &xsub, scheme)).collect();
    let mut best_err: f64 = states.iter().map(|st| st.err()).sum();

    // block coordinate descent over group-aligned slabs
    let slab_w = BLOCK.max(scheme.group);
    for _sweep in 0..SWEEPS {
        let mut b0 = 0;
        while b0 < n {
            let b1 = (b0 + slab_w).min(n);
            let saved: Vec<f32> = s[b0..b1].to_vec();
            let mut improved = false;
            for &mv in &MOVE_GRID {
                for (j, sv) in s[b0..b1].iter_mut().enumerate() {
                    *sv = (saved[j] * mv).clamp(0.1, 10.0);
                }
                let probes: Vec<(f64, Tensor)> =
                    states.iter().map(|st| st.probe(&s, b0, b1, &xsub, scheme)).collect();
                let err: f64 = probes.iter().map(|(e, _)| e).sum();
                if err < best_err {
                    best_err = err;
                    for (st, (_, slab)) in states.iter_mut().zip(probes) {
                        st.commit(slab, b0, b1, &xsub);
                    }
                    improved = true;
                    break;
                }
            }
            if !improved {
                s[b0..b1].copy_from_slice(&saved);
            }
            b0 = b1;
        }
    }
    s
}

/// `‖X·Wᵀ − (X/s)·Q(W·diag(s))ᵀ‖²` under the OMNI clip grid (reference
/// implementation kept for tests of the incremental ReconState path).
#[cfg_attr(not(test), allow(dead_code))]
fn recon_err(w: &Tensor, s: &[f32], x: &Tensor, scheme: QuantScheme) -> f64 {
    let mut ws = w.clone();
    scale_in_cols(&mut ws, s);
    let mut eff = clip::fake_quant_clip_search(&ws, scheme, &clip::OMNI_CLIP_GRID);
    let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
    scale_in_cols(&mut eff, &inv);
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut y0 = vec![0.0f32; m * n];
    let mut y1 = vec![0.0f32; m * n];
    matmul_nt(&x.data, &w.data, m, k, n, &mut y0);
    matmul_nt(&x.data, &eff.data, m, k, n, &mut y1);
    y0.iter().zip(&y1).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
}

fn subsample(x: &Tensor, rows: usize) -> Tensor {
    if x.rows <= rows {
        return x.clone();
    }
    let stride = x.rows / rows;
    let idx: Vec<usize> = (0..rows).map(|i| i * stride).collect();
    x.gather_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_setup;
    use crate::model::native::{forward, Capture};

    #[test]
    fn omniquant_fold_is_fp_invariant() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let p = prepare(QuantScheme::new(2, 32), &w, &stats);
        let ce0 = forward(&w, &calib.tokens, &calib.targets, &calib.masks, Capture::default()).ce;
        let ce1 = forward(&p.fp, &calib.tokens, &calib.targets, &calib.masks, Capture::default()).ce;
        assert!((ce0 - ce1).abs() / ce0 < 1e-4, "{ce0} vs {ce1}");
    }

    #[test]
    fn learned_scales_no_worse_than_init_on_objective() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let scheme = QuantScheme::new(2, 32);
        let x = &stats.inputs[0].down_in;
        let wt = w.layer(0, "down.w");
        let xsub = subsample(x, SEARCH_ROWS);
        // init (α=0.5 heuristic) error vs learned error
        let acts = channel_mean_abs(x);
        let mut wmag = vec![1e-8f32; x.cols];
        for r in 0..wt.rows {
            for (j, &v) in wt.row(r).iter().enumerate() {
                wmag[j] = wmag[j].max(v.abs());
            }
        }
        let s0: Vec<f32> = acts
            .iter()
            .zip(&wmag)
            .map(|(&a, &m)| (a.max(1e-6) / m).sqrt().clamp(0.1, 10.0))
            .collect();
        let e0 = recon_err(wt, &s0, &xsub, scheme);
        let s1 = learn_scales(&[wt], x, scheme);
        let e1 = recon_err(wt, &s1, &xsub, scheme);
        assert!(e1 <= e0 + 1e-9, "descent made it worse: {e1} vs {e0}");
    }

    #[test]
    fn quantizer_uses_fine_grid() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let p = prepare(QuantScheme::new(2, 32), &w, &stats);
        assert!(matches!(p.quantizer, Quantizer::Clipped(g) if g.len() == clip::OMNI_CLIP_GRID.len()));
    }
}

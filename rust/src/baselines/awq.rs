//! AWQ (activation-aware weight quantization, Lin et al. 2024b).
//!
//! Mechanism reproduced from scratch:
//!
//! 1. **Per-input-channel scaling** `s_j = a_j^α / w_j^(1-α)` (activation
//!    magnitude vs weight magnitude), normalized to geometric mean 1, with
//!    the exponent α grid-searched per linear by reconstruction MSE of the
//!    layer *output* on a calibration subsample.
//! 2. The chosen scales are **folded invariantly** into the model — the
//!    producer of each input channel absorbs `1/s`:
//!    * q/k/v inputs (post-LN1)  → LN1 affine params;
//!    * o input (attention mix)  → v-projection output rows (channel-exact
//!      because attention mixes over time, not channels);
//!    * up input (post-LN2)      → LN2 affine params;
//!    * down input (ReLU(up·x))  → up-projection rows (ReLU commutes with
//!      positive scales — the same identity as the paper's Eqn. 13).
//! 3. **Per-group weight clipping** at quantization time
//!    ([`crate::quant::clip`], AWQ grid).
//!
//! The folded model is FP-invariant, so it is a valid θ₀ for InvarExplore.

use super::{Method, Prepared, Quantizer};
use crate::calib::{channel_mean_abs, CalibStats};
use crate::model::Weights;
use crate::quant::{clip, QuantScheme};
use crate::tensor::ops::matmul_nt;
use crate::tensor::Tensor;

/// α grid (AWQ searches 20 points in [0,1]; 9 is enough at our scale).
const ALPHA_GRID: [f32; 9] = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];

/// Rows of calibration activations used for the α reconstruction search.
const SEARCH_ROWS: usize = 128;

pub fn prepare(scheme: QuantScheme, weights: &Weights, stats: &CalibStats) -> Prepared {
    let mut fp = weights.clone();
    let cfg = fp.config.clone();

    for l in 0..cfg.n_layers {
        let li = &stats.inputs[l];

        // q/k/v share the post-LN1 input; search α on their concatenated
        // reconstruction and fold one scale vector into LN1.
        let qkv_acts = channel_mean_abs(&li.qkv_in);
        let s_qkv = {
            let wq = fp.layer(l, "q.w").clone();
            best_scales(&qkv_acts, &[&wq], &li.qkv_in, scheme)
        };
        for nm in ["q.w", "k.w", "v.w"] {
            scale_in_cols(fp.layer_mut(l, nm), &s_qkv);
        }
        fold_inverse_into_ln(&mut fp, l, "ln1", &s_qkv);

        // o projection: fold 1/s into v output rows.
        let o_acts = channel_mean_abs(&li.o_in);
        let s_o = {
            let wo = fp.layer(l, "o.w").clone();
            best_scales(&o_acts, &[&wo], &li.o_in, scheme)
        };
        scale_in_cols(fp.layer_mut(l, "o.w"), &s_o);
        scale_out_rows(fp.layer_mut(l, "v.w"), &s_o, true);
        scale_bias(fp.layer_mut(l, "v.b"), &s_o, true);

        // up projection: fold into LN2.
        let up_acts = channel_mean_abs(&li.up_in);
        let s_up = {
            let wu = fp.layer(l, "up.w").clone();
            best_scales(&up_acts, &[&wu], &li.up_in, scheme)
        };
        scale_in_cols(fp.layer_mut(l, "up.w"), &s_up);
        fold_inverse_into_ln(&mut fp, l, "ln2", &s_up);

        // down projection: fold into up rows (ReLU-invariant).
        let down_acts = channel_mean_abs(&li.down_in);
        let s_down = {
            let wd = fp.layer(l, "down.w").clone();
            best_scales(&down_acts, &[&wd], &li.down_in, scheme)
        };
        scale_in_cols(fp.layer_mut(l, "down.w"), &s_down);
        scale_out_rows(fp.layer_mut(l, "up.w"), &s_down, true);
        scale_bias(fp.layer_mut(l, "up.b"), &s_down, true);
    }

    Prepared {
        method: Method::Awq,
        scheme,
        alloc: super::BitAllocation::uniform(scheme),
        fp,
        quantizer: Quantizer::Clipped(&clip::AWQ_CLIP_GRID),
    }
}

/// Grid-search α; returns the winning per-channel scale vector.
fn best_scales(acts: &[f32], ws: &[&Tensor], x: &Tensor, scheme: QuantScheme) -> Vec<f32> {
    let xsub = subsample(x, SEARCH_ROWS);
    let mut best = vec![1.0f32; acts.len()];
    let mut best_err = f64::INFINITY;
    for &alpha in &ALPHA_GRID {
        let s = scales_for_alpha(acts, ws, alpha);
        let mut err = 0.0;
        for w in ws {
            err += reconstruction_error(w, &s, &xsub, scheme);
        }
        if err < best_err {
            best_err = err;
            best = s;
        }
    }
    best
}

/// `s_j = a_j^α / w_j^(1-α)`, geometric-mean-normalized, clamped.
fn scales_for_alpha(acts: &[f32], ws: &[&Tensor], alpha: f32) -> Vec<f32> {
    let n = acts.len();
    // per-channel weight magnitude: max |W[:, j]| over all consumers
    let mut wmag = vec![1e-8f32; n];
    for w in ws {
        for r in 0..w.rows {
            for (j, &v) in w.row(r).iter().enumerate() {
                wmag[j] = wmag[j].max(v.abs());
            }
        }
    }
    let mut s: Vec<f32> = acts
        .iter()
        .zip(&wmag)
        .map(|(&a, &m)| (a.max(1e-6)).powf(alpha) / m.powf(1.0 - alpha))
        .collect();
    // normalize to geometric mean 1 (keeps the fold well-conditioned)
    let log_mean: f32 = s.iter().map(|v| v.ln()).sum::<f32>() / n as f32;
    let norm = (-log_mean).exp();
    for v in &mut s {
        *v = (*v * norm).clamp(0.1, 10.0);
    }
    s
}

/// `‖X·Wᵀ − (X/s)·Q(W·diag(s))ᵀ‖²` on the subsample.
fn reconstruction_error(w: &Tensor, s: &[f32], x: &Tensor, scheme: QuantScheme) -> f64 {
    let mut ws = w.clone();
    scale_in_cols(&mut ws, s);
    let qws = clip::fake_quant_clip_search(&ws, scheme, &clip::AWQ_CLIP_GRID);
    // fold the x-side back: effective weight = Q(W·S)·S⁻¹
    let mut eff = qws;
    let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
    scale_in_cols(&mut eff, &inv);

    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut y0 = vec![0.0f32; m * n];
    let mut y1 = vec![0.0f32; m * n];
    matmul_nt(&x.data, &w.data, m, k, n, &mut y0);
    matmul_nt(&x.data, &eff.data, m, k, n, &mut y1);
    y0.iter()
        .zip(&y1)
        .map(|(a, b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum()
}

fn subsample(x: &Tensor, rows: usize) -> Tensor {
    if x.rows <= rows {
        return x.clone();
    }
    let stride = x.rows / rows;
    let idx: Vec<usize> = (0..rows).map(|i| i * stride).collect();
    x.gather_rows(&idx)
}

/// Multiply input-channel columns of a weight: `W[:, j] *= s_j`.
pub(crate) fn scale_in_cols(w: &mut Tensor, s: &[f32]) {
    assert_eq!(w.cols, s.len());
    for r in 0..w.rows {
        for (v, &sc) in w.row_mut(r).iter_mut().zip(s) {
            *v *= sc;
        }
    }
}

/// Multiply output rows of a weight by `s` (or `1/s` when `inverse`).
pub(crate) fn scale_out_rows(w: &mut Tensor, s: &[f32], inverse: bool) {
    assert_eq!(w.rows, s.len());
    for (r, &sc) in s.iter().enumerate() {
        let f = if inverse { 1.0 / sc } else { sc };
        w.scale_row(r, f);
    }
}

pub(crate) fn scale_bias(b: &mut Tensor, s: &[f32], inverse: bool) {
    assert_eq!(b.numel(), s.len());
    for (v, &sc) in b.data.iter_mut().zip(s) {
        *v *= if inverse { 1.0 / sc } else { sc };
    }
}

/// Fold `1/s` into a LayerNorm's affine output: `ln.w /= s`, `ln.b /= s`.
fn fold_inverse_into_ln(fp: &mut Weights, l: usize, ln: &str, s: &[f32]) {
    for suffix in ["w", "b"] {
        let t = fp.layer_mut(l, &format!("{ln}.{suffix}"));
        for (v, &sc) in t.data.iter_mut().zip(s) {
            *v /= sc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::test_setup;
    use crate::model::native::{forward, Capture};

    #[test]
    fn awq_fold_is_fp_invariant() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let p = prepare(QuantScheme::new(2, 32), &w, &stats);
        let out0 = forward(&w, &calib.tokens, &calib.targets, &calib.masks, Capture::default());
        let out1 = forward(&p.fp, &calib.tokens, &calib.targets, &calib.masks, Capture::default());
        let drift = (out0.ce - out1.ce).abs() / out0.ce;
        assert!(drift < 1e-4, "AWQ fold changed FP model: {} vs {}", out0.ce, out1.ce);
    }

    #[test]
    fn awq_beats_rtn_on_calibration_ce() {
        let (w, calib) = test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let scheme = QuantScheme::new(2, 32);
        let rtn = crate::baselines::rtn::prepare(scheme, &w);
        let awq = prepare(scheme, &w, &stats);
        let q_rtn = rtn.quantize_model(&rtn.fp, None);
        let q_awq = awq.quantize_model(&awq.fp, None);
        let ce_rtn = forward(&q_rtn, &calib.tokens, &calib.targets, &calib.masks, Capture::default()).ce;
        let ce_awq = forward(&q_awq, &calib.tokens, &calib.targets, &calib.masks, Capture::default()).ce;
        // random tiny models are noisy; require "not meaningfully worse"
        assert!(
            ce_awq <= ce_rtn * 1.05,
            "AWQ {ce_awq} should be <= RTN {ce_rtn} (within 5%)"
        );
    }

    #[test]
    fn scales_normalized_and_clamped() {
        let acts = vec![10.0, 0.001, 1.0, 5.0];
        let w = Tensor::from_vec(2, 4, vec![0.1, 2.0, 0.5, 0.05, 0.2, 1.0, 0.3, 0.1]);
        let s = scales_for_alpha(&acts, &[&w], 0.5);
        assert!(s.iter().all(|&v| (0.1..=10.0).contains(&v)));
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn alpha_zero_ignores_activations() {
        let w = Tensor::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let s_a = scales_for_alpha(&[100.0, 1.0, 1.0, 1.0], &[&w], 0.0);
        let s_b = scales_for_alpha(&[1.0, 1.0, 1.0, 1.0], &[&w], 0.0);
        for (a, b) in s_a.iter().zip(&s_b) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}

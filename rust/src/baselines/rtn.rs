//! RTN (round-to-nearest): the no-frills baseline — plain groupwise
//! asymmetric quantization of every linear weight, no calibration.

use super::{Prepared, Quantizer};
use crate::model::Weights;
use crate::quant::QuantScheme;

pub fn prepare(scheme: QuantScheme, weights: &Weights) -> Prepared {
    Prepared {
        method: super::Method::Rtn,
        scheme,
        alloc: super::BitAllocation::uniform(scheme),
        fp: weights.clone(),
        quantizer: Quantizer::Plain,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OptConfig;
    use crate::quant;

    #[test]
    fn rtn_matches_codec_exactly() {
        let w = Weights::random(OptConfig::test_config(), 5);
        let scheme = QuantScheme::new(2, 32);
        let p = prepare(scheme, &w);
        let name = "l1.down.w";
        let q = p.quantize_tensor(name, w.get(name), None);
        let direct = quant::fake_quant(w.get(name), scheme);
        assert_eq!(q, direct);
    }
}

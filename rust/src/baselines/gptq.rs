//! GPTQ (Frantar et al., 2023) from scratch: sequential per-column
//! quantization with second-order (Hessian) error compensation.
//!
//! For each linear `y = W·x`, the damped Hessian `H = 2XXᵀ + λI` over
//! calibration inputs defines the OBQ update: after quantizing column `j`,
//! the remaining columns absorb `err_j · H⁻¹[j, k] / H⁻¹[j, j]`.  We
//! implement the update via explicit Gaussian elimination on `H⁻¹` (exactly
//! equivalent to the paper's Cholesky formulation).
//!
//! **Blocked mode (default)**: compensation is restricted to the columns of
//! each quantization *group* (group-diagonal Hessian blocks).  This keeps
//! the per-proposal re-quantization inside the InvarExplore search loop at
//! `O(out·group²)` instead of `O(in³)` and is the documented substitution
//! (DESIGN.md §1) for the full-Hessian variant, which is also implemented
//! (`exact = true`) and compared in tests — compensation is strongest
//! between nearby columns, so the gap is small.
//!
//! **Hessian under transforms**: the input of `down.w` is the FFN hidden,
//! which the search transforms.  P and S act exactly on post-ReLU channels
//! (`relu(s·x) = s·relu(x)` for `s > 0`); R acts approximately.  The stored
//! Hessian is mapped as `H' = T·H·Tᵀ` with `T = P·S·R` applied entrywise,
//! so GPTQ's compensation stays aligned with the transformed weights.

use std::collections::HashMap;

use super::{Method, Prepared, Quantizer};
use crate::calib::{hessian, CalibStats};
use crate::model::Weights;
use crate::quant::QuantScheme;
use crate::tensor::linalg::spd_inverse;
use crate::tensor::Tensor;
use crate::transform::LayerTransform;
use crate::util::pool;

/// Hessian damping factor (fraction of mean diagonal — GPTQ uses 0.01).
pub const DAMP: f64 = 0.01;

pub fn prepare(scheme: QuantScheme, weights: &Weights, stats: &CalibStats) -> Prepared {
    let cfg = weights.config.clone();
    // Build one Hessian per linear input; q/k/v share theirs.
    let names: Vec<(String, usize, &'static str)> = (0..cfg.n_layers)
        .flat_map(|l| {
            [
                (format!("l{l}.q.w"), l, "qkv"),
                (format!("l{l}.k.w"), l, "qkv"),
                (format!("l{l}.v.w"), l, "qkv"),
                (format!("l{l}.o.w"), l, "o"),
                (format!("l{l}.up.w"), l, "up"),
                (format!("l{l}.down.w"), l, "down"),
            ]
        })
        .collect();

    // compute the four distinct Hessians per layer in parallel
    let per_layer: Vec<[Vec<f64>; 4]> = pool::parallel_map(cfg.n_layers, pool::num_threads(), |l| {
        let li = &stats.inputs[l];
        [
            hessian(&li.qkv_in, DAMP),
            hessian(&li.o_in, DAMP),
            hessian(&li.up_in, DAMP),
            hessian(&li.down_in, DAMP),
        ]
    });

    let mut hessians = HashMap::new();
    for (name, l, kind) in names {
        let idx = match kind {
            "qkv" => 0,
            "o" => 1,
            "up" => 2,
            _ => 3,
        };
        hessians.insert(name, per_layer[l][idx].clone());
    }

    Prepared {
        method: Method::Gptq,
        scheme,
        alloc: super::BitAllocation::uniform(scheme),
        fp: weights.clone(),
        quantizer: Quantizer::Gptq { hessians, exact: false },
    }
}

#[inline]
fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// GPTQ-quantize one weight `[out, in]` given its input Hessian `[in, in]`.
///
/// `transform`, when given, maps the Hessian into the transformed channel
/// basis first (used for `down.w` during the search).
pub fn gptq_quantize(
    w: &Tensor,
    h: &[f64],
    scheme: QuantScheme,
    exact: bool,
    transform: Option<&LayerTransform>,
) -> Tensor {
    let (_rows, cols) = w.shape();
    assert_eq!(h.len(), cols * cols, "hessian shape mismatch");
    assert_eq!(cols % scheme.group, 0);

    let mut wq = w.clone();
    if exact {
        let h_owned;
        let h = match transform {
            Some(t) => {
                h_owned = transform_hessian(h, cols, t);
                &h_owned[..]
            }
            None => h,
        };
        gptq_span(&mut wq, h, cols, 0, cols, scheme);
    } else {
        // blocked mode touches only group-diagonal H' blocks — build each
        // block entrywise from the original H (perf: avoids materializing
        // the full cols² transformed Hessian per proposal)
        let mut hs = vec![0.0f64; scheme.group * scheme.group];
        for g0 in (0..cols).step_by(scheme.group) {
            match transform {
                Some(t) => {
                    for i in 0..scheme.group {
                        for j in 0..scheme.group {
                            hs[i * scheme.group + j] =
                                transformed_h_entry(h, cols, t, g0 + i, g0 + j);
                        }
                    }
                }
                None => {
                    for i in 0..scheme.group {
                        for j in 0..scheme.group {
                            hs[i * scheme.group + j] = h[(g0 + i) * cols + (g0 + j)];
                        }
                    }
                }
            }
            gptq_block(&mut wq, &hs, g0, scheme.group, scheme);
        }
    }
    wq
}

/// One entry of `H' = P·S·R · H · Rᵀ·S·Pᵀ` computed on the fly:
/// `H'[a,b] = s_a·s_b·(R·H·Rᵀ)[π_a, π_b]`, where the pairwise rotation
/// contributes at most 4 source entries.
fn transformed_h_entry(h: &[f64], n: usize, t: &LayerTransform, a: usize, b: usize) -> f64 {
    #[inline]
    fn row_coeffs(t: &LayerTransform, idx: usize) -> (usize, usize, f64, f64) {
        // R's row `idx` has entries over the pair (p0, p0+1)
        let p = idx / 2;
        let (c, s) = (t.phis[p].cos() as f64, t.phis[p].sin() as f64);
        let p0 = 2 * p;
        if idx % 2 == 0 {
            (p0, p0 + 1, c, -s)
        } else {
            (p0, p0 + 1, s, c)
        }
    }
    let (pa, pb) = (t.perm[a], t.perm[b]);
    let (i0, i1, ca0, ca1) = row_coeffs(t, pa);
    let (j0, j1, cb0, cb1) = row_coeffs(t, pb);
    let hr = ca0 * (cb0 * h[i0 * n + j0] + cb1 * h[i0 * n + j1])
        + ca1 * (cb0 * h[i1 * n + j0] + cb1 * h[i1 * n + j1]);
    t.scale[a] as f64 * t.scale[b] as f64 * hr
}

/// Run the OBQ recursion on columns `[a, a+len)` of the *full* Hessian
/// (exact mode), extracting the sub-Hessian first.
fn gptq_span(w: &mut Tensor, h: &[f64], n: usize, a: usize, len: usize, scheme: QuantScheme) {
    let mut hs = vec![0.0f64; len * len];
    for i in 0..len {
        for j in 0..len {
            hs[i * len + j] = h[(a + i) * n + (a + j)];
        }
    }
    gptq_block(w, &hs, a, len, scheme);
}

/// OBQ recursion on columns `[a, a+len)` given that span's Hessian block.
fn gptq_block(w: &mut Tensor, hs: &[f64], a: usize, len: usize, scheme: QuantScheme) {
    let mut hinv = match spd_inverse(hs, len) {
        Ok(v) => v,
        Err(_) => {
            // pathological sub-Hessian: fall back to plain RTN on the span
            plain_quant_span(w, a, len, scheme);
            return;
        }
    };

    let qmax = scheme.qmax();
    let rows = w.rows;
    let group = scheme.group;

    // group quant params are frozen at each group's start (standard GPTQ)
    let mut scale = vec![1.0f32; rows];
    let mut zero = vec![0.0f32; rows];

    for j in 0..len {
        let col = a + j;
        if col % group == 0 {
            // (re)compute params for this group from current weights
            for r in 0..rows {
                let seg = &w.row(r)[col..col + group];
                let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
                for &v in seg {
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                let range = mx - mn;
                scale[r] = if range > 0.0 { range / qmax } else { 1.0 };
                // packable-zero clamp, matching quant::group::quantize
                zero[r] = round_half_up(-mn / scale[r]).clamp(0.0, qmax);
            }
        }
        let d = hinv[j * len + j].max(1e-12);
        // quantize column j; push err into remaining columns of the span
        for r in 0..rows {
            let v = w.at(r, col);
            let q = (round_half_up(v / scale[r]) + zero[r]).clamp(0.0, qmax);
            let deq = scale[r] * (q - zero[r]);
            let err = ((v - deq) as f64 / d) as f64;
            w.set(r, col, deq);
            if err != 0.0 {
                let wrow = w.row_mut(r);
                for k in j + 1..len {
                    wrow[a + k] -= (err * hinv[j * len + k]) as f32;
                }
            }
        }
        // eliminate j from hinv for subsequent steps (OBQ removal update)
        for r2 in j + 1..len {
            let f = hinv[r2 * len + j] / d;
            if f == 0.0 {
                continue;
            }
            for c2 in j + 1..len {
                hinv[r2 * len + c2] -= f * hinv[j * len + c2];
            }
        }
    }
}

fn plain_quant_span(w: &mut Tensor, a: usize, len: usize, scheme: QuantScheme) {
    let qmax = scheme.qmax();
    for r in 0..w.rows {
        for g0 in (a..a + len).step_by(scheme.group) {
            let seg: Vec<f32> = w.row(r)[g0..g0 + scheme.group].to_vec();
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in &seg {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let range = mx - mn;
            let scale = if range > 0.0 { range / qmax } else { 1.0 };
            let zero = round_half_up(-mn / scale).clamp(0.0, qmax);
            for (i, &v) in seg.iter().enumerate() {
                let q = (round_half_up(v / scale) + zero).clamp(0.0, qmax);
                w.set(r, g0 + i, scale * (q - zero));
            }
        }
    }
}

/// `H' = T·H·Tᵀ` for `T = P·S·R` acting on FFN channels (entrywise form:
/// rotation mixes index pairs, then rows/cols are scaled and permuted).
pub fn transform_hessian(h: &[f64], n: usize, t: &LayerTransform) -> Vec<f64> {
    assert_eq!(t.d_ffn(), n);
    // R·H·Rᵀ first (pairwise Givens on both sides)
    let mut hr = h.to_vec();
    for (p, &phi) in t.phis.iter().enumerate() {
        if phi == 0.0 {
            continue;
        }
        let (i, j) = (2 * p, 2 * p + 1);
        let (c, s) = (phi.cos() as f64, phi.sin() as f64);
        // rows
        for k in 0..n {
            let (a, b) = (hr[i * n + k], hr[j * n + k]);
            hr[i * n + k] = c * a - s * b;
            hr[j * n + k] = s * a + c * b;
        }
        // cols
        for k in 0..n {
            let (a, b) = (hr[k * n + i], hr[k * n + j]);
            hr[k * n + i] = c * a - s * b;
            hr[k * n + j] = s * a + c * b;
        }
    }
    // S·(..)·S then P·(..)·Pᵀ in one gather pass
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        let si = t.scale[i] as f64;
        let pi = t.perm[i];
        for j in 0..n {
            out[i * n + j] = si * t.scale[j] as f64 * hr[pi * n + t.perm[j]];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant, quant_mse};
    use crate::tensor::ops::matmul_nt;
    use crate::util::rng::Pcg64;

    /// Proxy output error: ‖X·Wᵀ − X·Ŵᵀ‖² on the calibration inputs.
    fn output_error(w: &Tensor, wq: &Tensor, x: &Tensor) -> f64 {
        let (m, k, n) = (x.rows, x.cols, w.rows);
        let mut y0 = vec![0.0f32; m * n];
        let mut y1 = vec![0.0f32; m * n];
        matmul_nt(&x.data, &w.data, m, k, n, &mut y0);
        matmul_nt(&x.data, &wq.data, m, k, n, &mut y1);
        y0.iter().zip(&y1).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
    }

    fn setup(seed: u64, out: usize, inp: usize, samples: usize) -> (Tensor, Tensor, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        // correlated inputs make compensation matter
        let base: Vec<f32> = (0..samples).map(|_| rng.normal() as f32).collect();
        let mut x = Tensor::zeros(samples, inp);
        for r in 0..samples {
            for c in 0..inp {
                x.set(r, c, base[r] * 0.6 + rng.normal() as f32 * 0.8);
            }
        }
        let w = Tensor::from_vec(out, inp, (0..out * inp).map(|_| rng.normal() as f32).collect());
        let h = hessian(&x, DAMP);
        (w, x, h)
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let scheme = QuantScheme::new(2, 16);
        let (w, x, h) = setup(1, 12, 32, 64);
        let rtn = fake_quant(&w, scheme);
        let gq_blocked = gptq_quantize(&w, &h, scheme, false, None);
        let gq_exact = gptq_quantize(&w, &h, scheme, true, None);
        let e_rtn = output_error(&w, &rtn, &x);
        let e_blk = output_error(&w, &gq_blocked, &x);
        let e_ext = output_error(&w, &gq_exact, &x);
        assert!(e_blk < e_rtn, "blocked GPTQ {e_blk} !< RTN {e_rtn}");
        assert!(e_ext < e_rtn, "exact GPTQ {e_ext} !< RTN {e_rtn}");
    }

    #[test]
    fn blocked_close_to_exact() {
        let scheme = QuantScheme::new(2, 16);
        let (w, x, h) = setup(2, 8, 48, 96);
        let gq_blocked = gptq_quantize(&w, &h, scheme, false, None);
        let gq_exact = gptq_quantize(&w, &h, scheme, true, None);
        let e_blk = output_error(&w, &gq_blocked, &x);
        let e_ext = output_error(&w, &gq_exact, &x);
        // blocked within 2x of exact (usually much closer)
        assert!(e_blk <= e_ext * 2.0 + 1e-9, "blocked {e_blk} vs exact {e_ext}");
    }

    #[test]
    fn quantized_values_respect_codebook() {
        // each (row, group) segment holds at most 2^bits distinct values
        // (GPTQ's grid is frozen from compensated weights, so an RTN
        // fixed-point check would be too strong)
        let scheme = QuantScheme::new(2, 16);
        let (w, _, h) = setup(3, 4, 32, 64);
        let gq = gptq_quantize(&w, &h, scheme, false, None);
        for r in 0..gq.rows {
            for g in 0..gq.cols / scheme.group {
                let seg = &gq.row(r)[g * scheme.group..(g + 1) * scheme.group];
                let mut vals: Vec<i64> =
                    seg.iter().map(|&v| (v as f64 * 1e6).round() as i64).collect();
                vals.sort_unstable();
                vals.dedup();
                assert!(vals.len() <= 4, "row {r} group {g}: {} values", vals.len());
            }
        }
        let _ = quant_mse(&gq, scheme); // exercised for coverage
    }

    #[test]
    fn identity_transform_hessian_noop() {
        let (_, _, h) = setup(4, 4, 16, 32);
        let t = LayerTransform::identity(16);
        let h2 = transform_hessian(&h, 16, &t);
        for (a, b) in h.iter().zip(&h2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn permuted_hessian_matches_permuted_inputs() {
        // H(X·Pᵀ-ish) == P-transformed H(X): validate with explicit perm
        let mut rng = Pcg64::new(5);
        let n = 8;
        let x = Tensor::from_vec(20, n, (0..160).map(|_| rng.normal() as f32).collect());
        let h = hessian(&x, 0.0);
        let mut t = LayerTransform::identity(n);
        t.perm = rng.permutation(n);
        let h_t = transform_hessian(&h, n, &t);
        // explicit: permuted input columns x'[, i] = x[, perm[i]]
        let xp = x.gather_cols(&t.perm);
        let h_direct = hessian(&xp, 0.0);
        for (a, b) in h_t.iter().zip(&h_direct) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn scaled_hessian_matches_scaled_inputs() {
        let mut rng = Pcg64::new(6);
        let n = 8;
        let x = Tensor::from_vec(20, n, (0..160).map(|_| rng.normal() as f32).collect());
        let h = hessian(&x, 0.0);
        let mut t = LayerTransform::identity(n);
        for s in t.scale.iter_mut() {
            *s = (rng.uniform() as f32) + 0.5;
        }
        let h_t = transform_hessian(&h, n, &t);
        let mut xs = x.clone();
        for c in 0..n {
            xs.scale_col(c, t.scale[c]);
        }
        let h_direct = hessian(&xs, 0.0);
        for (a, b) in h_t.iter().zip(&h_direct) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn prepare_builds_all_hessians() {
        let (w, calib) = crate::baselines::tests::test_setup();
        let stats = crate::calib::capture(&w, &calib);
        let p = prepare(QuantScheme::new(2, 32), &w, &stats);
        if let Quantizer::Gptq { hessians, .. } = &p.quantizer {
            assert_eq!(hessians.len(), 6 * w.config.n_layers);
            let d = w.config.d_model;
            assert_eq!(hessians["l0.q.w"].len(), d * d);
            assert_eq!(hessians["l0.down.w"].len(), w.config.d_ffn * w.config.d_ffn);
        } else {
            panic!("not a GPTQ quantizer");
        }
    }
}

#[cfg(test)]
mod blocked_transform_tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn entrywise_transform_matches_full_matrix() {
        let mut rng = Pcg64::new(9);
        let n = 16;
        let x = crate::tensor::Tensor::from_vec(
            40,
            n,
            (0..40 * n).map(|_| rng.normal() as f32).collect(),
        );
        let h = crate::calib::hessian(&x, 0.01);
        let t = LayerTransform::identity(n).propose(
            &mut rng,
            crate::transform::TransformKinds::all(),
            0.5,
            0.2,
            0.05,
        );
        let full = transform_hessian(&h, n, &t);
        for a in 0..n {
            for b in 0..n {
                let e = transformed_h_entry(&h, n, &t, a, b);
                assert!(
                    (e - full[a * n + b]).abs() < 1e-9 * (1.0 + full[a * n + b].abs()),
                    "({a},{b}): {e} vs {}",
                    full[a * n + b]
                );
            }
        }
    }

    #[test]
    fn blocked_quantize_same_with_and_without_transform_identity() {
        let mut rng = Pcg64::new(10);
        let (out, inp) = (12, 64);
        let x = crate::tensor::Tensor::from_vec(
            64,
            inp,
            (0..64 * inp).map(|_| rng.normal() as f32).collect(),
        );
        let h = crate::calib::hessian(&x, 0.01);
        let w = crate::tensor::Tensor::from_vec(
            out,
            inp,
            (0..out * inp).map(|_| rng.normal() as f32).collect(),
        );
        let t_id = LayerTransform::identity(inp);
        let a = gptq_quantize(&w, &h, QuantScheme::new(2, 32), false, None);
        let b = gptq_quantize(&w, &h, QuantScheme::new(2, 32), false, Some(&t_id));
        for (x1, x2) in a.data.iter().zip(&b.data) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }
}

//! Property-testing substrate ("proptest-lite": proptest is not vendored).
//!
//! Drives a closure over many seeded random cases; on failure it reports the
//! failing seed so the case can be replayed deterministically.  Coordinator
//! invariants (routing of proposals, transform algebra, codec round-trips)
//! are checked with this throughout the test suite.

use super::rng::Pcg64;

/// Number of cases per property (env override `INVAREXPLORE_PROPCHECK_CASES`).
pub fn default_cases() -> usize {
    std::env::var("INVAREXPLORE_PROPCHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Like [`check`] with the default case count.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    check(name, default_cases(), prop)
}

/// Assertion helpers returning `Result<(), String>` for use inside props.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f32, b: f32, atol: f32, what: &str) -> Result<(), String> {
    if (a - b).abs() <= atol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (atol {atol})"))
    }
}

pub fn ensure_all_close(a: &[f32], b: &[f32], atol: f32, what: &str) -> Result<(), String> {
    ensure(a.len() == b.len(), format!("{what}: length {} vs {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("{what}[{i}]: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("uniform in range", 32, |rng| {
            let u = rng.uniform();
            ensure((0.0..1.0).contains(&u), format!("u={u}"))
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_helpers() {
        assert!(ensure_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(ensure_close(1.0, 1.1, 1e-3, "x").is_err());
        assert!(ensure_all_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, "v").is_ok());
        assert!(ensure_all_close(&[1.0], &[1.0, 2.0], 0.0, "v").is_err());
    }
}

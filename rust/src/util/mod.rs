//! Infrastructure substrates built from scratch for the offline sandbox
//! (the vendored crate set only contains the `xla` closure — no serde, no
//! clap, no rand, no criterion, no rayon).

pub mod atomic;
pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod logging;
pub mod plot;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod sampling;

pub use atomic::atomic_write;

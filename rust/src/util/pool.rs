//! Scoped thread-pool substrate (rayon/tokio are not vendored).
//!
//! Used for data-parallel work in the coordinator: calibration capture over
//! batches, GPTQ over independent linear layers, and reasoning-task
//! scoring.  Built on `std::thread::scope`, so closures may borrow.

/// Number of worker threads to use (env override `INVAREXPLORE_THREADS`).
pub fn num_threads() -> usize {
    if let Some(n) = crate::util::cli::env_parse::<usize>("INVAREXPLORE_THREADS") {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Extract a human-readable message from a panic payload.  `panic!` with a
/// format string produces `String` payloads and bare string literals
/// produce `&str`; anything else gets a stable placeholder.  Shared with
/// the serving router's replica supervision, so chaos-test failures name
/// the actual worker error instead of a generic "worker panicked".
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Apply `f` to every index `0..n` in parallel, collecting results in order.
///
/// Work is distributed by atomic counter (dynamic scheduling), so uneven
/// item costs (e.g. GPTQ on differently-shaped layers) balance well.
///
/// A panicking worker is caught, remaining work is abandoned (the claim
/// counter is exhausted so idle workers stop early), and the panic is
/// rethrown on the caller's thread with the original payload message
/// attached — attributable, not a bare "worker panicked".
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let failed: std::sync::Mutex<Option<String>> = std::sync::Mutex::new(None);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slot_ptr = SendPtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let failed = &failed;
            let f = &f;
            let slot_ptr = slot_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                    // SAFETY: each index i is claimed exactly once, so each
                    // slot is written by exactly one thread; the scope
                    // outlives use.
                    Ok(out) => unsafe {
                        *slot_ptr.get().add(i) = Some(out);
                    },
                    Err(payload) => {
                        let mut first = failed.lock().unwrap_or_else(|e| e.into_inner());
                        if first.is_none() {
                            *first = Some(panic_message(payload.as_ref()));
                        }
                        // abandon unclaimed work: no point computing slots
                        // the caller will never see
                        next.store(n, Ordering::Relaxed);
                        break;
                    }
                }
            });
        }
    });

    if let Some(msg) = failed.into_inner().unwrap_or_else(|e| e.into_inner()) {
        panic!("pool worker panicked: {msg}");
    }
    slots.into_iter().map(|s| s.expect("slot filled")).collect()
}

/// Wrapper making a raw pointer Send for the scoped-disjoint-writes pattern.
///
/// Accessed through [`SendPtr::get`] so closures capture the whole wrapper
/// (Rust 2021 disjoint capture would otherwise grab the raw field, which is
/// not `Send`).
struct SendPtr<T>(*mut T);
// SAFETY: SendPtr is only ever constructed over a buffer that outlives the
// `thread::scope` in which it is shared, and every user partitions writes
// so no two threads touch the same element: `parallel_map` writes slot `i`
// only from the thread that won `i` from the atomic claim counter, and
// `parallel_chunks_mut` hands each worker `[ci*chunk, min((ci+1)*chunk,
// len))` for distinct claimed `ci`, so the derived `&mut` ranges never
// alias. No references into the buffer exist outside the scope while
// workers run (the owner is borrowed away by `as_mut_ptr`), so moving the
// raw pointer to another thread cannot create aliased mutable access.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: sharing `&SendPtr` across threads only exposes a copy of the raw
// pointer; dereferencing stays unsafe at each use site, where the
// disjoint-write argument above applies. T: Send is required by the public
// entry points, which move T values across worker threads.
unsafe impl<T> Sync for SendPtr<T> {}

// manual impls: `derive` would wrongly require `T: Copy`
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    fn get(self) -> *mut T {
        self.0
    }
}

/// Chunked parallel-for over a mutable slice: each worker gets disjoint
/// chunks (used by the native forward's batched matmul).
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || data.len() <= chunk {
        for (ci, c) in data.chunks_mut(chunk).enumerate() {
            f(ci, c);
        }
        return;
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    let n_chunks = data.len().div_ceil(chunk);
    let next = AtomicUsize::new(0);
    let failed: std::sync::Mutex<Option<String>> = std::sync::Mutex::new(None);
    let base = SendPtr(data.as_mut_ptr());
    let len = data.len();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n_chunks) {
            let next = &next;
            let failed = &failed;
            let f = &f;
            let base = base;
            scope.spawn(move || loop {
                let ci = next.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let start = ci * chunk;
                let end = (start + chunk).min(len);
                // SAFETY: `ci` is claimed exactly once from the atomic
                // counter and `ci < n_chunks`, so `start < len` and
                // `end <= len`: the range is in bounds of the original
                // slice, and ranges for distinct `ci` are disjoint, so no
                // two live `&mut [T]` overlap. The scope keeps `data`
                // borrowed until all workers join.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(base.get().add(start), end - start)
                };
                if let Err(payload) =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ci, slice)))
                {
                    let mut first = failed.lock().unwrap_or_else(|e| e.into_inner());
                    if first.is_none() {
                        *first = Some(panic_message(payload.as_ref()));
                    }
                    next.store(n_chunks, Ordering::Relaxed);
                    break;
                }
            });
        }
    });
    if let Some(msg) = failed.into_inner().unwrap_or_else(|e| e.into_inner()) {
        panic!("pool worker panicked: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn map_uneven_costs() {
        let out = parallel_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn chunks_mut_writes_all() {
        let mut data = vec![0usize; 1000];
        parallel_chunks_mut(&mut data, 64, 8, |ci, c| {
            for (j, x) in c.iter_mut().enumerate() {
                *x = ci * 64 + j;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    // -- property tests: the batched proposal scheduler leans on the unsafe
    //    slot-pointer internals, so pin the contract down hard. ------------

    #[test]
    fn prop_map_matches_sequential_for_any_geometry() {
        crate::util::propcheck::check("parallel_map ≡ sequential map", 48, |rng| {
            let n = rng.below(65); // includes n == 0
            let threads = 1 + rng.below(16); // includes n < threads
            let salt = rng.next_u64();
            let out = parallel_map(n, threads, |i| (i as u64).wrapping_mul(salt) ^ i as u64);
            let expect: Vec<u64> =
                (0..n).map(|i| (i as u64).wrapping_mul(salt) ^ i as u64).collect();
            crate::util::propcheck::ensure(
                out == expect,
                format!("mismatch at n={n} threads={threads}"),
            )
        });
    }

    #[test]
    fn prop_map_order_with_uneven_worker_costs() {
        crate::util::propcheck::check("ordering under work-stealing imbalance", 8, |rng| {
            let n = 16 + rng.below(17);
            let slow = rng.below(n);
            let out = parallel_map(n, 4, |i| {
                if i == slow {
                    std::thread::sleep(std::time::Duration::from_millis(3));
                }
                i
            });
            crate::util::propcheck::ensure(
                out == (0..n).collect::<Vec<_>>(),
                format!("order broken with slow item {slow}"),
            )
        });
    }

    #[test]
    fn map_fewer_items_than_threads() {
        // threads are clamped to n; every slot still filled exactly once
        for n in 1..5 {
            let out = parallel_map(n, 16, |i| i * 3);
            assert_eq!(out, (0..n).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_zero_items_spawns_nothing() {
        let out: Vec<usize> = parallel_map(0, 8, |_| panic!("worker must not run"));
        assert!(out.is_empty());
    }

    #[test]
    fn map_worker_panic_propagates() {
        // a panicking worker must unwind out of parallel_map (scope joins all
        // threads first), not dead-lock or silently drop slots — and the
        // rethrown payload must carry the worker's own message so chaos-test
        // failures are attributable
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(32, 4, |i| {
                if i == 17 {
                    panic!("worker bug at item {i}");
                }
                i
            })
        }));
        let payload = result.err().expect("worker panic was swallowed");
        let msg = panic_message(payload.as_ref());
        assert!(
            msg.contains("worker bug at item 17"),
            "rethrown panic lost the worker message: {msg:?}"
        );
    }

    #[test]
    fn chunks_mut_worker_panic_carries_message() {
        let mut data = vec![0u8; 512];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_chunks_mut(&mut data, 16, 4, |ci, _c| {
                if ci == 9 {
                    panic!("chunk {ci} exploded");
                }
            })
        }));
        let payload = result.err().expect("chunk worker panic was swallowed");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("chunk 9 exploded"), "message lost: {msg:?}");
    }

    #[test]
    fn panic_message_handles_all_payload_shapes() {
        assert_eq!(panic_message(&"literal"), "literal");
        assert_eq!(panic_message(&String::from("formatted")), "formatted");
        assert_eq!(panic_message(&42usize), "non-string panic payload");
    }

    // NOTE: no set_var-based test for INVAREXPLORE_THREADS here — other
    // unit tests read that variable concurrently through num_threads(),
    // and mutating the process environment mid-test-run is a race (and
    // getenv/setenv UB on glibc).  The parse-and-clamp behavior is covered
    // via util::cli::env_parse's own tests on dedicated variable names.
}

//! Minimal JSON parser / writer (serde is not in the offline vendor set).
//!
//! Covers the full JSON grammar needed by this repo: the artifacts manifest,
//! reasoning-task files, search-state checkpoints and result emitters.
//! Numbers are stored as `f64`; object key order is preserved (important
//! for the canonical parameter ordering recorded by `aot.py`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (Vec keeps `aot.py`'s key order).
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- constructors -------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert for object construction.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut entries) = self {
            entries.push((key.to_string(), value.into()));
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // -- accessors -----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the missing key name — for manifest parsing.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key: {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(e) => Some(e),
            _ => None,
        }
    }

    /// Object entries as a map view (for tests / unordered comparison).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        self.entries()
            .map(|e| e.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    /// Convenience: `[f64]` array extraction.
    pub fn f64_array(&self) -> crate::Result<Vec<f64>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected JSON array"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    pub fn usize_array(&self) -> crate::Result<Vec<usize>> {
        Ok(self.f64_array()?.into_iter().map(|n| n as usize).collect())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/nan; readers treat null as "absent"
                    // and fall back (e.g. search-state best_ce -> +inf)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse error with byte offset for debugging manifest problems.
#[derive(Debug, thiserror::Error)]
#[error("JSON parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> crate::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our writers;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"name":"opt-tiny","shape":[8,128],"ok":true,"eps":0.5,"nul":null}"#;
        let v = parse(text).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("truex").is_err());
    }

    #[test]
    fn builder() {
        let v = Json::obj().set("a", 1usize).set("b", "x").set("c", vec![1i64, 2]);
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn non_finite_numbers_stay_parseable() {
        // JSON has no inf/nan tokens; the writer must not emit them
        for v in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let text = Json::obj().set("x", v).to_string();
            let back = parse(&text).unwrap();
            assert_eq!(back.get("x").unwrap(), &Json::Null);
        }
    }

    #[test]
    fn f64_array_helper() {
        let v = parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.f64_array().unwrap(), vec![1.0, 2.5, 3.0]);
        assert!(parse("[1, \"x\"]").unwrap().f64_array().is_err());
    }
}

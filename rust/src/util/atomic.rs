//! Crash-safe file writes: temp file in the target directory + `fsync` +
//! atomic rename.
//!
//! A plain `std::fs::write` that loses the race with a crash (or a `kill -9`
//! mid-run) leaves a truncated file behind — fatal for search checkpoints,
//! whose whole point is resuming an hours-long hill-climb, and quietly
//! corrupting for bench trajectories and the audit baseline.  Routing those
//! writers through [`atomic_write`] guarantees readers observe either the
//! old complete file or the new complete file, never a torn prefix:
//!
//! 1. the bytes land in a uniquely-named temp file *in the same directory*
//!    (rename is only atomic within a filesystem),
//! 2. the temp file is `fsync`ed so the data is durable before it becomes
//!    visible under the real name,
//! 3. `rename` swaps it in — POSIX guarantees the destination name always
//!    refers to one complete file or the other,
//! 4. best-effort `fsync` of the directory makes the rename itself durable.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

// Distinguishes concurrent atomic_write calls from the same process to the
// same destination (e.g. two bench suites flushing into one directory).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` crash-safely: a reader (or a post-crash restart)
/// sees either the previous contents or the new contents in full, never a
/// truncated intermediate.  The temp file is removed on any failure.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let dir: PathBuf = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "out".to_string());
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!(".{name}.tmp.{}.{seq}", std::process::id()));

    let mut file = File::create(&tmp)?;
    let written = file.write_all(bytes).and_then(|()| file.sync_all());
    drop(file);
    if let Err(e) = written {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    // Durability of the *name* change; failure here cannot tear the file
    // (the data is already synced and renamed), so it is non-fatal.
    if let Ok(d) = File::open(&dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("invarexplore_atomic_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).expect("create scratch dir");
        d
    }

    #[test]
    fn write_then_read_roundtrips() {
        let dir = scratch_dir("roundtrip");
        let path = dir.join("out.json");
        atomic_write(&path, b"{\"k\":1}").expect("atomic write");
        assert_eq!(std::fs::read(&path).expect("read back"), b"{\"k\":1}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overwrite_replaces_previous_contents() {
        let dir = scratch_dir("overwrite");
        let path = dir.join("state.json");
        atomic_write(&path, b"old contents, longer than the new ones").expect("first write");
        atomic_write(&path, b"new").expect("second write");
        assert_eq!(std::fs::read(&path).expect("read back"), b"new");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_temp_files_left_behind() {
        let dir = scratch_dir("cleanup");
        let path = dir.join("bench.json");
        for i in 0..4u32 {
            atomic_write(&path, format!("run {i}").as_bytes()).expect("write");
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .expect("list dir")
            .map(|e| e.expect("entry").file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["bench.json".to_string()], "stray temp files: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_an_error_not_a_panic() {
        let path = std::env::temp_dir()
            .join(format!("invarexplore_atomic_missing_{}", std::process::id()))
            .join("nested")
            .join("out.json");
        assert!(atomic_write(&path, b"x").is_err());
    }
}

//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! PCG64 (O'Neill) with helpers for the search algorithm: Gaussian random
//! walks (`normal`), Fisher–Yates shuffles (permutation proposals), and
//! subset sampling (the paper's "change 10% of neurons per step").
//! All randomness in the binary flows from one seeded root so identical CLI
//! invocations produce identical tables (DESIGN.md §5).

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream for the same seed (used to give each layer /
    /// worker its own generator without correlation).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child generator (splittable-PRNG style).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian with given mean / std.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k>n");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut rng = Pcg64::new(9);
        let p = rng.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::new(11);
        for _ in 0..50 {
            let k = rng.below(64) + 1;
            let idx = rng.sample_indices(128, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(idx.iter().all(|&i| i < 128));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut rng = Pcg64::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn split_streams_uncorrelated() {
        let mut root = Pcg64::new(17);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}

//! Tiny leveled logger (the `log`/`env_logger` pair is deliberately avoided
//! to keep the dependency set to the xla closure).
//!
//! Level comes from `INVAREXPLORE_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.  Messages go to stderr so CLI table output on
//! stdout stays machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::LazyLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: LazyLock<Instant> = LazyLock::new(Instant::now);

/// Initialise from the environment; safe to call multiple times.
pub fn init() {
    let lvl = match std::env::var("INVAREXPLORE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    LazyLock::force(&START);
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments) {
    if enabled(lvl) {
        let t = START.elapsed().as_secs_f64();
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}

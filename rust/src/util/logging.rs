//! Tiny leveled logger (the `log`/`env_logger` pair is deliberately avoided
//! to keep the dependency set to the xla closure).
//!
//! Level comes from `INVAREXPLORE_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.  Messages go to stderr so CLI table output on
//! stdout stays machine-readable.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::LazyLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: LazyLock<Instant> = LazyLock::new(Instant::now);
static WARNED_BAD_LEVEL: AtomicBool = AtomicBool::new(false);

/// The accepted `INVAREXPLORE_LOG` values, in severity order.
pub const LEVEL_NAMES: [&str; 5] = ["error", "warn", "info", "debug", "trace"];

/// Parse one `INVAREXPLORE_LOG` value.  Every accepted name is matched
/// explicitly — including `info` — so an unrecognized value is
/// distinguishable from the default instead of silently falling through.
pub fn parse_level(s: &str) -> Option<Level> {
    match s {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Initialise from the environment; safe to call multiple times.  An
/// unrecognized `INVAREXPLORE_LOG` value keeps the `info` default and warns
/// once, naming the bad value and the accepted set.
pub fn init() {
    let lvl = match std::env::var("INVAREXPLORE_LOG") {
        Ok(v) => match parse_level(&v) {
            Some(l) => l,
            None => {
                if !WARNED_BAD_LEVEL.swap(true, Ordering::Relaxed) {
                    eprintln!(
                        "[logging] unrecognized INVAREXPLORE_LOG value {v:?}; \
                         accepted: {}; defaulting to \"info\"",
                        LEVEL_NAMES.join("|")
                    );
                }
                Level::Info
            }
        },
        Err(_) => Level::Info,
    };
    set_level(lvl);
    LazyLock::force(&START);
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments) {
    if enabled(lvl) {
        let t = START.elapsed().as_secs_f64();
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_level_accepts_exactly_the_documented_set() {
        // pure-fn coverage — no env mutation (setenv in tests is UB under
        // concurrent getenv)
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info), "info is matched explicitly");
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        for bad in ["", "INFO", "verbose", "warning", "2", "Info "] {
            assert_eq!(parse_level(bad), None, "{bad:?} must not parse");
        }
        // the advertised name list round-trips through the parser
        for name in LEVEL_NAMES {
            assert!(parse_level(name).is_some(), "{name} advertised but unparseable");
        }
    }
}

//! Criterion-less micro/macro benchmark harness (criterion is not vendored).
//!
//! The `rust/benches/*.rs` binaries use [`BenchSuite`] both for wall-clock
//! measurement (perf_hotpath) and for driving the paper's table/figure
//! reproductions, whose primary output is the table itself.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }

    /// A single externally-measured timing (e.g. one wall-clock run of a
    /// whole serving workload) as a recordable row.
    pub fn one_shot(d: Duration) -> Stats {
        Stats { iters: 1, mean: d, min: d, max: d, p50: d }
    }
}

/// Measure `f` adaptively: warm up, then run until `budget` or `max_iters`.
pub fn measure<F: FnMut()>(mut f: F, budget: Duration, max_iters: usize) -> Stats {
    // warmup
    f();
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && times.len() < max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    if times.is_empty() {
        times.push(Duration::ZERO);
    }
    let mut sorted = times.clone();
    sorted.sort();
    let sum: Duration = times.iter().sum();
    Stats {
        iters: times.len(),
        mean: sum / times.len() as u32,
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: sorted[sorted.len() / 2],
    }
}

/// Named collection of benchmark results with aligned text output.
pub struct BenchSuite {
    name: String,
    rows: Vec<(String, Stats)>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        BenchSuite {
            name: name.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, label: &str, f: F) -> Stats {
        let budget = Duration::from_millis(
            std::env::var("INVAREXPLORE_BENCH_MS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(1500),
        );
        let stats = measure(f, budget, 1000);
        println!(
            "  {label:<42} {:>12?} mean  {:>12?} p50  ({} iters)",
            stats.mean, stats.p50, stats.iters
        );
        self.rows.push((label.to_string(), stats.clone()));
        stats
    }

    pub fn report(&self) -> String {
        let mut out = format!("== bench suite: {} ==\n", self.name);
        for (label, s) in &self.rows {
            out.push_str(&format!(
                "{label},{:.6e},{:.6e},{}\n",
                s.mean.as_secs_f64(),
                s.p50.as_secs_f64(),
                s.iters
            ));
        }
        out
    }

    /// Measured rows so far (label, stats).
    pub fn rows(&self) -> &[(String, Stats)] {
        &self.rows
    }

    /// Record an externally-measured result (e.g. [`Stats::one_shot`]) as a
    /// row, so one-shot workload timings land in the JSON trajectory next
    /// to the loop-measured rows.
    pub fn record(&mut self, label: &str, stats: Stats) {
        println!(
            "  {label:<42} {:>12?} mean  ({} iters, recorded)",
            stats.mean, stats.iters
        );
        self.rows.push((label.to_string(), stats));
    }

    /// Serialize the suite as JSON — the machine-readable perf trajectory
    /// CI archives per run (`BENCH_<suite>.json` artifacts), replacing the
    /// log-scrape-only text report.
    pub fn to_json(&self) -> Json {
        Json::obj().set("suite", self.name.as_str()).set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|(label, s)| {
                        Json::obj()
                            .set("label", label.as_str())
                            .set("mean_s", s.mean.as_secs_f64())
                            .set("p50_s", s.p50.as_secs_f64())
                            .set("min_s", s.min.as_secs_f64())
                            .set("max_s", s.max.as_secs_f64())
                            .set("iters", s.iters)
                    })
                    .collect(),
            ),
        )
    }

    /// Write `BENCH_<suite>.json` into `dir`, returning the path.
    pub fn write_json(&self, dir: &Path) -> crate::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }
}

/// Per-case measurement budget for CI smoke runs: honor an explicit
/// `INVAREXPLORE_BENCH_MS`, else drop to `ms` so a smoke still measures
/// real (non-empty) rows without holding the pipeline for seconds per case.
pub fn smoke_budget_ms(ms: u64) {
    if std::env::var("INVAREXPLORE_BENCH_MS").is_err() {
        std::env::set_var("INVAREXPLORE_BENCH_MS", ms.to_string());
    }
}

/// Helper: should the bench run at paper scale? (`INVAREXPLORE_FULL=1`)
pub fn full_scale() -> bool {
    std::env::var("INVAREXPLORE_FULL").as_deref() == Ok("1")
}

/// Search-step budget for benches (`INVAREXPLORE_STEPS` override).
pub fn step_budget(default: usize) -> usize {
    std::env::var("INVAREXPLORE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if full_scale() { 10_000 } else { default })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let s = measure(
            || {
                std::hint::black_box(1 + 1);
            },
            Duration::from_millis(20),
            50,
        );
        assert!(s.iters >= 1 && s.iters <= 50);
        assert!(s.min <= s.mean && s.mean <= s.max.max(s.mean));
    }

    #[test]
    fn suite_report_contains_labels() {
        let mut suite = BenchSuite::new("t");
        suite.bench("fast_op", || {
            std::hint::black_box(2 * 2);
        });
        assert!(suite.report().contains("fast_op"));
    }

    #[test]
    fn step_budget_default() {
        std::env::remove_var("INVAREXPLORE_STEPS");
        std::env::remove_var("INVAREXPLORE_FULL");
        assert_eq!(step_budget(123), 123);
    }

    #[test]
    fn json_trajectory_written_and_parseable() {
        let mut suite = BenchSuite::new("unit_test_suite");
        suite.bench("tiny_op", || {
            std::hint::black_box(3 * 3);
        });
        let dir = std::env::temp_dir().join("invarexplore_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = suite.write_json(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit_test_suite.json"));
        let j = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(j.req("suite").unwrap().as_str(), Some("unit_test_suite"));
        let rows = j.req("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1, "smoke trajectories must not be empty");
        assert_eq!(rows[0].req("label").unwrap().as_str(), Some("tiny_op"));
        assert!(rows[0].req("iters").unwrap().as_usize().unwrap() >= 1);
        assert!(rows[0].req("mean_s").unwrap().as_f64().is_some());
    }
}

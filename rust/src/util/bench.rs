//! Criterion-less micro/macro benchmark harness (criterion is not vendored).
//!
//! The `rust/benches/*.rs` binaries use [`BenchSuite`] both for wall-clock
//! measurement (perf_hotpath) and for driving the paper's table/figure
//! reproductions, whose primary output is the table itself.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }

    /// A single externally-measured timing (e.g. one wall-clock run of a
    /// whole serving workload) as a recordable row.
    pub fn one_shot(d: Duration) -> Stats {
        Stats { iters: 1, mean: d, min: d, max: d, p50: d }
    }
}

/// Measure `f` adaptively: warm up, then run until `budget` or `max_iters`.
pub fn measure<F: FnMut()>(mut f: F, budget: Duration, max_iters: usize) -> Stats {
    // warmup
    f();
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && times.len() < max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    if times.is_empty() {
        times.push(Duration::ZERO);
    }
    let mut sorted = times.clone();
    sorted.sort();
    let sum: Duration = times.iter().sum();
    Stats {
        iters: times.len(),
        mean: sum / times.len() as u32,
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: sorted[sorted.len() / 2],
    }
}

/// Named collection of benchmark results with aligned text output.
pub struct BenchSuite {
    name: String,
    rows: Vec<(String, Stats)>,
    /// Wall-clock placement of each row relative to the trace epoch
    /// (`ts_us`, `dur_us`) — turned into Chrome-trace span events by
    /// [`BenchSuite::write_json`] so a trace viewer shows where suite time
    /// went.
    row_spans: Vec<(u64, u64)>,
    /// Derived scalar results (achieved GB/s, overhead fractions, …)
    /// attached to the JSON trajectory under `"counters"` — the
    /// perf-history drift check reads these as higher-is-better series.
    counters: Vec<(String, f64)>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        // pin the obs trace epoch now, so every row span (and any recorder
        // event emitted during the run) shares one zero point
        let _ = crate::obs::trace::rel_us(Instant::now());
        BenchSuite {
            name: name.to_string(),
            rows: Vec::new(),
            row_spans: Vec::new(),
            counters: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, label: &str, f: F) -> Stats {
        let budget = Duration::from_millis(
            std::env::var("INVAREXPLORE_BENCH_MS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(1500),
        );
        let t0 = Instant::now();
        let stats = measure(f, budget, 1000);
        self.row_spans
            .push((crate::obs::trace::rel_us(t0), t0.elapsed().as_micros() as u64));
        println!(
            "  {label:<42} {:>12?} mean  {:>12?} p50  ({} iters)",
            stats.mean, stats.p50, stats.iters
        );
        self.rows.push((label.to_string(), stats.clone()));
        stats
    }

    pub fn report(&self) -> String {
        let mut out = format!("== bench suite: {} ==\n", self.name);
        for (label, s) in &self.rows {
            out.push_str(&format!(
                "{label},{:.6e},{:.6e},{}\n",
                s.mean.as_secs_f64(),
                s.p50.as_secs_f64(),
                s.iters
            ));
        }
        out
    }

    /// Measured rows so far (label, stats).
    pub fn rows(&self) -> &[(String, Stats)] {
        &self.rows
    }

    /// Record an externally-measured result (e.g. [`Stats::one_shot`]) as a
    /// row, so one-shot workload timings land in the JSON trajectory next
    /// to the loop-measured rows.
    pub fn record(&mut self, label: &str, stats: Stats) {
        // externally measured: the best span placement available is "it
        // ended about now and lasted mean * iters"
        let now = Instant::now();
        let total = stats.mean.saturating_mul(stats.iters.max(1) as u32);
        let ts = crate::obs::trace::rel_us(now).saturating_sub(total.as_micros() as u64);
        self.row_spans.push((ts, total.as_micros() as u64));
        println!(
            "  {label:<42} {:>12?} mean  ({} iters, recorded)",
            stats.mean, stats.iters
        );
        self.rows.push((label.to_string(), stats));
    }

    /// Attach a derived scalar result (e.g. achieved GB/s per SIMD tier,
    /// or an overhead fraction) to the suite.  Lands under `"counters"` in
    /// `BENCH_<suite>.json` and as a Chrome counter event in
    /// `TRACE_<suite>.json`; re-setting a name overwrites its value.
    pub fn set_counter(&mut self, name: &str, value: f64) {
        if let Some(c) = self.counters.iter_mut().find(|(n, _)| n == name) {
            c.1 = value;
        } else {
            self.counters.push((name.to_string(), value));
        }
    }

    /// Counters attached so far (name, value).
    pub fn counters(&self) -> &[(String, f64)] {
        &self.counters
    }

    /// Serialize the suite as JSON — the machine-readable perf trajectory
    /// CI archives per run (`BENCH_<suite>.json` artifacts), replacing the
    /// log-scrape-only text report.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj().set("suite", self.name.as_str()).set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|(label, s)| {
                        Json::obj()
                            .set("label", label.as_str())
                            .set("mean_s", s.mean.as_secs_f64())
                            .set("p50_s", s.p50.as_secs_f64())
                            .set("min_s", s.min.as_secs_f64())
                            .set("max_s", s.max.as_secs_f64())
                            .set("iters", s.iters)
                    })
                    .collect(),
            ),
        );
        if !self.counters.is_empty() {
            let mut c = Json::obj();
            for (name, v) in &self.counters {
                c = c.set(name.as_str(), *v);
            }
            j = j.set("counters", c);
        }
        j
    }

    /// Chrome trace-event document for the suite: one span per bench row
    /// (wall-clock placement against the shared trace epoch), one counter
    /// event per attached counter, plus everything the span recorder
    /// captured during the run (drained here; empty when tracing was off).
    pub fn to_trace_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for ((label, _), &(ts, dur)) in self.rows.iter().zip(&self.row_spans) {
            events.push(
                Json::obj()
                    .set("name", label.as_str())
                    .set("cat", "bench")
                    .set("ph", "X")
                    .set("pid", 1usize)
                    .set("tid", 0usize)
                    .set("ts", ts as f64)
                    .set("dur", dur as f64),
            );
        }
        let t_end = self.row_spans.last().map_or(0, |&(ts, dur)| ts + dur);
        for (name, v) in &self.counters {
            events.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("cat", "bench")
                    .set("ph", "C")
                    .set("pid", 1usize)
                    .set("tid", 0usize)
                    .set("ts", t_end as f64)
                    .set("args", Json::obj().set("value", *v)),
            );
        }
        for ev in crate::obs::trace::take_events() {
            events.push(crate::obs::chrome::event_json(&ev));
        }
        Json::obj().set("displayTimeUnit", "ms").set("traceEvents", Json::Arr(events))
    }

    /// Write `BENCH_<suite>.json` into `dir` — and its Chrome-trace twin
    /// `TRACE_<suite>.json` next to it, so every bench smoke ships a
    /// loadable trace artifact without per-binary plumbing.  Returns the
    /// BENCH path.
    pub fn write_json(&self, dir: &Path) -> crate::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        // atomic (temp + fsync + rename): a bench killed mid-write can't
        // leave a torn artifact for the CI expected-file check to trip on
        crate::util::atomic_write(&path, self.to_json().to_string().as_bytes())?;
        let trace_path = dir.join(format!("TRACE_{}.json", self.name));
        crate::util::atomic_write(&trace_path, self.to_trace_json().to_string().as_bytes())?;
        Ok(path)
    }
}

/// Per-case measurement budget for CI smoke runs: honor an explicit
/// `INVAREXPLORE_BENCH_MS`, else drop to `ms` so a smoke still measures
/// real (non-empty) rows without holding the pipeline for seconds per case.
pub fn smoke_budget_ms(ms: u64) {
    if std::env::var("INVAREXPLORE_BENCH_MS").is_err() {
        std::env::set_var("INVAREXPLORE_BENCH_MS", ms.to_string());
    }
}

/// Helper: should the bench run at paper scale? (`INVAREXPLORE_FULL=1`)
pub fn full_scale() -> bool {
    std::env::var("INVAREXPLORE_FULL").as_deref() == Ok("1")
}

/// Search-step budget for benches (`INVAREXPLORE_STEPS` override).
pub fn step_budget(default: usize) -> usize {
    std::env::var("INVAREXPLORE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if full_scale() { 10_000 } else { default })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let s = measure(
            || {
                std::hint::black_box(1 + 1);
            },
            Duration::from_millis(20),
            50,
        );
        assert!(s.iters >= 1 && s.iters <= 50);
        assert!(s.min <= s.mean && s.mean <= s.max.max(s.mean));
    }

    #[test]
    fn suite_report_contains_labels() {
        let mut suite = BenchSuite::new("t");
        suite.bench("fast_op", || {
            std::hint::black_box(2 * 2);
        });
        assert!(suite.report().contains("fast_op"));
    }

    #[test]
    fn step_budget_default() {
        std::env::remove_var("INVAREXPLORE_STEPS");
        std::env::remove_var("INVAREXPLORE_FULL");
        assert_eq!(step_budget(123), 123);
    }

    #[test]
    fn json_trajectory_written_and_parseable() {
        // write_json's trace twin drains the global span recorder — hold
        // the obs guard so concurrently-running obs tests don't lose events
        let _g = crate::obs::test_guard();
        let mut suite = BenchSuite::new("unit_test_suite");
        suite.bench("tiny_op", || {
            std::hint::black_box(3 * 3);
        });
        suite.set_counter("kernel_gemm_gbps_scalar", 12.5);
        suite.set_counter("kernel_gemm_gbps_scalar", 13.0); // overwrite wins
        let dir = std::env::temp_dir().join("invarexplore_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = suite.write_json(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit_test_suite.json"));
        let j = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(j.req("suite").unwrap().as_str(), Some("unit_test_suite"));
        let rows = j.req("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1, "smoke trajectories must not be empty");
        assert_eq!(rows[0].req("label").unwrap().as_str(), Some("tiny_op"));
        assert!(rows[0].req("iters").unwrap().as_usize().unwrap() >= 1);
        assert!(rows[0].req("mean_s").unwrap().as_f64().is_some());
        let c = j.req("counters").unwrap();
        assert_eq!(c.get("kernel_gemm_gbps_scalar").unwrap().as_f64(), Some(13.0));

        // the Chrome-trace twin is written next to it and is a loadable
        // trace: one span per row, one counter event per counter
        let trace = dir.join("TRACE_unit_test_suite.json");
        let t = crate::util::json::parse_file(&trace).unwrap();
        assert_eq!(t.req("displayTimeUnit").unwrap().as_str(), Some("ms"));
        let evs = t.req("traceEvents").unwrap().as_arr().unwrap();
        let row_span = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("tiny_op"))
            .expect("bench row span");
        assert_eq!(row_span.get("ph").unwrap().as_str(), Some("X"));
        assert!(row_span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        let counter_ev = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("kernel_gemm_gbps_scalar"))
            .expect("counter event");
        assert_eq!(counter_ev.get("ph").unwrap().as_str(), Some("C"));
        assert_eq!(
            counter_ev.get("args").unwrap().get("value").unwrap().as_f64(),
            Some(13.0)
        );
    }
}

//! ASCII line plots for Figure-1 style optimization curves, rendered into
//! bench output and EXPERIMENTS.md (no plotting library in the sandbox).

/// Render one or more (x, y) series into a fixed-size ASCII grid.
///
/// Each series gets a distinct glyph; axes are annotated with min/max.
pub fn render(
    title: &str,
    series: &[(&str, &[(f64, f64)])],
    width: usize,
    height: usize,
) -> String {
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &pts {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for (x, y) in s.iter() {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy.min(height - 1)][cx.min(width - 1)] = g;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", glyphs[si % glyphs.len()], name));
    }
    out.push_str(&format!("{ymax:>10.4} ┐\n"));
    for row in &grid {
        out.push_str("           │");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{ymin:>10.4} ┴"));
    out.push_str(&"─".repeat(width));
    out.push('\n');
    out.push_str(&format!("            {xmin:<12.1}{:>w$.1}\n", xmax, w = width.saturating_sub(12)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points() {
        let s1: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, (i * i) as f64)).collect();
        let out = render("quadratic", &[("sq", &s1)], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains("quadratic"));
        // title + legend + ymax + grid rows + ymin + x axis
        assert_eq!(out.lines().count(), 1 + 1 + 1 + 10 + 1 + 1);
    }

    #[test]
    fn empty_series() {
        let out = render("nothing", &[("e", &[])], 10, 5);
        assert!(out.contains("no data"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s: Vec<(f64, f64)> = (0..5).map(|i| (i as f64, 3.0)).collect();
        let out = render("flat", &[("f", &s)], 20, 5);
        assert!(out.contains('*'));
    }

    #[test]
    fn multiple_series_distinct_glyphs() {
        let a: Vec<(f64, f64)> = vec![(0.0, 0.0), (1.0, 1.0)];
        let b: Vec<(f64, f64)> = vec![(0.0, 1.0), (1.0, 0.0)];
        let out = render("two", &[("a", &a), ("b", &b)], 20, 8);
        assert!(out.contains('*') && out.contains('o'));
    }
}

//! Command-line argument parsing substrate (clap is not vendored).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positionals,
//! and generates usage text from registered options.

use std::collections::BTreeMap;

/// Parse an environment-variable override: `None` when the variable is
/// unset or fails to parse.  The crate-wide pattern for tuning knobs
/// (`INVAREXPLORE_THREADS`, `INVAREXPLORE_SIGMA_R`, …).
pub fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
    // ENV-DOC: generic accessor — each caller names its knob and is
    // checked against the README table at its own call site
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Env override with a fallback default.
pub fn env_override<T: std::str::FromStr>(name: &str, default: T) -> T {
    // ENV-DOC: generic accessor — callers name the knob
    env_parse(name).unwrap_or(default)
}

/// Declarative option spec for one subcommand.
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for a subcommand.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req(&self, name: &str) -> crate::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value for --{name}: {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse raw argv (after the subcommand) against a spec.
pub fn parse_args(spec: &[ArgSpec], argv: &[String]) -> crate::Result<Args> {
    let mut args = Args::default();
    // seed defaults
    for s in spec {
        if let Some(d) = s.default {
            args.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let s = spec
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", usage(spec)))?;
            if s.is_flag {
                anyhow::ensure!(inline_val.is_none(), "--{name} takes no value");
                args.flags.push(name);
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                    }
                };
                args.values.insert(name, val);
            }
        } else {
            args.positionals.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Usage text generated from a spec.
pub fn usage(spec: &[ArgSpec]) -> String {
    let mut out = String::from("options:\n");
    for s in spec {
        let tail = if s.is_flag {
            String::new()
        } else if let Some(d) = s.default {
            format!(" <value> (default: {d})")
        } else {
            " <value> (required)".to_string()
        };
        out.push_str(&format!("  --{}{}\n      {}\n", s.name, tail, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<ArgSpec> {
        vec![
            ArgSpec { name: "model", help: "model name", default: Some("opt-base"), is_flag: false },
            ArgSpec { name: "steps", help: "search steps", default: Some("100"), is_flag: false },
            ArgSpec { name: "verbose", help: "chatty", default: None, is_flag: true },
            ArgSpec { name: "out", help: "output path", default: None, is_flag: false },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse_args(&spec(), &sv(&["--steps", "250"])).unwrap();
        assert_eq!(a.get("model"), Some("opt-base"));
        assert_eq!(a.parse_or::<usize>("steps", 0).unwrap(), 250);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse_args(&spec(), &sv(&["--model=opt-tiny", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("model"), Some("opt-tiny"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["pos1"]);
    }

    #[test]
    fn required_missing() {
        let a = parse_args(&spec(), &sv(&[])).unwrap();
        assert!(a.req("out").is_err());
        assert!(a.req("model").is_ok());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse_args(&spec(), &sv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse_args(&spec(), &sv(&["--out"])).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage(&spec());
        assert!(u.contains("--model") && u.contains("default: opt-base"));
    }

    #[test]
    fn env_override_roundtrip() {
        // unique variable names: tests run concurrently in one process
        std::env::remove_var("INVAREXPLORE_TEST_ENV_A");
        assert_eq!(env_parse::<f64>("INVAREXPLORE_TEST_ENV_A"), None);
        assert_eq!(env_override("INVAREXPLORE_TEST_ENV_A", 0.25f64), 0.25);

        std::env::set_var("INVAREXPLORE_TEST_ENV_B", "42");
        assert_eq!(env_parse::<usize>("INVAREXPLORE_TEST_ENV_B"), Some(42));
        assert_eq!(env_override("INVAREXPLORE_TEST_ENV_B", 7usize), 42);

        std::env::set_var("INVAREXPLORE_TEST_ENV_C", "not-a-number");
        assert_eq!(env_parse::<f64>("INVAREXPLORE_TEST_ENV_C"), None);
        assert_eq!(env_override("INVAREXPLORE_TEST_ENV_C", 1.5f64), 1.5);
        std::env::remove_var("INVAREXPLORE_TEST_ENV_B");
        std::env::remove_var("INVAREXPLORE_TEST_ENV_C");
    }
}

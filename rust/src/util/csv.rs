//! CSV emission for the figure/table benches (and a small reader used by
//! tests to check what the benches wrote).

use std::io::Write;
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> crate::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter {
            file,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[String]) -> crate::Result<()> {
        anyhow::ensure!(
            values.len() == self.cols,
            "CSV row has {} values, header has {}",
            values.len(),
            self.cols
        );
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    /// Convenience for all-numeric rows.
    pub fn row_f64(&mut self, values: &[f64]) -> crate::Result<()> {
        let vs: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        self.row(&vs)
    }

    pub fn flush(&mut self) -> crate::Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Parse a simple CSV (no quoting — our writers never quote).
pub fn read_csv(path: &Path) -> crate::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty CSV"))?
        .split(',')
        .map(String::from)
        .collect();
    let rows = lines
        .filter(|l| !l.is_empty())
        .map(|l| l.split(',').map(String::from).collect())
        .collect();
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("invarexplore_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        {
            let mut w = CsvWriter::create(&p, &["step", "loss"]).unwrap();
            w.row_f64(&[1.0, 0.5]).unwrap();
            w.row(&["2".into(), "0.25".into()]).unwrap();
            w.flush().unwrap();
        }
        let (hdr, rows) = read_csv(&p).unwrap();
        assert_eq!(hdr, ["step", "loss"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["2", "0.25"]);
    }

    #[test]
    fn wrong_arity_rejected() {
        let dir = std::env::temp_dir().join("invarexplore_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(&dir.join("y.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
    }
}

//! Token sampling strategies for the serving path: greedy, temperature,
//! top-k — operating on raw logit slices from the `head_logits` program.

use super::rng::Pcg64;

/// Decoding strategy.
#[derive(Debug, Clone, Copy)]
pub enum Sampler {
    Greedy,
    /// Softmax sampling at a temperature (> 0).
    Temperature(f32),
    /// Top-k restricted temperature sampling.
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    /// Parse a CLI/serve-config spec: `greedy`, `temp:0.8`, `topk:8` or
    /// `topk:8:0.7` (temperature defaults to 1.0).
    pub fn parse(s: &str) -> crate::Result<Sampler> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0].to_ascii_lowercase().as_str() {
            "greedy" => Ok(Sampler::Greedy),
            "temp" | "temperature" => {
                anyhow::ensure!(parts.len() == 2, "bad sampler {s:?} (want temp:<t>)");
                Ok(Sampler::Temperature(parts[1].parse()?))
            }
            "topk" => {
                anyhow::ensure!(
                    parts.len() == 2 || parts.len() == 3,
                    "bad sampler {s:?} (want topk:<k>[:<t>])"
                );
                let k: usize = parts[1].parse()?;
                anyhow::ensure!(k >= 1, "bad sampler {s:?}: k must be >= 1");
                let temperature = if parts.len() == 3 { parts[2].parse()? } else { 1.0 };
                Ok(Sampler::TopK { k, temperature })
            }
            _ => anyhow::bail!("unknown sampler {s:?} (greedy|temp:<t>|topk:<k>[:<t>])"),
        }
    }

    /// Pick the next token id from `logits`.
    pub fn sample(&self, logits: &[f32], rng: &mut Pcg64) -> usize {
        match *self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature(t) => {
                let idx: Vec<usize> = (0..logits.len()).collect();
                categorical(logits, &idx, t, rng)
            }
            Sampler::TopK { k, temperature } => {
                let idx = top_k_indices(logits, k.max(1));
                categorical(logits, &idx, temperature, rng)
            }
        }
    }
}

pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest logits (unordered).
pub fn top_k_indices(logits: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    let k = k.min(logits.len());
    idx.select_nth_unstable_by(k - 1, |&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Sample among `idx` proportional to `softmax(logits[idx] / t)`.
fn categorical(logits: &[f32], idx: &[usize], temperature: f32, rng: &mut Pcg64) -> usize {
    let t = temperature.max(1e-4);
    let mx = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx.iter().map(|&i| (((logits[i] - mx) / t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (w, &i) in weights.iter().zip(idx) {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    *idx.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = [0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg64::new(0);
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0, 5.0, 4.0, -3.0, 1.0];
        let mut rng = Pcg64::new(1);
        let s = Sampler::TopK { k: 2, temperature: 1.0 };
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = [0.0, 1.0, 0.5];
        let mut rng = Pcg64::new(2);
        let s = Sampler::Temperature(0.01);
        let hits = (0..200).filter(|_| s.sample(&logits, &mut rng) == 1).count();
        assert!(hits > 195, "hits {hits}");
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = [0.0, 1.0];
        let mut rng = Pcg64::new(3);
        let s = Sampler::Temperature(100.0);
        let hits = (0..2000).filter(|_| s.sample(&logits, &mut rng) == 0).count();
        assert!((800..1200).contains(&hits), "hits {hits}");
    }

    #[test]
    fn top_k_indices_correct() {
        let logits = [3.0, 1.0, 4.0, 1.5, 5.0];
        let mut idx = top_k_indices(&logits, 3);
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 2, 4]);
    }

    #[test]
    fn parse_forms() {
        assert!(matches!(Sampler::parse("greedy").unwrap(), Sampler::Greedy));
        assert!(matches!(Sampler::parse("temp:0.5").unwrap(), Sampler::Temperature(t) if t == 0.5));
        assert!(matches!(
            Sampler::parse("topk:8:0.7").unwrap(),
            Sampler::TopK { k: 8, temperature } if temperature == 0.7
        ));
        assert!(matches!(
            Sampler::parse("TOPK:4").unwrap(),
            Sampler::TopK { k: 4, temperature } if temperature == 1.0
        ));
        assert!(Sampler::parse("nope").is_err());
        assert!(Sampler::parse("temp").is_err());
        assert!(Sampler::parse("topk:x").is_err());
        assert!(Sampler::parse("topk:0").is_err());
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let s = Sampler::TopK { k: 8, temperature: 0.7 };
        let run = |seed| {
            let mut rng = Pcg64::new(seed);
            (0..20).map(|_| s.sample(&logits, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}

//! `invarexplore` — CLI entry point.  See `cli::USAGE`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match invarexplore::cli::main_with_args(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
